"""L2 correctness: the fused nomad_step graph (kernel + scatter + SGD)."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref
from .test_forces import make_problem


def test_step_equals_manual_update():
    rng = np.random.default_rng(0)
    prob = make_problem(rng, 256, 7, 4, 16, frac_valid=0.8)
    args = list(map(jnp.asarray, prob))
    lr = jnp.float32(0.5)
    pos_new, loss = model.nomad_step(*args, lr, block=64)

    grad = ref.nomad_grad_ref(*args)
    valid = args[7]
    want = args[0] - lr * grad * valid[:, None]
    np.testing.assert_allclose(pos_new, want, rtol=1e-5, atol=1e-6)

    hg, tg, ng, loss_h = ref.nomad_forces_ref(*args)
    np.testing.assert_allclose(loss, jnp.sum(loss_h) / jnp.sum(valid), rtol=1e-5)


def test_step_decreases_loss():
    """A few gradient steps on a fixed problem must reduce the NOMAD loss."""
    rng = np.random.default_rng(1)
    prob = make_problem(rng, 256, 7, 4, 16)
    args = list(map(jnp.asarray, prob))
    l0 = float(ref.nomad_loss(*args))
    pos = args[0]
    for _ in range(10):
        pos, loss = model.nomad_step(pos, *args[1:], jnp.float32(2.0), block=64)
    l1 = float(ref.nomad_loss(pos, *args[1:]))
    assert l1 < l0, (l0, l1)


def test_padding_is_invariant():
    """Padding a shard (extra masked rows) must not change valid results."""
    rng = np.random.default_rng(2)
    s, k, n, r = 128, 5, 4, 8
    prob = list(make_problem(rng, s, k, n, r))
    args = list(map(jnp.asarray, prob))
    pos1, loss1 = model.nomad_step(*args, jnp.float32(1.0), block=64)

    # pad to 2s: padded heads self-loop with zero weight
    pos_p = np.concatenate([prob[0], np.zeros((s, 2), np.float32)])
    nbr_p = np.concatenate([prob[1], np.tile(np.arange(s, 2 * s, dtype=np.int32)[:, None], (1, k))])
    w_p = np.concatenate([prob[2], np.zeros((s, k), np.float32)])
    neg_p = np.concatenate([prob[3], np.tile(np.arange(s, 2 * s, dtype=np.int32)[:, None], (1, n))])
    valid_p = np.concatenate([prob[7], np.zeros((s,), np.float32)])
    pos2, loss2 = model.nomad_step(
        jnp.asarray(pos_p), jnp.asarray(nbr_p), jnp.asarray(w_p), jnp.asarray(neg_p),
        jnp.asarray(prob[4]), jnp.asarray(prob[5]), jnp.asarray(prob[6]),
        jnp.asarray(valid_p), jnp.float32(1.0), block=64,
    )
    np.testing.assert_allclose(np.asarray(pos2)[:s], pos1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(loss2, loss1, rtol=1e-5)
    # padded rows do not move
    np.testing.assert_allclose(np.asarray(pos2)[s:], 0.0, atol=0.0)
