"""L1 correctness: Pallas force kernel vs pure-jnp oracle vs jax.grad.

This is the core numerical contract of the whole stack: the same math is
re-implemented in Rust (embed/native.rs) and cross-checked against the HLO
artifacts lowered from these exact functions.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.forces import nomad_forces


def make_problem(rng, s, k, n, r, frac_valid=1.0, spread=3.0):
    pos = rng.normal(size=(s, 2)).astype(np.float32) * spread
    nbr_idx = rng.integers(0, s, size=(s, k)).astype(np.int32)
    nbr_w = rng.random(size=(s, k)).astype(np.float32)
    nbr_w /= nbr_w.sum(axis=1, keepdims=True)
    neg_idx = rng.integers(0, s, size=(s, n)).astype(np.int32)
    neg_w = np.array([rng.random() * 2.0 + 0.1], dtype=np.float32)
    means = rng.normal(size=(r, 2)).astype(np.float32) * spread
    mean_w = (rng.random(size=(r,)) * 4.0).astype(np.float32)
    nvalid = max(1, int(s * frac_valid))
    valid = np.zeros((s,), np.float32)
    valid[:nvalid] = 1.0
    # zero edge weights of padded heads, as the coordinator does
    nbr_w[nvalid:] = 0.0
    return pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid


@pytest.mark.parametrize("s,k,n,r,block", [(256, 5, 4, 8, 64), (512, 15, 8, 32, 256)])
def test_pallas_matches_ref(s, k, n, r, block):
    rng = np.random.default_rng(0)
    prob = make_problem(rng, s, k, n, r)
    got = nomad_forces(*map(jnp.asarray, prob), block=block)
    want = ref.nomad_forces_ref(*map(jnp.asarray, prob))
    for g, w, name in zip(got, want, ["head", "tail", "negtail", "loss"]):
        np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-6, err_msg=name)


@pytest.mark.parametrize("frac_valid", [1.0, 0.7, 0.3])
def test_analytic_grad_matches_autodiff(frac_valid):
    rng = np.random.default_rng(1)
    prob = make_problem(rng, 128, 7, 5, 16, frac_valid=frac_valid)
    args = list(map(jnp.asarray, prob))
    got = ref.nomad_grad_ref(*args)
    want = ref.nomad_grad_autodiff(*args)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_padded_heads_receive_no_head_force():
    rng = np.random.default_rng(2)
    prob = make_problem(rng, 128, 7, 5, 16, frac_valid=0.5)
    hg, _, _, loss = ref.nomad_forces_ref(*map(jnp.asarray, prob))
    np.testing.assert_allclose(hg[64:], 0.0, atol=0.0)
    np.testing.assert_allclose(loss[64:], 0.0, atol=0.0)


def test_zero_mean_weight_means_are_inert():
    rng = np.random.default_rng(3)
    s, k, n, r = 128, 7, 5, 16
    prob = list(make_problem(rng, s, k, n, r))
    prob[6] = np.zeros((r,), np.float32)  # mean_w = 0
    g_masked = ref.nomad_grad_ref(*map(jnp.asarray, prob))
    # moving the (masked) means must not change the gradient
    prob2 = list(prob)
    prob2[5] = prob[5] + 100.0
    g_moved = ref.nomad_grad_ref(*map(jnp.asarray, prob2))
    np.testing.assert_allclose(g_masked, g_moved, rtol=1e-6)


def test_repulsion_pushes_apart_attraction_pulls_together():
    # two points, one positive edge 0->1, no means, no negatives beyond q(ii)
    pos = np.array([[0.0, 0.0], [1.0, 0.0]], np.float32)
    nbr_idx = np.array([[1], [0]], np.int32)
    nbr_w = np.ones((2, 1), np.float32)
    neg_idx = np.array([[0], [1]], np.int32)  # self-negative: delta 0, no force
    neg_w = np.array([0.0], np.float32)
    means = np.zeros((1, 2), np.float32)
    valid = np.ones((2,), np.float32)

    # Decompose with a mean-negative at the midpoint: the attractive head
    # component must point along +delta (descent pulls i toward j) and the
    # repulsive component along -delta (descent pushes i off the mean).
    means = np.array([[0.5, 0.0]], np.float32)
    mean_w = np.array([1.0], np.float32)
    args = list(map(jnp.asarray, (pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)))
    hg, tg, ng, _ = ref.nomad_forces_ref(*args)

    # positive-edge tail reaction: tail_grad = -c_att * delta with c_att >= 0
    # and delta_01 = p0 - p1 = (-1, 0)  =>  tail x-component >= 0, so the
    # descent step moves p1 toward -x, i.e. toward p0 (attraction).
    assert float(tg[0, 0, 0]) > 0.0
    assert float(tg[1, 0, 0]) < 0.0  # mirrored edge 1->0

    # exact-negative tail: negtail_grad = +c_nr * delta_in; put a negative at
    # x=0.25 for head 0 => delta x = -0.25 => grad x < 0 => descent pushes
    # the negative toward +x, away from the head (repulsion).
    pos3 = np.array([[0.0, 0.0], [1.0, 0.0], [0.25, 0.0]], np.float32)
    nbr3 = np.array([[1], [0], [0]], np.int32)
    w3 = np.array([[1.0], [1.0], [0.0]], np.float32)
    negi3 = np.array([[2], [2], [2]], np.int32)
    negw3 = np.array([5.0], np.float32)
    valid3 = np.ones((3,), np.float32)
    _, _, ng3, _ = ref.nomad_forces_ref(
        *map(jnp.asarray, (pos3, nbr3, w3, negi3, negw3, means, mean_w, valid3))
    )
    assert float(ng3[0, 0, 0]) < 0.0  # pushed away from head 0 (toward +x)
    assert float(ng3[1, 0, 0]) > 0.0  # pushed away from head 1 (toward -x)

    # mirror symmetry of the two-point configuration
    g_small = ref.nomad_grad_ref(*args)
    np.testing.assert_allclose(np.asarray(g_small[0]), -np.asarray(g_small[1]), rtol=1e-5, atol=1e-7)


def test_nomad_upper_bounds_infonc_tsne():
    """Theorem 1: the mean-approximated loss >= the exact-negative loss.

    We realize both sides with the same machinery: the 'exact' loss uses the
    actual negative samples of a cell (neg_w path); the 'approximate' loss
    replaces that cell with its weighted mean (mean_w path).  Jensen ->
    approximate >= exact, up to the 2nd-order Taylor term, which vanishes
    here because we evaluate with the cell's *exact* empirical mean.
    """
    rng = np.random.default_rng(4)
    s, k = 256, 5
    pos = rng.normal(size=(s, 2)).astype(np.float32) * 2.0
    nbr_idx = rng.integers(0, s, size=(s, k)).astype(np.int32)
    nbr_w = rng.random(size=(s, k)).astype(np.float32)
    nbr_w /= nbr_w.sum(axis=1, keepdims=True)
    valid = np.ones((s,), np.float32)

    # one cell containing ALL points, |M| = 16 noise samples
    m_count = 16.0
    cell = np.arange(s)
    mu = pos[cell].mean(axis=0, keepdims=True)

    # exact: negatives are 16 uniform samples, weight |M|*p(cell)/16 = 1 each.
    # To kill sampling noise use the expectation: every point with weight
    # m_count / s. That is exactly E_{M~xi}[sum q(im)].
    neg_idx_full = np.tile(np.arange(s, dtype=np.int32)[None, :], (s, 1))
    neg_w_full = np.array([m_count / s], np.float32)
    zero_means = np.zeros((1, 2), np.float32)
    zero_mw = np.zeros((1,), np.float32)
    l_exact = ref.nomad_loss(
        *map(jnp.asarray, (pos, nbr_idx, nbr_w, neg_idx_full, neg_w_full, zero_means, zero_mw, valid))
    )

    # approx: the single cell replaced by its mean with weight |M|*p = 16
    neg_idx0 = np.zeros((s, 1), np.int32)
    neg_w0 = np.array([0.0], np.float32)
    mean_w = np.array([m_count], np.float32)
    l_approx = ref.nomad_loss(
        *map(jnp.asarray, (pos, nbr_idx, nbr_w, neg_idx0, neg_w0, mu, mean_w, valid))
    )
    assert float(l_approx) >= float(l_exact) - 1e-5


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([64, 128]),
    k=st.integers(1, 8),
    n=st.integers(1, 6),
    r=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_pallas_vs_ref(s, k, n, r, seed):
    rng = np.random.default_rng(seed)
    prob = make_problem(rng, s, k, n, r, frac_valid=rng.random() * 0.9 + 0.1)
    got = nomad_forces(*map(jnp.asarray, prob), block=s // 2)
    want = ref.nomad_forces_ref(*map(jnp.asarray, prob))
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=3e-5, atol=3e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), spread=st.floats(0.1, 10.0))
def test_hypothesis_analytic_vs_autodiff(seed, spread):
    rng = np.random.default_rng(seed)
    prob = make_problem(rng, 64, 5, 3, 8, spread=spread)
    args = list(map(jnp.asarray, prob))
    got = ref.nomad_grad_ref(*args)
    want = ref.nomad_grad_autodiff(*args)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)
