"""L1 correctness: K-Means assignment + within-cluster kNN kernels vs oracles."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.kmeans import kmeans_assign
from compile.kernels.knn import knn
from compile import model


def test_kmeans_assign_matches_ref():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(1024, 32)).astype(np.float32)
    c = rng.normal(size=(64, 32)).astype(np.float32)
    cmask = np.ones((64,), np.float32)
    cmask[50:] = 0.0
    a1, d1 = kmeans_assign(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), block=256)
    a2, d2 = ref.kmeans_assign_ref(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask))
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)
    assert int(np.max(np.asarray(a1))) < 50  # padded centroids never selected


def test_kmeans_assign_exact_vs_numpy():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(512, 16)).astype(np.float32)
    c = rng.normal(size=(8, 16)).astype(np.float32)
    cmask = np.ones((8,), np.float32)
    a, d = kmeans_assign(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), block=128)
    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)
    np.testing.assert_array_equal(np.asarray(a), d2.argmin(1).astype(np.int32))
    np.testing.assert_allclose(np.asarray(d), d2.min(1), rtol=1e-3, atol=1e-3)


def test_kmeans_em_step_statistics():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(512, 16)).astype(np.float32)
    c = rng.normal(size=(8, 16)).astype(np.float32)
    cmask = np.ones((8,), np.float32)
    a, d, sums, counts = model.kmeans_em_step(
        jnp.asarray(x), jnp.asarray(c), jnp.asarray(cmask), block=128
    )
    a = np.asarray(a)
    for j in range(8):
        m = a == j
        np.testing.assert_allclose(np.asarray(counts)[j], m.sum(), atol=0)
        if m.any():
            np.testing.assert_allclose(np.asarray(sums)[j], x[m].sum(0), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,k,block", [(256, 16, 5, 64), (512, 32, 15, 256)])
def test_knn_matches_ref(n, d, k, block):
    rng = np.random.default_rng(3)
    x = rng.normal(size=(n, d)).astype(np.float32)
    vmask = np.ones((n,), np.float32)
    vmask[n - n // 4 :] = 0.0
    x[vmask == 0.0] = 0.0
    i1, d1 = knn(jnp.asarray(x), jnp.asarray(vmask), k=k, block=block)
    i2, d2 = ref.knn_ref(jnp.asarray(x), jnp.asarray(vmask), k)
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-4)
    # indices may tie-break differently; check distances and validity instead
    nv = int(vmask.sum())
    valid_rows = np.asarray(d1)[:nv]
    assert np.all(valid_rows < 1e37)


def test_knn_exact_vs_numpy_bruteforce():
    rng = np.random.default_rng(4)
    n, d, k = 128, 8, 6
    x = rng.normal(size=(n, d)).astype(np.float32)
    vmask = np.ones((n,), np.float32)
    idx, dd = knn(jnp.asarray(x), jnp.asarray(vmask), k=k, block=64)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    want = np.sort(d2, axis=1)[:, :k]
    np.testing.assert_allclose(np.sort(np.asarray(dd), axis=1), want, rtol=1e-3, atol=1e-3)
    # no self edges
    assert not np.any(np.asarray(idx) == np.arange(n)[:, None])


@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([4, 16, 33]),
    k=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_knn_distances(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * rng.random()).astype(np.float32)
    vmask = np.ones((n,), np.float32)
    idx, dd = knn(jnp.asarray(x), jnp.asarray(vmask), k=k, block=n // 2)
    d2 = ((x[:, None, :] - x[None, :, :]) ** 2).sum(-1)
    np.fill_diagonal(d2, np.inf)
    want = np.sort(d2, axis=1)[:, :k]
    np.testing.assert_allclose(np.sort(np.asarray(dd), axis=1), want, rtol=2e-3, atol=2e-3)
