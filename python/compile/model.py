"""Layer-2 JAX model: the NOMAD Projection shard-step and index-build graphs.

Everything here is build-time only: ``aot.py`` lowers these jitted functions
once to HLO text which the Rust coordinator loads and executes via PJRT.
Each function composes a Layer-1 Pallas kernel (kernels/*.py) with the XLA
glue (scatter-adds, top-k, SGD update) that the paper's CUDA implementation
did with separate kernel launches — XLA fuses them into one executable, so
the Rust hot path makes exactly one ``execute`` call per shard per epoch.

Contracts are mirrored 1:1 by:
  * ``kernels/ref.py``         — jnp oracles (pytest, build time)
  * ``rust/src/embed/native.rs`` etc. — the Rust fallback (cross-checked in
    ``rust/tests/integration.rs``)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import forces as forces_k
from .kernels import kmeans as kmeans_k
from .kernels import knn as knn_k


@functools.partial(jax.jit, static_argnames=("block",))
def nomad_step(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, lr, *, block=256):
    """One full NOMAD gradient-descent step for one shard.

    Inputs: see kernels/ref.py docstring; ``lr`` is a scalar f32.
    Returns (pos_new [S,2], loss [] f32).  (No buffer donation: the AOT HLO
    interchange drops aliasing info anyway, and tests reuse the input.)

    The gradient is the mean-normalized analytic gradient of the NOMAD loss
    (paper Eq 3) with remote cluster means treated as constants; padding
    heads are masked so they never move.
    """
    hg, tg, ng, loss_h = forces_k.nomad_forces(
        pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, block=block
    )
    s, k = nbr_idx.shape
    n = neg_idx.shape[1]
    grad = hg
    grad = grad.at[nbr_idx.reshape(-1)].add(tg.reshape(s * k, 2))
    grad = grad.at[neg_idx.reshape(-1)].add(ng.reshape(s * n, 2))
    nvalid = jnp.maximum(jnp.sum(valid), 1.0)
    grad = grad / nvalid
    pos_new = pos - lr * grad * valid[:, None]
    return pos_new, jnp.sum(loss_h) / nvalid


@functools.partial(jax.jit, static_argnames=("block",))
def kmeans_em_step(x, c, cmask, *, block=512):
    """One K-Means EM step over a padded point bucket.

    x [N,D], c [C,D] centroids, cmask [C] -> (assign [N] i32, d2 [N],
    sums [C,D], counts [C]).  ``sums``/``counts`` are the scatter-added
    statistics for the M-step; the Rust coordinator divides (and re-seeds
    empty clusters) because that logic is data-dependent control flow.
    Padded points must be passed with x row = 0 and are excluded by the
    caller via a validity mask applied to assign on the Rust side; here every
    row participates (the coordinator always packs real points first and
    slices the outputs).
    """
    assign, d2 = kmeans_k.kmeans_assign(x, c, cmask, block=block)
    cc, d = c.shape
    sums = jnp.zeros((cc, d), jnp.float32).at[assign].add(x)
    counts = jnp.zeros((cc,), jnp.float32).at[assign].add(1.0)
    return assign, d2, sums, counts


@functools.partial(jax.jit, static_argnames=("k", "block"))
def knn_build(x, vmask, *, k, block=256):
    """Exact within-cluster kNN over one padded cluster bucket.

    x [N,D], vmask [N] -> (idx [N,k] i32, d2 [N,k] f32); see kernels/knn.py.
    """
    return knn_k.knn(x, vmask, k=k, block=block)
