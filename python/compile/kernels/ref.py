"""Pure-jnp reference oracles for the NOMAD Pallas kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must match its oracle here to float tolerance, and the analytic
gradient oracle must itself match ``jax.grad`` of the scalar loss.

Shapes / conventions (see DESIGN.md §7):
  pos      [S, 2]  f32   low-dimensional positions of one shard (padded)
  nbr_idx  [S, K]  i32   within-shard indices of each head's kNN (self for pad)
  nbr_w    [S, K]  f32   p(j|i) edge weights (inverse-rank model; 0 for pad)
  neg_idx  [S, N]  i32   within-shard exact-negative sample indices
  neg_w    [1]     f32   scale |M| * p(m in own cell) / N for exact negatives
  means    [R, 2]  f32   all-gathered cluster means (embedding space, padded)
  mean_w   [R]     f32   |M| * p(m in r) weights (0 for padding rows)
  valid    [S]     f32   1.0 for real points, 0.0 for shard padding
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cauchy(d2):
    """The Cauchy / Student-t(1) kernel q = 1 / (1 + d^2)."""
    return 1.0 / (1.0 + d2)


def pairwise_d2(a, b):
    """Squared euclidean distances between rows of a [n,d] and b [m,d]."""
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)


def nomad_loss(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid):
    """Scalar NOMAD Projection loss (paper Eq 3) for one shard.

    Mean over valid heads of
      -sum_j w_ij [ log q(ij) - log (q(ij) + A_i) ]
    with A_i the mean-negative plus exact-negative mass.  ``means`` are
    treated as constants (remote shards; stop_gradient), matching the
    distributed algorithm where gradients never cross devices.
    """
    means = jax.lax.stop_gradient(means)
    pn = jnp.take(pos, nbr_idx, axis=0)            # [S,K,2]
    d2 = jnp.sum((pos[:, None, :] - pn) ** 2, -1)  # [S,K]
    q_ij = cauchy(d2)

    dm2 = pairwise_d2(pos, means)                  # [S,R]
    q_ir = cauchy(dm2)

    pneg = jnp.take(pos, neg_idx, axis=0)          # [S,N,2]
    dn2 = jnp.sum((pos[:, None, :] - pneg) ** 2, -1)
    q_in = cauchy(dn2)

    a = jnp.sum(mean_w[None, :] * q_ir, -1) + neg_w[0] * jnp.sum(q_in, -1)
    z = q_ij + a[:, None]
    per_head = -jnp.sum(nbr_w * (jnp.log(q_ij) - jnp.log(z)), -1)
    nvalid = jnp.maximum(jnp.sum(valid), 1.0)
    return jnp.sum(per_head * valid) / nvalid


def nomad_grad_autodiff(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid):
    """jax.grad of the scalar loss — the gold oracle for the analytic forms."""
    return jax.grad(nomad_loss)(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)


def nomad_forces_ref(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid):
    """Analytic per-head force decomposition (the Pallas kernel contract).

    Returns (head_grad [S,2], tail_grad [S,K,2], negtail_grad [S,N,2],
    loss [S]).  The full position gradient of ``nomad_loss`` (times the number
    of valid heads) is

        head_grad + scatter_add(tail_grad @ nbr_idx)
                  + scatter_add(negtail_grad @ neg_idx)

    which ``nomad_grad_ref`` assembles below.
    """
    pn = jnp.take(pos, nbr_idx, axis=0)
    delta_j = pos[:, None, :] - pn                 # [S,K,2]
    q_ij = cauchy(jnp.sum(delta_j**2, -1))         # [S,K]

    dm = pos[:, None, :] - means[None, :, :]       # [S,R,2]
    q_ir = cauchy(jnp.sum(dm**2, -1))              # [S,R]

    pneg = jnp.take(pos, neg_idx, axis=0)
    delta_n = pos[:, None, :] - pneg               # [S,N,2]
    q_in = cauchy(jnp.sum(delta_n**2, -1))         # [S,N]

    a = jnp.sum(mean_w[None, :] * q_ir, -1) + neg_w[0] * jnp.sum(q_in, -1)
    z = q_ij + a[:, None]                          # [S,K]
    w = nbr_w * valid[:, None]

    loss = -jnp.sum(w * (jnp.log(q_ij) - jnp.log(z)), -1)

    # attraction: 2 w q (1 - q/Z) along delta; reaction on the tail.
    c_att = 2.0 * w * q_ij * (1.0 - q_ij / z)      # [S,K]
    att_i = jnp.sum(c_att[:, :, None] * delta_j, 1)
    tail_grad = -c_att[:, :, None] * delta_j

    # shared repulsion strength s_i = sum_j w_ij / Z_ij
    s = jnp.sum(w / z, -1)                         # [S]

    c_mr = 2.0 * s[:, None] * mean_w[None, :] * q_ir**2
    rep_means = jnp.sum(c_mr[:, :, None] * dm, 1)

    c_nr = 2.0 * s[:, None] * neg_w[0] * q_in**2
    rep_negs = jnp.sum(c_nr[:, :, None] * delta_n, 1)
    negtail_grad = c_nr[:, :, None] * delta_n

    head_grad = att_i - rep_means - rep_negs
    return head_grad, tail_grad, negtail_grad, loss


def nomad_grad_ref(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid):
    """Assemble the full analytic gradient of ``nomad_loss`` (mean-normalized)."""
    hg, tg, ng, _ = nomad_forces_ref(
        pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid
    )
    s, k = nbr_idx.shape
    grad = hg
    grad = grad.at[nbr_idx.reshape(-1)].add(tg.reshape(s * k, 2))
    n = neg_idx.shape[1]
    grad = grad.at[neg_idx.reshape(-1)].add(ng.reshape(s * n, 2))
    nvalid = jnp.maximum(jnp.sum(valid), 1.0)
    return grad / nvalid


def kmeans_assign_ref(x, c, cmask):
    """Nearest-centroid assignment.

    x [N,D], c [C,D], cmask [C] (1 real / 0 padding) ->
    (assign [N] i32, d2 [N] f32 squared distance to the chosen centroid).
    Padding centroids are pushed to a huge distance so they are never chosen.
    """
    d2 = pairwise_d2(x, c)
    big = jnp.float32(3.4e38)
    d2 = jnp.where(cmask[None, :] > 0.0, d2, big)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    best = jnp.min(d2, axis=1)
    return assign, best


def knn_ref(x, vmask, k):
    """Exact within-cluster kNN.

    x [N,D], vmask [N] -> (idx [N,k] i32, d2 [N,k] f32), self excluded,
    invalid rows/cols pushed to a huge distance (callers mask by vmask and
    d2 < 1e37).
    """
    d2 = pairwise_d2(x, x)
    n = x.shape[0]
    big = jnp.float32(3.4e38)
    eye = jnp.eye(n, dtype=bool)
    d2 = jnp.where(eye, big, d2)
    d2 = jnp.where(vmask[None, :] > 0.0, d2, big)
    neg_d2, idx = jax.lax.top_k(-d2, k)
    return idx.astype(jnp.int32), -neg_d2
