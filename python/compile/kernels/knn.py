"""Layer-1 Pallas kernel: within-cluster exact kNN distance tiles.

NOMAD's ANN index computes *exact* nearest neighbors inside each K-Means
cluster (paper §3.2), so each cluster is a connected component of the ANN
graph and shards freely across devices.  The inner computation is a padded
N x N squared-distance matrix (N = cluster bucket size, D = ambient dim),
again MXU work: -2 X X^T plus rank-1 norms, tiled (B x D) x (D x N).  The
top-k selection runs as jax.lax.top_k on the tile output (Layer 2), which XLA
fuses with the distance computation.

interpret=True for CPU-PJRT executability (see forces.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.4e38


def _dist_kernel(x_ref, xall_ref, vmask_ref, d2_ref):
    x = x_ref[...]                        # [B, D] row tile
    xa = xall_ref[...]                    # [N, D] full matrix
    vmask = vmask_ref[...]                # [N]
    x2 = jnp.sum(x * x, -1)[:, None]
    a2 = jnp.sum(xa * xa, -1)[None, :]
    xc = jax.lax.dot_general(
        x, xa, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(x2 + a2 - 2.0 * xc, 0.0)
    # mask invalid columns
    d2 = jnp.where(vmask[None, :] > 0.0, d2, _BIG)
    # mask the diagonal (self) for this row tile
    b, n = d2.shape
    row = pl.program_id(0) * b + jax.lax.iota(jnp.int32, b)[:, None]
    col = jax.lax.iota(jnp.int32, n)[None, :]
    d2_ref[...] = jnp.where(row == col, _BIG, d2)


@functools.partial(jax.jit, static_argnames=("k", "block"))
def knn(x, vmask, *, k, block=256):
    """Exact kNN within one padded cluster: (idx [N,k] i32, d2 [N,k]).

    Same contract as ``ref.knn_ref``.
    """
    n, d = x.shape
    assert n % block == 0, (n, block)
    grid = (n // block,)
    d2 = pl.pallas_call(
        _dist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, x, vmask)
    # k rounds of masked argmin instead of lax.top_k: top_k lowers to a
    # `sort` carrying the "largest" attribute, which the xla crate's
    # xla_extension 0.5.1 HLO-text parser rejects.  k passes over the tile
    # output are negligible next to the distance matmul and parse cleanly.
    col = jnp.arange(n, dtype=jnp.int32)[None, :]
    idxs = []
    dists = []
    cur = d2
    for _ in range(k):
        i = jnp.argmin(cur, axis=1).astype(jnp.int32)   # [N]
        v = jnp.min(cur, axis=1)
        idxs.append(i)
        dists.append(v)
        cur = jnp.where(col == i[:, None], _BIG, cur)
    return jnp.stack(idxs, axis=1), jnp.stack(dists, axis=1)
