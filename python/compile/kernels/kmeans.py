"""Layer-1 Pallas kernel: K-Means nearest-centroid assignment.

This is the high-dimensional hot spot of the NOMAD ANN index build: every EM
iteration assigns all N points (D up to 768) to the nearest of C centroids.
On TPU this is an MXU problem: the N x C distance matrix is
|x|^2 + |c|^2 - 2 X C^T, dominated by the X C^T matmul, which we tile
(B_N x D) x (D x C) per grid step so each tile's operands sit in VMEM and the
systolic array does the contraction — the Pallas re-think of the brute-force
CUDA distance loops in t-SNE-CUDA / RAPIDS.

interpret=True for CPU-PJRT executability (see forces.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG = 3.4e38


def _assign_kernel(x_ref, c_ref, cmask_ref, d2_ref):
    x = x_ref[...]                       # [B, D]
    c = c_ref[...]                       # [C, D]
    cmask = cmask_ref[...]               # [C]
    x2 = jnp.sum(x * x, -1)[:, None]
    c2 = jnp.sum(c * c, -1)[None, :]
    # MXU contraction; accumulate in f32.
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = jnp.maximum(x2 + c2 - 2.0 * xc, 0.0)
    d2_ref[...] = jnp.where(cmask[None, :] > 0.0, d2, _BIG)


@functools.partial(jax.jit, static_argnames=("block",))
def kmeans_assign(x, c, cmask, *, block=512):
    """Tiled nearest-centroid assignment: returns (assign i32 [N], d2 [N]).

    Same contract as ``ref.kmeans_assign_ref``.  N must be divisible by
    ``block`` (callers pad to bucket sizes).
    """
    n, d = x.shape
    cc = c.shape[0]
    assert n % block == 0, (n, block)
    d2 = pl.pallas_call(
        _assign_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((cc, d), lambda i: (0, 0)),
            pl.BlockSpec((cc,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, cc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cc), jnp.float32),
        interpret=True,
    )(x, c, cmask)
    assign = jnp.argmin(d2, axis=1).astype(jnp.int32)
    best = jnp.min(d2, axis=1)
    return assign, best
