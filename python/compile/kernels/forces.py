"""Layer-1 Pallas kernel: fused NOMAD force computation.

The hot spot of NOMAD Projection is, per head point i of a shard:

  * gather K neighbor positions, compute Cauchy affinities q(ij)
  * compute q(i, mu_r) against the R all-gathered cluster means
  * gather N exact-negative positions, compute q(in)
  * combine into the per-edge normalizer Z_ij = q_ij + A_i and emit the
    analytic gradient decomposition (head force, per-edge tail reaction,
    per-negative tail reaction) plus the per-head loss.

TPU mapping (see DESIGN.md §5 Hardware-Adaptation): the grid tiles heads in
blocks of B; the shard position array (S x 2 f32, <=128 KiB at S=16384) is
replicated into VMEM for every grid step so neighbor/negative gathers are
VMEM-local, replacing the CUDA shared-memory gather in t-SNE-CUDA. All math
is VPU element-wise/reduction work with the lane axis on K / R / N.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against kernels.ref via pytest and
the real-TPU resource budget is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _forces_kernel(
    pos_ref,      # [S, 2]   full shard positions (replicated per grid step)
    nbr_idx_ref,  # [B, K]   i32
    nbr_w_ref,    # [B, K]   f32
    neg_idx_ref,  # [B, N]   i32
    neg_w_ref,    # [1]      f32
    means_ref,    # [R, 2]   f32 (replicated)
    mean_w_ref,   # [R]      f32 (replicated)
    valid_ref,    # [B]      f32
    head_ref,     # out [B, 2]
    tail_ref,     # out [B, K, 2]
    negtail_ref,  # out [B, N, 2]
    loss_ref,     # out [B]
):
    pos = pos_ref[...]
    nbr_idx = nbr_idx_ref[...]
    w = nbr_w_ref[...] * valid_ref[...][:, None]
    neg_idx = neg_idx_ref[...]
    neg_w = neg_w_ref[0]
    means = means_ref[...]
    mean_w = mean_w_ref[...]

    i = pl.program_id(0)
    b = nbr_idx.shape[0]
    pi = jax.lax.dynamic_slice(pos, (i * b, 0), (b, 2))   # [B,2] head tile

    # -- attractive edges ---------------------------------------------------
    pn = jnp.take(pos, nbr_idx, axis=0)                   # [B,K,2]
    delta_j = pi[:, None, :] - pn
    q_ij = 1.0 / (1.0 + jnp.sum(delta_j * delta_j, -1))   # [B,K]

    # -- mean negatives -----------------------------------------------------
    dm = pi[:, None, :] - means[None, :, :]               # [B,R,2]
    q_ir = 1.0 / (1.0 + jnp.sum(dm * dm, -1))             # [B,R]

    # -- exact negatives ----------------------------------------------------
    pneg = jnp.take(pos, neg_idx, axis=0)                 # [B,N,2]
    delta_n = pi[:, None, :] - pneg
    q_in = 1.0 / (1.0 + jnp.sum(delta_n * delta_n, -1))   # [B,N]

    a = jnp.sum(mean_w[None, :] * q_ir, -1) + neg_w * jnp.sum(q_in, -1)
    z = q_ij + a[:, None]

    loss_ref[...] = -jnp.sum(w * (jnp.log(q_ij) - jnp.log(z)), -1)

    c_att = 2.0 * w * q_ij * (1.0 - q_ij / z)
    att_i = jnp.sum(c_att[:, :, None] * delta_j, 1)
    tail_ref[...] = -c_att[:, :, None] * delta_j

    s = jnp.sum(w / z, -1)

    c_mr = 2.0 * s[:, None] * mean_w[None, :] * (q_ir * q_ir)
    rep_means = jnp.sum(c_mr[:, :, None] * dm, 1)

    c_nr = 2.0 * s[:, None] * neg_w * (q_in * q_in)
    rep_negs = jnp.sum(c_nr[:, :, None] * delta_n, 1)
    negtail_ref[...] = c_nr[:, :, None] * delta_n

    head_ref[...] = att_i - rep_means - rep_negs


@functools.partial(jax.jit, static_argnames=("block",))
def nomad_forces(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, *, block=256):
    """Pallas-tiled NOMAD force computation.

    Same contract as ``ref.nomad_forces_ref`` (see there for shapes).  The
    head axis S must be divisible by ``block``; callers pad shards to bucket
    sizes so this always holds.
    """
    s, k = nbr_idx.shape
    n = neg_idx.shape[1]
    r = means.shape[0]
    assert s % block == 0, (s, block)
    grid = (s // block,)
    b = block

    return pl.pallas_call(
        _forces_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, 2), lambda i: (0, 0)),        # pos: full
            pl.BlockSpec((b, k), lambda i: (i, 0)),        # nbr_idx
            pl.BlockSpec((b, k), lambda i: (i, 0)),        # nbr_w
            pl.BlockSpec((b, n), lambda i: (i, 0)),        # neg_idx
            pl.BlockSpec((1,), lambda i: (0,)),            # neg_w
            pl.BlockSpec((r, 2), lambda i: (0, 0)),        # means: full
            pl.BlockSpec((r,), lambda i: (0,)),            # mean_w: full
            pl.BlockSpec((b,), lambda i: (i,)),            # valid
        ],
        out_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b, k, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((b, n, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, 2), jnp.float32),
            jax.ShapeDtypeStruct((s, k, 2), jnp.float32),
            jax.ShapeDtypeStruct((s, n, 2), jnp.float32),
            jax.ShapeDtypeStruct((s,), jnp.float32),
        ],
        interpret=True,
    )(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)
