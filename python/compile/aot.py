"""AOT lowering: JAX (L2) -> HLO text artifacts + manifest for the Rust runtime.

Interchange format is HLO *text*, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
The Makefile invokes this once; the Rust binary is self-contained afterwards.

Artifacts are generated per shape *bucket* (shards are padded up to the next
bucket).  ``manifest.json`` records every artifact's function, bucket
parameters and input/output signature; the Rust runtime
(rust/src/runtime/artifact.rs) parses it with the from-scratch JSON parser
and picks buckets at run time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default bucket grids.  Kept deliberately small so `make artifacts` stays
# fast; the Rust runtime falls back to its native implementation for any
# shape without an artifact, so adding buckets is purely a perf knob.
STEP_BUCKETS = [512, 1024, 2048, 4096, 8192]
KMEANS_BUCKETS = [2048, 8192]
KNN_BUCKETS = [512, 2048]
DIMS = [32, 64, 256]
K_NBRS = 15
N_NEGS = 8
R_MEANS = 256
STEP_BLOCK = 256
ASSIGN_BLOCK = 512
KNN_BLOCK = 256
C_CENTROIDS = 256


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args, outs):
    def one(x):
        return {"shape": list(x.shape), "dtype": str(x.dtype)}

    return [one(a) for a in args], [one(o) for o in outs]


def lower_nomad_step(s, k, n, r, block):
    f32, i32 = jnp.float32, jnp.int32
    args = [
        jax.ShapeDtypeStruct((s, 2), f32),     # pos
        jax.ShapeDtypeStruct((s, k), i32),     # nbr_idx
        jax.ShapeDtypeStruct((s, k), f32),     # nbr_w
        jax.ShapeDtypeStruct((s, n), i32),     # neg_idx
        jax.ShapeDtypeStruct((1,), f32),       # neg_w
        jax.ShapeDtypeStruct((r, 2), f32),     # means
        jax.ShapeDtypeStruct((r,), f32),       # mean_w
        jax.ShapeDtypeStruct((s,), f32),       # valid
        jax.ShapeDtypeStruct((), f32),         # lr
    ]
    fn = lambda *a: model.nomad_step(*a, block=block)
    lowered = jax.jit(fn).lower(*args)
    outs = [jax.ShapeDtypeStruct((s, 2), f32), jax.ShapeDtypeStruct((), f32)]
    ins, os_ = _sig(args, outs)
    return lowered, {
        "fn": "nomad_step",
        "params": {"s": s, "k": k, "neg": n, "r": r, "block": block},
        "inputs": ins,
        "outputs": os_,
    }


def lower_kmeans_em(n, d, c, block):
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((n, d), f32),
        jax.ShapeDtypeStruct((c, d), f32),
        jax.ShapeDtypeStruct((c,), f32),
    ]
    fn = lambda *a: model.kmeans_em_step(*a, block=block)
    lowered = jax.jit(fn).lower(*args)
    outs = [
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((c, d), f32),
        jax.ShapeDtypeStruct((c,), f32),
    ]
    ins, os_ = _sig(args, outs)
    return lowered, {
        "fn": "kmeans_em_step",
        "params": {"n": n, "d": d, "c": c, "block": block},
        "inputs": ins,
        "outputs": os_,
    }


def lower_knn(n, d, k, block):
    f32 = jnp.float32
    args = [
        jax.ShapeDtypeStruct((n, d), f32),
        jax.ShapeDtypeStruct((n,), f32),
    ]
    fn = lambda *a: model.knn_build(*a, k=k, block=block)
    lowered = jax.jit(fn).lower(*args)
    outs = [
        jax.ShapeDtypeStruct((n, k), jnp.int32),
        jax.ShapeDtypeStruct((n, k), f32),
    ]
    ins, os_ = _sig(args, outs)
    return lowered, {
        "fn": "knn_build",
        "params": {"n": n, "d": d, "k": k, "block": block},
        "inputs": ins,
        "outputs": os_,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--step-buckets", type=int, nargs="*", default=STEP_BUCKETS)
    ap.add_argument("--kmeans-buckets", type=int, nargs="*", default=KMEANS_BUCKETS)
    ap.add_argument("--knn-buckets", type=int, nargs="*", default=KNN_BUCKETS)
    ap.add_argument("--dims", type=int, nargs="*", default=DIMS)
    ap.add_argument("--k", type=int, default=K_NBRS)
    ap.add_argument("--negs", type=int, default=N_NEGS)
    ap.add_argument("--r", type=int, default=R_MEANS)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    entries = []

    def emit(name, lowered, meta):
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta["name"] = name
        meta["file"] = f"{name}.hlo.txt"
        entries.append(meta)
        print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")

    for s in args.step_buckets:
        block = min(STEP_BLOCK, s)
        name = f"nomad_step_s{s}_k{args.k}_n{args.negs}_r{args.r}"
        print(f"lowering {name} ...")
        lowered, meta = lower_nomad_step(s, args.k, args.negs, args.r, block)
        emit(name, lowered, meta)

    for n in args.kmeans_buckets:
        for d in args.dims:
            block = min(ASSIGN_BLOCK, n)
            name = f"kmeans_em_n{n}_d{d}_c{C_CENTROIDS}"
            print(f"lowering {name} ...")
            lowered, meta = lower_kmeans_em(n, d, C_CENTROIDS, block)
            emit(name, lowered, meta)

    for n in args.knn_buckets:
        for d in args.dims:
            block = min(KNN_BLOCK, n)
            name = f"knn_n{n}_d{d}_k{args.k}"
            print(f"lowering {name} ...")
            lowered, meta = lower_knn(n, d, args.k, block)
            emit(name, lowered, meta)

    manifest = {
        "version": 1,
        "defaults": {"k": args.k, "negs": args.negs, "r": args.r, "c": C_CENTROIDS},
        "artifacts": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
