//! Closed-loop load generator for the map serving subsystem (ISSUE 4's
//! acceptance gauge): start `serve::http` on loopback, drive a zoom/pan
//! request mix (tiles / kNN queries / stats) from N concurrent clients,
//! and report client-observed p50/p99 latency and tiles/sec — with the
//! tile cache enabled vs disabled.
//!
//!   cargo bench --bench serve_load                  # full 100k-point run
//!   cargo bench --bench serve_load -- --smoke       # CI-sized
//!   cargo bench --bench serve_load -- --n 500000 --requests 20000
//!
//! Emits `bench_results/BENCH_serve_load.json`.  In `--smoke` mode the
//! run is also a gate: it exits nonzero unless tiles were served, every
//! tile body carried valid PNG magic, and no request failed.

use nomad::bench::jsonx::{num, obj, s, Json};
use nomad::bench::save_bench_json;
use nomad::cli::Args;
use nomad::data::gaussian_mixture;
use nomad::serve::artifact::{MapArtifact, Provenance};
use nomad::serve::http::{self, http_get};
use nomad::serve::{ServeConfig, TileConfig};
use nomad::util::rng::Rng;
use nomad::util::stats::Summary;
use std::time::Instant;

const PNG_MAGIC: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n'];

struct LoadResult {
    lat: Summary,
    tiles: u64,
    queries: u64,
    stats_reqs: u64,
    bad_png: u64,
    failures: u64,
    wall_secs: f64,
    cache_hits: i64,
    cache_misses: i64,
    cache_evictions: i64,
}

fn run_load(
    art: MapArtifact,
    cfg: &ServeConfig,
    requests: usize,
    clients: usize,
    zmax: u32,
) -> LoadResult {
    let handle = http::start(art, cfg).expect("server starts");
    let addr = handle.addr.to_string();
    let per_client = requests.div_ceil(clients.max(1));

    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients.max(1) {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + c as u64);
            let (mut z, mut x, mut y) = (0u32, 0u32, 0u32);
            let mut lats = Vec::with_capacity(per_client);
            let (mut tiles, mut queries, mut stats_reqs) = (0u64, 0u64, 0u64);
            let (mut bad_png, mut failures) = (0u64, 0u64);
            for _ in 0..per_client {
                let roll = rng.f32();
                let path = if roll < 0.7 {
                    format!("/tiles/{z}/{x}/{y}.png")
                } else if roll < 0.9 {
                    format!(
                        "/query?x={:.3}&y={:.3}&k={}",
                        rng.normal() * 12.0,
                        rng.normal() * 12.0,
                        1 + rng.below(20)
                    )
                } else {
                    "/stats".to_string()
                };
                let t = Instant::now();
                match http_get(&addr, &path) {
                    Ok((200, body)) => {
                        lats.push(t.elapsed().as_secs_f64());
                        if roll < 0.7 {
                            tiles += 1;
                            if body.len() < 8 || body[..8] != PNG_MAGIC {
                                bad_png += 1;
                            }
                        } else if roll < 0.9 {
                            queries += 1;
                        } else {
                            stats_reqs += 1;
                        }
                    }
                    Ok((_, _)) | Err(_) => failures += 1,
                }
                if roll < 0.7 {
                    // zoom/pan walk over the pyramid
                    match rng.below(4) {
                        0 if z < zmax => {
                            z += 1;
                            x = x * 2 + rng.below(2) as u32;
                            y = y * 2 + rng.below(2) as u32;
                        }
                        1 if z > 0 => {
                            z -= 1;
                            x /= 2;
                            y /= 2;
                        }
                        _ => {
                            let side = 1u32 << z;
                            let step = |v: u32, r: &mut Rng| {
                                (v + side + if r.below(2) == 0 { 1 } else { side - 1 }) % side
                            };
                            x = step(x, &mut rng);
                            y = step(y, &mut rng);
                        }
                    }
                }
            }
            (lats, tiles, queries, stats_reqs, bad_png, failures)
        }));
    }

    let mut lats = Vec::new();
    let (mut tiles, mut queries, mut stats_reqs) = (0u64, 0u64, 0u64);
    let (mut bad_png, mut failures) = (0u64, 0u64);
    for j in joins {
        let (l, t, q, st, b, f) = j.join().expect("client thread");
        lats.extend(l);
        tiles += t;
        queries += q;
        stats_reqs += st;
        bad_png += b;
        failures += f;
    }
    let wall_secs = t0.elapsed().as_secs_f64();

    // server-side cache counters
    let (mut hits, mut misses, mut evictions) = (-1i64, -1i64, -1i64);
    if let Ok((200, body)) = http_get(&addr, "/stats") {
        if let Ok(v) = Json::parse(std::str::from_utf8(&body).unwrap_or("")) {
            hits = v.get("cache").get("hits").as_i64().unwrap_or(-1);
            misses = v.get("cache").get("misses").as_i64().unwrap_or(-1);
            evictions = v.get("cache").get("evictions").as_i64().unwrap_or(-1);
        }
    }
    handle.stop();

    LoadResult {
        lat: Summary::of(&lats),
        tiles,
        queries,
        stats_reqs,
        bad_png,
        failures,
        wall_secs,
        cache_hits: hits,
        cache_misses: misses,
        cache_evictions: evictions,
    }
}

fn result_json(r: &LoadResult) -> Json {
    obj(vec![
        ("p50_ms", num(r.lat.p50 * 1e3)),
        ("p99_ms", num(r.lat.p99 * 1e3)),
        ("mean_ms", num(r.lat.mean * 1e3)),
        ("tiles", num(r.tiles as f64)),
        ("queries", num(r.queries as f64)),
        ("stats_requests", num(r.stats_reqs as f64)),
        ("tiles_per_sec", num(r.tiles as f64 / r.wall_secs.max(1e-9))),
        ("requests_per_sec", num(r.lat.n as f64 / r.wall_secs.max(1e-9))),
        ("failures", num(r.failures as f64)),
        ("bad_png", num(r.bad_png as f64)),
        ("wall_secs", num(r.wall_secs)),
        ("cache_hits", num(r.cache_hits as f64)),
        ("cache_misses", num(r.cache_misses as f64)),
        ("cache_evictions", num(r.cache_evictions as f64)),
    ])
}

fn print_result(tag: &str, r: &LoadResult) {
    println!(
        "{tag:>10}: p50 {:.2}ms p99 {:.2}ms | {:.0} tiles/s ({} tiles, {} queries, {} stats) | \
         cache {}h/{}m | {} failures, {} bad png",
        r.lat.p50 * 1e3,
        r.lat.p99 * 1e3,
        r.tiles as f64 / r.wall_secs.max(1e-9),
        r.tiles,
        r.queries,
        r.stats_reqs,
        r.cache_hits,
        r.cache_misses,
        r.failures,
        r.bad_png,
    );
}

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let smoke = args.bool("smoke");
    let n = args.usize("n", if smoke { 5_000 } else { 100_000 });
    let requests = args.usize("requests", if smoke { 300 } else { 4_000 });
    let clients = args.usize("clients", if smoke { 4 } else { 8 });
    let workers = args.usize("workers", 8);
    let zmax = args.usize("zmax", 5) as u32;
    let tile_px = args.usize("tile-px", if smoke { 64 } else { 256 });

    // Synthetic finished map: a 2-D gaussian mixture *is* an embedding, so
    // the read path is benched without paying for a training run.
    let mut rng = Rng::new(7);
    let ds = gaussian_mixture(n, 2, 24, 12.0, 0.2, 0.5, &mut rng);
    let labels = ds.fine_labels().to_vec();
    let art = MapArtifact::from_run(
        ds.x.clone(),
        Some(labels),
        Provenance { dataset: "serve_load synthetic".into(), seed: 7, epochs: 0, final_loss: 0.0 },
    )
    .expect("artifact");

    let tile = TileConfig { tile_px, max_points: 20_000, ..Default::default() };
    let base = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        backlog: 128,
        cache_entries: 4096,
        tile,
    };
    println!(
        "serve_load: {n} points, {requests} requests, {clients} clients, {workers} workers, \
         zmax {zmax}, {tile_px}px tiles"
    );

    let on = run_load(art.clone(), &base, requests, clients, zmax);
    print_result("cache on", &on);
    let off_cfg = ServeConfig { cache_entries: 0, ..base };
    let off = run_load(art, &off_cfg, requests, clients, zmax);
    print_result("cache off", &off);

    save_bench_json(
        "serve_load",
        obj(vec![
            ("bench", s("serve_load")),
            ("n", num(n as f64)),
            ("requests", num(requests as f64)),
            ("clients", num(clients as f64)),
            ("workers", num(workers as f64)),
            ("tile_px", num(tile_px as f64)),
            ("zmax", num(zmax as f64)),
            ("smoke", Json::Bool(smoke)),
            ("cache_on", result_json(&on)),
            ("cache_off", result_json(&off)),
        ]),
    );

    if smoke {
        let ok = on.tiles > 0
            && off.tiles > 0
            && on.bad_png == 0
            && off.bad_png == 0
            && on.failures == 0
            && off.failures == 0;
        if !ok {
            eprintln!(
                "FAIL: smoke gate (tiles on/off {}/{}, bad_png {}/{}, failures {}/{})",
                on.tiles, off.tiles, on.bad_png, off.bad_png, on.failures, off.failures
            );
            std::process::exit(1);
        }
        println!("smoke gate OK: tiles served with valid PNG magic, zero failures");
    }
}
