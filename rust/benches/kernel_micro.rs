//! Layer-1/Layer-3 microbenchmarks: per-block NOMAD gradient latency for
//! the retired chunked **scatter** path vs the production **gather** force
//! engine (DESIGN.md §9) at 1 worker and the full thread budget — plus,
//! when built with the `xla` feature and AOT artifacts exist, the XLA
//! artifact path; and the ANN kernels (assignment, within-cluster kNN).
//! These drive the §Perf iteration log in EXPERIMENTS.md.
//!
//!   cargo bench --bench kernel_micro  [-- --runs 20]
//!
//! The "sc/ga" column is the acceptance gauge for the gather engine
//! (scatter-x1 time over gather-x1 time: the algorithmic win with no
//! threading in play); "x1/xN" shows the gather engine's thread scaling.
//! The JSON also records each engine's gradient working set —
//! O(size × n_chunks) for scatter, O(size) for gather.

use nomad::ann::backend::{assign_naive, knn_naive, AnnBackend, NativeBackend};
use nomad::ann::graph::{edge_weights, WeightModel};
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::bench::jsonx::{arr, num, obj, s, Json};
use nomad::bench::{fmt_secs, save_bench_json, time_fn, Table};
use nomad::cli::Args;
use nomad::data::gaussian_mixture;
use nomad::embed::native::{nomad_grad_gather, nomad_grad_scatter, HEAD_CHUNK};
use nomad::embed::ClusterBlock;
#[cfg(feature = "xla")]
use nomad::embed::{StepBackend, StepInputs};
use nomad::linalg::{simd, Matrix};
use nomad::util::rng::Rng;

fn block_of_size(
    target_real: usize,
    r: usize,
    seed: u64,
) -> (ClusterBlock, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let n = target_real + target_real / 8;
    let ds = gaussian_mixture(n, 16, 2, 50.0, 0.0, 0.0, &mut rng);
    // force one big cluster of ~target_real via params
    let idx = ClusterIndex::build(
        &ds.x,
        &IndexParams {
            n_clusters: 2,
            k: 15,
            max_cluster_size: 8192,
            ..Default::default()
        },
        &NativeBackend::default(),
        &mut rng,
    );
    let ew = edge_weights(&idx, WeightModel::InverseRankPaper);
    let init: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
    // pick the biggest cluster
    let c = (0..idx.n_clusters())
        .max_by_key(|&c| idx.clusters[c].len())
        .unwrap();
    let block = ClusterBlock::build(&idx, &ew, c, &init, n, 5.0, 8);
    let mean_x: Vec<f32> = (0..r).map(|_| rng.normal() * 5.0).collect();
    let mean_y: Vec<f32> = (0..r).map(|_| rng.normal() * 5.0).collect();
    let mean_w: Vec<f32> = (0..r).map(|_| 1.0).collect();
    (block, mean_x, mean_y, mean_w)
}

/// Time the two gradient engines on identical inputs (negatives resampled
/// once up front, so the comparison is kernel-only): the retired scatter
/// path at 1 worker, the gather engine at 1 and `threads` workers.
fn engine_times(
    block: &mut ClusterBlock,
    mean_x: &[f32],
    mean_y: &[f32],
    mean_w: &[f32],
    runs: usize,
    threads: usize,
) -> (f64, f64, f64) {
    let mut rng = Rng::new(2);
    block.resample_negatives(&mut rng);
    let b = &*block;
    let means_aos: Vec<f32> = mean_x.iter().zip(mean_y).flat_map(|(&x, &y)| [x, y]).collect();
    let t_scatter = time_fn(2, runs, || {
        std::hint::black_box(nomad_grad_scatter(
            &b.pos, &b.nbr_idx, &b.nbr_w, &b.neg_idx, b.neg_w, &means_aos, mean_w, &b.valid,
            b.k, b.negs, 1,
        ));
    })
    .mean;
    let gather = |t: usize| {
        time_fn(2, runs, || {
            std::hint::black_box(nomad_grad_gather(
                &b.pos, &b.nbr_idx, &b.nbr_w, &b.nbr_in, &b.neg_idx, &b.neg_in, b.neg_w,
                mean_x, mean_y, mean_w, &b.valid, b.k, b.negs, t,
            ));
        })
        .mean
    };
    (t_scatter, gather(1), gather(threads))
}

/// Gradient working-set bytes per engine: the scatter path allocates a
/// full `size x 2` accumulator **per head chunk** plus the reduced output;
/// the gather engine a fixed O(size) set (gradient + per-edge reaction
/// coefficients + per-head loss), independent of any chunk count.
fn grad_bytes(size: usize, k: usize, negs: usize) -> (f64, f64) {
    let n_chunks = size.div_ceil(HEAD_CHUNK);
    let scatter = (n_chunks * size * 2 + size * 2) * 4;
    let gather = (size * 2 + size * k + size * negs) * 4 + size * 8;
    (scatter as f64, gather as f64)
}

#[cfg(feature = "xla")]
fn xla_step_cells(
    block0: &ClusterBlock,
    mean_x: &[f32],
    mean_y: &[f32],
    mean_w: &[f32],
    runs: usize,
    t_native: f64,
) -> (String, String) {
    use nomad::runtime::XlaStepBackend;
    if !nomad::runtime::artifacts_dir().join("manifest.json").exists() {
        return ("n/a".into(), "-".into());
    }
    match XlaStepBackend::from_env() {
        Ok(x) => {
            let inputs = StepInputs { mean_x, mean_y, mean_w, lr: 0.5, threads: 1 };
            let mut b = block0.clone();
            let mut rng = Rng::new(2);
            let t = time_fn(2, runs, || {
                x.step(&mut b, &inputs, &mut rng);
            });
            (fmt_secs(t.mean), format!("{:.2}x", t.mean / t_native))
        }
        Err(_) => ("n/a".into(), "-".into()),
    }
}

#[cfg(not(feature = "xla"))]
fn xla_step_cells(
    _block0: &ClusterBlock,
    _mean_x: &[f32],
    _mean_y: &[f32],
    _mean_w: &[f32],
    _runs: usize,
    _t_native: f64,
) -> (String, String) {
    ("n/a".into(), "-".into())
}

#[cfg(feature = "xla")]
fn xla_ann_cells(x: &Matrix, cent: &Matrix, sub: &Matrix, runs: usize) -> (String, String) {
    use nomad::runtime::XlaAnnBackend;
    if !nomad::runtime::artifacts_dir().join("manifest.json").exists() {
        return ("n/a".into(), "n/a".into());
    }
    match XlaAnnBackend::from_env() {
        Ok(b) => {
            let t_assign = time_fn(1, runs, || {
                std::hint::black_box(b.assign(x, cent));
            });
            let t_knn = time_fn(1, runs, || {
                std::hint::black_box(b.knn(sub, 15));
            });
            (fmt_secs(t_assign.mean), fmt_secs(t_knn.mean))
        }
        Err(_) => ("n/a".into(), "n/a".into()),
    }
}

#[cfg(not(feature = "xla"))]
fn xla_ann_cells(_x: &Matrix, _cent: &Matrix, _sub: &Matrix, _runs: usize) -> (String, String) {
    ("n/a".into(), "n/a".into())
}

/// Seconds per kernel call, batched over `rows` row pairs so one timed
/// closure is long enough to measure.
fn time_rows(
    runs: usize,
    rows: usize,
    d: usize,
    a: &[f32],
    b: &[f32],
    f: &dyn Fn(&[f32], &[f32]) -> f32,
) -> f64 {
    let t = time_fn(2, runs, || {
        let mut acc = 0.0f32;
        for r in 0..rows {
            acc += f(&a[r * d..(r + 1) * d], &b[r * d..(r + 1) * d]);
        }
        std::hint::black_box(acc);
    });
    t.mean / rows as f64
}

/// NaN-aware bit equality — the dispatch contract compares payloads except
/// that any NaN matches any NaN (payload bits are not contractual).
fn bits_eq(x: f32, y: f32) -> bool {
    x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan())
}

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let runs = args.usize("runs", 15);
    let threads = nomad::util::parallel::num_threads();

    let par_header = format!("gather x{threads}");
    let mut table = Table::new(
        "L1/L3 microbench — per-block NOMAD gradient (scatter vs gather engine)",
        &[
            "Bucket (real pts)",
            "R",
            "scatter x1",
            "gather x1",
            par_header.as_str(),
            "sc/ga",
            "x1/xN",
            "xla",
            "xla/native",
        ],
    );

    let mut step_rows: Vec<Json> = Vec::new();
    for (target, r) in [(400usize, 64usize), (1500, 64), (1500, 255), (6000, 255)] {
        let (mut block0, mean_x, mean_y, mean_w) = block_of_size(target, r, 1);
        let (t_scatter, t_ga1, t_gan) =
            engine_times(&mut block0, &mean_x, &mean_y, &mean_w, runs, threads);
        // xla runs single-threaded per device, so its ratio is against the
        // 1-worker gather time (the production native engine)
        let (t_xla, ratio) = xla_step_cells(&block0, &mean_x, &mean_y, &mean_w, runs, t_ga1);
        let (sc_bytes, ga_bytes) = grad_bytes(block0.size, block0.k, block0.negs);
        table.row(vec![
            format!("{} (bucket {})", block0.n_real, block0.size).into(),
            format!("{r}").into(),
            fmt_secs(t_scatter).into(),
            fmt_secs(t_ga1).into(),
            fmt_secs(t_gan).into(),
            format!("{:.2}x", t_scatter / t_ga1.max(1e-12)).into(),
            format!("{:.2}x", t_ga1 / t_gan.max(1e-12)).into(),
            t_xla.into(),
            ratio.into(),
        ]);
        step_rows.push(obj(vec![
            ("shape", s(&format!("{}x{} r={r}", block0.n_real, block0.size))),
            ("scatter_x1_ns_per_op", num(t_scatter * 1e9)),
            ("gather_x1_ns_per_op", num(t_ga1 * 1e9)),
            ("gather_xn_ns_per_op", num(t_gan * 1e9)),
            ("speedup_scatter_over_gather_x1", num(t_scatter / t_ga1.max(1e-12))),
            ("speedup_gather_x1_over_xn", num(t_ga1 / t_gan.max(1e-12))),
            ("scatter_grad_bytes", num(sc_bytes)),
            ("gather_grad_bytes", num(ga_bytes)),
        ]));
    }
    table.print();
    table.save_json("kernel_micro_step");

    // ---- ANN kernels ------------------------------------------------------
    // both sides single-threaded so the speedup column isolates the
    // algorithmic win of the tiled engine; thread scaling is
    // bench/index_build's job
    let mut t2 = Table::new(
        "ANN microbench — assignment & within-cluster kNN (naive vs tiled, both x1)",
        &["Kernel", "Shape", "naive x1", "tiled x1", "speedup", "xla"],
    );
    let mut rng = Rng::new(3);
    let ds = gaussian_mixture(2000, 64, 8, 10.0, 0.2, 0.5, &mut rng);
    let mut cent = Matrix::zeros(64, 64);
    for v in cent.data.iter_mut() {
        *v = rng.normal();
    }
    let nb = NativeBackend::default();
    let sub = ds.x.gather(&(0..500).collect::<Vec<_>>());
    let (xla_assign, xla_knn) = xla_ann_cells(&ds.x, &cent, &sub, runs);
    let mut ann_rows: Vec<Json> = Vec::new();

    let t_assign_naive = time_fn(1, runs, || {
        std::hint::black_box(assign_naive(&ds.x, &cent));
    });
    let t_assign_n = time_fn(1, runs, || {
        std::hint::black_box(nomad::linalg::distance::assign_tiled(&ds.x, &cent, 1));
    });
    t2.row(vec![
        "kmeans assign".into(),
        "2000x64 vs 64".into(),
        fmt_secs(t_assign_naive.mean).into(),
        fmt_secs(t_assign_n.mean).into(),
        format!("{:.2}x", t_assign_naive.mean / t_assign_n.mean.max(1e-12)).into(),
        xla_assign.into(),
    ]);
    ann_rows.push(obj(vec![
        ("kernel", s("kmeans assign")),
        ("shape", s("2000x64 vs 64")),
        ("naive_ns_per_op", num(t_assign_naive.mean * 1e9)),
        ("tiled_x1_ns_per_op", num(t_assign_n.mean * 1e9)),
        ("speedup_naive_over_tiled_x1", num(t_assign_naive.mean / t_assign_n.mean.max(1e-12))),
    ]));

    let t_knn_naive = time_fn(1, runs, || {
        std::hint::black_box(knn_naive(&sub, 15));
    });
    let t_knn_n = time_fn(1, runs, || {
        std::hint::black_box(nb.knn_with_budget(&sub, 15, 1));
    });
    t2.row(vec![
        "within-cluster knn".into(),
        "500x64 k=15".into(),
        fmt_secs(t_knn_naive.mean).into(),
        fmt_secs(t_knn_n.mean).into(),
        format!("{:.2}x", t_knn_naive.mean / t_knn_n.mean.max(1e-12)).into(),
        xla_knn.into(),
    ]);
    ann_rows.push(obj(vec![
        ("kernel", s("within-cluster knn")),
        ("shape", s("500x64 k=15")),
        ("naive_ns_per_op", num(t_knn_naive.mean * 1e9)),
        ("tiled_x1_ns_per_op", num(t_knn_n.mean * 1e9)),
        ("speedup_naive_over_tiled_x1", num(t_knn_naive.mean / t_knn_n.mean.max(1e-12))),
    ]));
    t2.print();
    t2.save_json("kernel_micro_ann");

    // ---- SIMD kernels -----------------------------------------------------
    // the runtime-dispatched path vs the forced-scalar fallback on the
    // dot-bound kernels (DESIGN.md §16).  On hosts without AVX2 (or under
    // NOMAD_SIMD=scalar) both columns time the same code path and the
    // speedup reads ~1.0x.
    let mut t3 = Table::new(
        "SIMD microbench — dispatched vs scalar 8-lane kernels (both x1)",
        &["Kernel", "d", "scalar", "simd", "speedup"],
    );
    let mut simd_rows: Vec<Json> = Vec::new();
    let mut rng4 = Rng::new(4);
    for d in [64usize, 256, 1024] {
        let rows = 256usize;
        let a: Vec<f32> = (0..rows * d).map(|_| rng4.normal()).collect();
        let b: Vec<f32> = (0..rows * d).map(|_| rng4.normal()).collect();
        let kernels: [(&str, fn(&[f32], &[f32]) -> f32, fn(&[f32], &[f32]) -> f32); 2] =
            [("dot", simd::dot_scalar, simd::dot), ("d2", simd::d2_scalar, simd::d2)];
        for (kernel, scalar, dispatched) in kernels {
            let t_sc = time_rows(runs, rows, d, &a, &b, &scalar);
            let t_si = time_rows(runs, rows, d, &a, &b, &dispatched);
            t3.row(vec![
                kernel.into(),
                format!("{d}").into(),
                fmt_secs(t_sc).into(),
                fmt_secs(t_si).into(),
                format!("{:.2}x", t_sc / t_si.max(1e-18)).into(),
            ]);
            simd_rows.push(obj(vec![
                ("kernel", s(kernel)),
                ("d", num(d as f64)),
                ("scalar_ns_per_op", num(t_sc * 1e9)),
                ("simd_ns_per_op", num(t_si * 1e9)),
                ("speedup_scalar_over_simd", num(t_sc / t_si.max(1e-18))),
            ]));
        }
    }
    t3.print();
    t3.save_json("kernel_micro_simd");

    // scalar-vs-simd bitwise gate: the dispatch contract (DESIGN.md §16)
    // is bitwise identity, so any divergence fails the bench-smoke CI job.
    let mut rng5 = Rng::new(5);
    let mut gate_ok = true;
    for _ in 0..500 {
        let d = rng5.below(130);
        let a: Vec<f32> = (0..d).map(|_| rng5.normal()).collect();
        let b: Vec<f32> = (0..d).map(|_| rng5.normal()).collect();
        gate_ok &= bits_eq(simd::dot(&a, &b), simd::dot_scalar(&a, &b));
        gate_ok &= bits_eq(simd::d2(&a, &b), simd::d2_scalar(&a, &b));
    }
    if !gate_ok {
        eprintln!("FAIL: dispatched SIMD kernels diverge bitwise from the scalar fallback");
        std::process::exit(1);
    }
    println!("\nscalar-vs-simd bitwise gate: OK (simd_active = {})", simd::simd_active());

    save_bench_json(
        "kernel_micro",
        obj(vec![
            ("bench", s("kernel_micro")),
            ("threads", num(threads as f64)),
            ("runs", num(runs as f64)),
            ("simd_active", Json::Bool(simd::simd_active())),
            ("step", arr(step_rows)),
            ("ann", arr(ann_rows)),
            ("simd", arr(simd_rows)),
        ]),
    );
}
