//! Layer-1/Layer-3 microbenchmarks: per-block NOMAD step latency for the
//! native path vs the AOT XLA artifact, per bucket size, plus the ANN
//! kernels (assignment, within-cluster kNN).  These drive the §Perf
//! iteration log in EXPERIMENTS.md.
//!
//!   cargo bench --bench kernel_micro  [-- --runs 20]

use nomad::ann::backend::{AnnBackend, NativeBackend};
use nomad::ann::graph::{edge_weights, WeightModel};
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::bench::{fmt_secs, time_fn, Table};
use nomad::cli::Args;
use nomad::data::gaussian_mixture;
use nomad::embed::native::NativeStepBackend;
use nomad::embed::{ClusterBlock, StepBackend, StepInputs};
use nomad::linalg::Matrix;
use nomad::runtime::{XlaAnnBackend, XlaStepBackend};
use nomad::util::rng::Rng;

fn block_of_size(target_real: usize, r: usize, seed: u64) -> (ClusterBlock, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let n = target_real + target_real / 8;
    let ds = gaussian_mixture(n, 16, 2, 50.0, 0.0, 0.0, &mut rng);
    // force one big cluster of ~target_real via params
    let idx = ClusterIndex::build(
        &ds.x,
        &IndexParams {
            n_clusters: 2,
            k: 15,
            max_cluster_size: 8192,
            ..Default::default()
        },
        &NativeBackend::default(),
        &mut rng,
    );
    let ew = edge_weights(&idx, WeightModel::InverseRankPaper);
    let init: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
    // pick the biggest cluster
    let c = (0..idx.n_clusters())
        .max_by_key(|&c| idx.clusters[c].len())
        .unwrap();
    let block = ClusterBlock::build(&idx, &ew, c, &init, n, 5.0, 8);
    let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 5.0).collect();
    let mean_w: Vec<f32> = (0..r).map(|_| 1.0).collect();
    (block, means, mean_w)
}

fn main() {
    let args = Args::from_env();
    let runs = args.usize("runs", 15);
    let have_artifacts = nomad::runtime::artifacts_dir().join("manifest.json").exists();

    let mut table = Table::new(
        "L1/L3 microbench — per-block NOMAD step",
        &["Bucket (real pts)", "R", "native", "xla", "xla/native"],
    );
    let xla = if have_artifacts { XlaStepBackend::from_env().ok() } else { None };
    let native = NativeStepBackend::default();

    for (target, r) in [(400usize, 64usize), (1500, 64), (1500, 255), (6000, 255)] {
        let (block0, means, mean_w) = block_of_size(target, r, 1);
        let inputs = StepInputs { means: &means, mean_w: &mean_w, lr: 0.5 };
        let mut rng = Rng::new(2);

        let mut bn = block0.clone();
        let t_native = time_fn(2, runs, || {
            native.step(&mut bn, &inputs, &mut rng);
        });
        let (t_xla, ratio) = if let Some(x) = &xla {
            let mut bx = block0.clone();
            let mut rng2 = Rng::new(2);
            let t = time_fn(2, runs, || {
                x.step(&mut bx, &inputs, &mut rng2);
            });
            (fmt_secs(t.mean), format!("{:.2}x", t.mean / t_native.mean))
        } else {
            ("n/a".into(), "-".into())
        };
        table.row(vec![
            format!("{} (bucket {})", block0.n_real, block0.size).into(),
            format!("{r}").into(),
            fmt_secs(t_native.mean).into(),
            t_xla.into(),
            ratio.into(),
        ]);
    }
    table.print();
    table.save_json("kernel_micro_step");

    // ---- ANN kernels ------------------------------------------------------
    let mut t2 = Table::new(
        "ANN microbench — assignment & within-cluster kNN",
        &["Kernel", "Shape", "native", "xla"],
    );
    let mut rng = Rng::new(3);
    let ds = gaussian_mixture(2000, 64, 8, 10.0, 0.2, 0.5, &mut rng);
    let mut cent = Matrix::zeros(64, 64);
    for v in cent.data.iter_mut() {
        *v = rng.normal();
    }
    let nb = NativeBackend::default();
    let xab = if have_artifacts { XlaAnnBackend::from_env().ok() } else { None };

    let t_assign_n = time_fn(1, runs, || {
        std::hint::black_box(nb.assign(&ds.x, &cent));
    });
    let t_assign_x = xab
        .as_ref()
        .map(|x| time_fn(1, runs, || {
            std::hint::black_box(x.assign(&ds.x, &cent));
        }));
    t2.row(vec![
        "kmeans assign".into(),
        "2000x64 vs 64".into(),
        fmt_secs(t_assign_n.mean).into(),
        t_assign_x.map(|t| fmt_secs(t.mean)).unwrap_or("n/a".into()).into(),
    ]);

    let sub = ds.x.gather(&(0..500).collect::<Vec<_>>());
    let t_knn_n = time_fn(1, runs, || {
        std::hint::black_box(nb.knn(&sub, 15));
    });
    let t_knn_x = xab.as_ref().map(|x| time_fn(1, runs, || {
        std::hint::black_box(x.knn(&sub, 15));
    }));
    t2.row(vec![
        "within-cluster knn".into(),
        "500x64 k=15".into(),
        fmt_secs(t_knn_n.mean).into(),
        t_knn_x.map(|t| fmt_secs(t.mean)).unwrap_or("n/a".into()).into(),
    ]);
    t2.print();
    t2.save_json("kernel_micro_ann");
}
