//! Distributed-placement bench: in-process device threads vs real
//! TCP-loopback `nomad worker` sessions over the same shard set.
//!
//! Reports, per configuration: wall time, **measured** wire bytes
//! (per-epoch mean/max from `CommStats::wire_epoch_bytes`), the modeled
//! all-gather volume, and the cost model's per-epoch time — so the modeled
//! communication story (DESIGN.md §3) can be checked against bytes that
//! actually crossed a socket.  Every row also carries the obs registry's
//! `nomad_wire_bytes_total` delta for the run, and the bench exits nonzero
//! if it drifts from `CommStats` — the registry and the run report are one
//! source of truth (DESIGN.md §15).  Exits nonzero unless the remote run's
//! final positions are **bitwise identical** to the in-process run with
//! the same seeds (the tentpole invariant of DESIGN.md §12).
//!
//!   cargo bench --bench distributed  [-- --n 6000 --epochs 30 | --smoke]

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::edge_weights;
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::bench::{fmt_secs, jsonx, save_bench_json, Table};
use nomad::checkpoint::DatasetSpec;
use nomad::cli::Args;
use nomad::coordinator::{NomadCoordinator, NomadRun, Placement, RunConfig};
use nomad::data::shard::{write_shards, ShardSet};
use nomad::data::text_corpus_like;
use nomad::distributed::comm_model;
use nomad::distributed::transport::Endpoint;
use nomad::distributed::worker::{serve_session, WorkerCfg, WorkerListener};
use nomad::embed::NomadParams;
use nomad::obs::metrics::{self, Value};
use nomad::util::rng::Rng;
use std::path::PathBuf;

const SEED: u64 = 42;
const CLUSTERS: usize = 16;

fn coordinator(n_epochs: usize, placement: Placement, n_devices: usize) -> NomadCoordinator {
    NomadCoordinator::new(
        NomadParams { epochs: n_epochs, seed: SEED, ..Default::default() },
        RunConfig {
            n_devices,
            index: IndexParams { n_clusters: CLUSTERS, ..Default::default() },
            placement,
            ..Default::default()
        },
    )
}

/// Host `n_workers` worker sessions on loopback TCP threads (real sockets,
/// real frames — the only thing CI's worker-smoke job adds is a process
/// boundary) and return their endpoints plus join handles.
fn spawn_workers(
    shard_dir: &PathBuf,
    n_workers: usize,
) -> (Vec<String>, Vec<std::thread::JoinHandle<()>>) {
    let mut endpoints = Vec::new();
    let mut joins = Vec::new();
    for _ in 0..n_workers {
        let shards = ShardSet::open(shard_dir).expect("open shard set");
        let listener =
            WorkerListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).expect("bind worker");
        endpoints.push(listener.local_addr_string());
        joins.push(std::thread::spawn(move || {
            let mut t = listener.accept_transport().expect("accept coordinator");
            serve_session(&mut *t, &shards, &WorkerCfg::default()).expect("worker session");
        }));
    }
    (endpoints, joins)
}

/// Sum of every series of a counter family in the global obs registry.
fn obs_counter_total(name: &str) -> u64 {
    match metrics::snapshot().families.get(name) {
        Some(fam) => fam
            .series
            .values()
            .map(|v| match v {
                Value::Counter(c) => *c,
                _ => 0,
            })
            .sum(),
        None => 0,
    }
}

/// Fail the bench if the obs registry's wire-byte delta for this run
/// drifts from the `CommStats` total — both must come from the same
/// transport accounting.
fn check_wire_source(placement: &str, obs_delta: u64, comm_total: u64) {
    if obs_delta != comm_total {
        eprintln!(
            "FAIL: {placement}: obs nomad_wire_bytes_total delta {obs_delta} != \
             CommStats wire_bytes_total {comm_total}"
        );
        std::process::exit(1);
    }
}

fn row_stats(run: &NomadRun) -> (u64, u64, f64) {
    let epochs = run.comm.wire_epoch_bytes.len().max(1) as u64;
    let mean = run.comm.wire_bytes_total / epochs;
    let max = run.comm.wire_epoch_bytes.iter().copied().max().unwrap_or(0);
    let hw = comm_model::HwProfile::h100();
    let modeled_epoch = comm_model::epoch_time(&hw, &run.last_epoch_work);
    (mean, max, modeled_epoch)
}

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let smoke = args.bool("smoke");
    let n = args.usize("n", if smoke { 2000 } else { 6000 });
    let epochs = args.usize("epochs", if smoke { 6 } else { 30 });

    let mut rng = Rng::new(0);
    let ds = text_corpus_like(n, &mut rng);

    // shard set (what `nomad shard` writes), in a scratch dir
    let shard_dir = std::env::temp_dir().join(format!("nomad_bench_shards_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&shard_dir);
    {
        let params = NomadParams { seed: SEED, ..Default::default() };
        let idxp = IndexParams { n_clusters: CLUSTERS, ..Default::default() };
        let mut irng = Rng::new(SEED);
        let index = ClusterIndex::build(&ds.x, &idxp, &NativeBackend::default(), &mut irng);
        let weights = edge_weights(&index, params.weight_model);
        let spec =
            DatasetSpec { kind: "synthetic".into(), source: "arxiv".into(), n, seed: 0 };
        write_shards(
            &shard_dir,
            &index,
            &weights,
            ds.dim(),
            SEED,
            params.weight_model,
            &idxp,
            &spec,
        )
        .expect("write shard set");
    }

    let mut table = Table::new(
        &format!("Distributed placements — {} (n={n}, {epochs} epochs)", ds.name),
        &[
            "Placement",
            "Devices",
            "Wall",
            "Wire bytes (total)",
            "Wire/epoch (mean)",
            "Wire/epoch (max)",
            "All-gather bytes",
            "Modeled epoch",
        ],
    );
    let mut rows = Vec::new();
    let mut reference: Option<Vec<f32>> = None;

    for devices in [1usize, 2, 4] {
        let wire_obs_before = obs_counter_total("nomad_wire_bytes_total");
        let coord = coordinator(epochs, Placement::InProcess, devices);
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        let run = coord.fit_resumable(n, &prep, None).expect("in-process run");
        let wire_obs = obs_counter_total("nomad_wire_bytes_total") - wire_obs_before;
        check_wire_source("in-process", wire_obs, run.comm.wire_bytes_total);
        let (mean, max, modeled) = row_stats(&run);
        table.row(vec![
            "in-process".into(),
            format!("{devices}").into(),
            fmt_secs(run.train_secs).into(),
            format!("{}", run.comm.wire_bytes_total).into(),
            format!("{mean}").into(),
            format!("{max}").into(),
            format!("{}", run.comm.allgather_bytes_total).into(),
            fmt_secs(modeled).into(),
        ]);
        rows.push(jsonx::obj(vec![
            ("placement", jsonx::s("in-process")),
            ("devices", jsonx::num(devices as f64)),
            ("train_secs", jsonx::num(run.train_secs)),
            ("wire_bytes_total", jsonx::num(run.comm.wire_bytes_total as f64)),
            ("wire_bytes_obs", jsonx::num(wire_obs as f64)),
            ("wire_epoch_mean", jsonx::num(mean as f64)),
            ("wire_epoch_max", jsonx::num(max as f64)),
            ("allgather_bytes", jsonx::num(run.comm.allgather_bytes_total as f64)),
            ("modeled_epoch_secs", jsonx::num(modeled)),
        ]));
        if devices == 2 {
            reference = Some(run.positions.data.clone());
        }
    }

    // the same 2-device run, but over real loopback TCP worker sessions
    let wire_obs_before = obs_counter_total("nomad_wire_bytes_total");
    let (endpoints, joins) = spawn_workers(&shard_dir, 2);
    let coord = coordinator(
        epochs,
        Placement::Remote { endpoints, shards: shard_dir.clone() },
        2,
    );
    let prep = coord.prepare(&ds.x, &NativeBackend::default());
    let run = coord.fit_resumable(n, &prep, None).expect("tcp-workers run");
    for j in joins {
        j.join().expect("worker thread");
    }
    let wire_obs = obs_counter_total("nomad_wire_bytes_total") - wire_obs_before;
    check_wire_source("tcp-workers", wire_obs, run.comm.wire_bytes_total);
    let (mean, max, modeled) = row_stats(&run);
    table.row(vec![
        "tcp-workers".into(),
        "2".into(),
        fmt_secs(run.train_secs).into(),
        format!("{}", run.comm.wire_bytes_total).into(),
        format!("{mean}").into(),
        format!("{max}").into(),
        format!("{}", run.comm.allgather_bytes_total).into(),
        fmt_secs(modeled).into(),
    ]);
    rows.push(jsonx::obj(vec![
        ("placement", jsonx::s("tcp-workers")),
        ("devices", jsonx::num(2.0)),
        ("train_secs", jsonx::num(run.train_secs)),
        ("wire_bytes_total", jsonx::num(run.comm.wire_bytes_total as f64)),
        ("wire_bytes_obs", jsonx::num(wire_obs as f64)),
        ("wire_epoch_mean", jsonx::num(mean as f64)),
        ("wire_epoch_max", jsonx::num(max as f64)),
        ("allgather_bytes", jsonx::num(run.comm.allgather_bytes_total as f64)),
        ("modeled_epoch_secs", jsonx::num(modeled)),
    ]));

    let identical = match &reference {
        Some(r) => {
            r.len() == run.positions.data.len()
                && r.iter()
                    .zip(&run.positions.data)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
        }
        None => false,
    };

    table.print();
    table.save_json("distributed");
    save_bench_json(
        "distributed",
        jsonx::obj(vec![
            ("bench", jsonx::s("distributed")),
            ("n", jsonx::num(n as f64)),
            ("epochs", jsonx::num(epochs as f64)),
            ("clusters", jsonx::num(CLUSTERS as f64)),
            ("rows", jsonx::arr(rows)),
            ("remote_bitwise_equal", jsonx::Json::Bool(identical)),
        ]),
    );
    let _ = std::fs::remove_dir_all(&shard_dir);

    println!(
        "\n2-device TCP-worker run vs in-process: positions bitwise {}",
        if identical { "IDENTICAL" } else { "DIFFERENT" }
    );
    if !identical {
        eprintln!("FAIL: remote placement diverged from in-process placement");
        std::process::exit(1);
    }
}
