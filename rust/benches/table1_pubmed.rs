//! Table 1 regenerator (bench-grade, multi-seed): see
//! examples/pubmed_table1.rs for the narrated version; this one runs the
//! row set with seed repetition and writes bench_results/table1.json.
//!
//!   cargo bench --bench table1_pubmed  [-- --n 8000 --seeds 3]

use nomad::ann::IndexParams;
use nomad::bench::{fmt_pct, fmt_secs, log_experiment, Table};
use nomad::bench::jsonx::*;
use nomad::cli::Args;
use nomad::coordinator::BackendKind;
use nomad::data::pubmed_like;
use nomad::harness::{run_method, EvalCfg, Method};
use nomad::util::rng::Rng;
use nomad::util::stats::Summary;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 8000);
    let seeds = args.u64("seeds", 3);
    let epochs = args.usize("epochs", 100);

    let mut rng = Rng::new(0);
    let ds = pubmed_like(n, &mut rng);
    let index = IndexParams { n_clusters: 48, ..Default::default() };
    let eval_cfg = EvalCfg { np_sample: 250, triplets: 8000, ..Default::default() };

    let mut table = Table::new(
        &format!("Table 1 — PubMed-like (n={n})"),
        &["Method", "NP@10", "Wall", "Modeled-8xH100", "Speedup vs OpenTSNE"],
    );

    let mut reference_time = 0.0;
    for (mi, method) in [
        Method::OpenTsneLike,
        Method::Nomad { devices: 8, backend: BackendKind::Xla },
        Method::Nomad { devices: 8, backend: BackendKind::Native },
        Method::UmapLike,
        Method::TsneCudaLike,
    ]
    .iter()
    .enumerate()
    {
        let mut nps = Vec::new();
        let mut walls = Vec::new();
        let mut modeled = Vec::new();
        let reps = if matches!(method, Method::Nomad { .. }) { seeds } else { 1 };
        for seed in 0..reps {
            let e = if matches!(method, Method::OpenTsneLike) { epochs * 2 } else { epochs };
            let r = run_method(&ds, method, e, 0, &index, &eval_cfg, seed);
            nps.push(r.quality[0].np_at_10);
            walls.push(r.total_secs);
            modeled.push(r.modeled_secs);
        }
        let np = Summary::of(&nps);
        let wall = Summary::of(&walls).mean;
        let modeled_t = Summary::of(&modeled).mean;
        if mi == 0 {
            reference_time = wall;
        }
        let is_nomad = matches!(method, Method::Nomad { .. });
        table.row(vec![
            method.name().into(),
            fmt_pct(np.mean, np.sem()).into(),
            fmt_secs(wall).into(),
            if is_nomad { fmt_secs(modeled_t).into() } else { "-".into() },
            if mi == 0 {
                "1x".into()
            } else if is_nomad {
                format!("{:.1}x (modeled)", reference_time / modeled_t.max(1e-9)).into()
            } else {
                "-".into()
            },
        ]);
        log_experiment(
            "table1",
            obj(vec![
                ("method", s(&method.name())),
                ("np10_mean", num(np.mean)),
                ("np10_sem", num(np.sem())),
                ("wall_secs", num(wall)),
                ("modeled_secs", num(modeled_t)),
            ]),
        );
    }
    table.print();
    table.save_json("table1_pubmed");
    println!("\n(paper: NOMAD NP@10 parity with OpenTSNE at 5.4x speedup on 8xH100)");
}
