//! Figure 3 regenerator: NP@10 and random-triplet-accuracy vs wall-clock
//! for NOMAD Projection (1 and 4 simulated devices) against the GPU
//! baseline stand-ins, on the ArXiv-like and ImageNet-like corpora.
//!
//! Paper shape to reproduce: (a) NOMAD reaches similar-or-better NP and
//! RTA when run long enough; (b) t-SNE-CUDA gets good NP *fast* but
//! plateaus and has weak RTA (no early exaggeration / PCA init);
//! (c) multi-device NOMAD improves speed & NP at slight RTA cost.
//!
//!   cargo bench --bench fig3_speed_quality  [-- --n 5000 --epochs 120]

use nomad::ann::IndexParams;
use nomad::bench::{fmt_secs, Table};
use nomad::cli::Args;
use nomad::coordinator::BackendKind;
use nomad::data;
use nomad::harness::{run_method, EvalCfg, Method};
use nomad::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 5000);
    let epochs = args.usize("epochs", 120);
    let ckpt = args.usize("ckpt", 30);

    let mut rng = Rng::new(3);
    let datasets = [
        data::text_corpus_like(n, &mut rng),
        data::image_corpus_like(n, &mut rng),
    ];
    let index = IndexParams { n_clusters: 32, ..Default::default() };
    let eval_cfg = EvalCfg { np_sample: 250, triplets: 8000, ..Default::default() };
    let methods = [
        Method::Nomad { devices: 1, backend: BackendKind::Native },
        Method::Nomad { devices: 4, backend: BackendKind::Native },
        Method::TsneCudaLike,
        Method::UmapLike,
    ];

    for ds in &datasets {
        let mut table = Table::new(
            &format!("Fig 3 — {} (n={}, d={})", ds.name, ds.n(), ds.dim()),
            &["Method", "Epoch", "Wall", "NP@10", "RTA"],
        );
        for m in &methods {
            let run = run_method(ds, m, epochs, ckpt, &index, &eval_cfg, 11);
            for cp in &run.quality {
                table.row(vec![
                    run.method.clone().into(),
                    format!("{}", cp.epoch).into(),
                    fmt_secs(cp.wall_secs).into(),
                    format!("{:.1}%", cp.np_at_10 * 100.0).into(),
                    format!("{:.1}%", cp.rta * 100.0).into(),
                ]);
            }
        }
        table.print();
        table.save_json(&format!("fig3_{}", ds.name));
    }
    println!("\nPaper-shape checks: NOMAD final NP/RTA >= baselines'; tSNE-CUDA-like RTA lowest.");
}
