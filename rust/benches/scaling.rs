//! Device-scaling bench: per-epoch step time, all-gather volume, modeled
//! H100-node speedup and measured 1-core wall time for 1..8 devices, plus
//! the cost-model sanity row the paper's Fig 2 narrative implies
//! (positive phase: zero bytes).
//!
//!   cargo bench --bench scaling  [-- --n 8000 --epochs 40]

use nomad::ann::backend::NativeBackend;
use nomad::ann::IndexParams;
use nomad::bench::{fmt_secs, Table};
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::text_corpus_like;
use nomad::embed::NomadParams;
use nomad::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 8000);
    let epochs = args.usize("epochs", 40);

    let mut rng = Rng::new(5);
    let ds = text_corpus_like(n, &mut rng);

    let mut table = Table::new(
        &format!("Scaling — {} (n={n}, {} epochs)", ds.name, epochs),
        &[
            "Devices",
            "Wall",
            "Max-dev step (total)",
            "Step speedup",
            "Modeled@24M/epoch",
            "Modeled speedup@24M",
            "All-gather bytes",
            "Pos-phase bytes",
        ],
    );
    // extrapolate the cost model to the paper's PubMed scale (24M points)
    let paper_scale = 24.0e6 / n as f64;
    let hw = nomad::distributed::comm_model::HwProfile::h100();
    let mut base_step = None;
    let mut base_modeled = None;
    for devices in [1usize, 2, 4, 8] {
        let coord = NomadCoordinator::new(
            NomadParams { epochs, ..Default::default() },
            RunConfig {
                n_devices: devices,
                backend: BackendKind::Native,
                index: IndexParams { n_clusters: 64, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        let max_dev = run
            .device_step_secs
            .iter()
            .cloned()
            .fold(0.0f64, f64::max);
        let modeled_24m = nomad::distributed::comm_model::epoch_time_scaled(
            &hw,
            &run.last_epoch_work,
            paper_scale,
        );
        let bs = *base_step.get_or_insert(max_dev);
        let bm = *base_modeled.get_or_insert(modeled_24m);
        table.row(vec![
            format!("{devices}").into(),
            fmt_secs(run.train_secs).into(),
            fmt_secs(max_dev).into(),
            format!("{:.2}x", bs / max_dev.max(1e-12)).into(),
            fmt_secs(modeled_24m).into(),
            format!("{:.2}x", bm / modeled_24m.max(1e-12)).into(),
            format!("{}", run.comm.allgather_bytes_total).into(),
            format!("{}", run.comm.positive_phase_bytes_total).into(),
        ]);
    }
    table.print();
    table.save_json("scaling");
    println!("\n(expected shape: near-linear step/modeled speedup; positive-phase bytes identically 0)");
}
