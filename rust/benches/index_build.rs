//! Index-build benchmark: the tiled norm-trick distance engine vs the
//! naive pointwise scans, across the build kernels (K-Means assignment,
//! within-cluster kNN) and the end-to-end `ClusterIndex::build`, plus a
//! bitwise determinism check across 1/2/4 worker threads — the acceptance
//! gauge for the tiled engine (ISSUE 2).
//!
//!   cargo bench --bench index_build                 # full 20k x 64 run
//!   cargo bench --bench index_build -- --smoke      # CI-sized (2k x 32)
//!   cargo bench --bench index_build -- --n 50000 --d 128 --runs 5
//!
//! Emits `bench_results/BENCH_index_build.json`: shapes, naive vs tiled
//! ns/op, naive/tiled and 1-vs-N speedups, the f32-vs-int8 comparison for
//! the `--quantize-build` scan, the determinism verdict, and the
//! quantized-equality verdict (both gates exit nonzero on failure).

use nomad::ann::backend::{assign_naive, NativeBackend};
use nomad::ann::knn::{within_clusters, within_clusters_naive};
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::bench::jsonx::{arr, num, obj, s, Json};
use nomad::bench::{fmt_secs, save_bench_json, time_fn, Table};
use nomad::cli::Args;
use nomad::data::gaussian_mixture;
use nomad::linalg::distance::{assign_tiled, self_knn_tiled};
use nomad::linalg::{quant, Matrix};
use nomad::util::rng::Rng;
use std::hint::black_box;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let smoke = args.bool("smoke");
    let n = args.usize("n", if smoke { 2_000 } else { 20_000 });
    let d = args.usize("d", if smoke { 32 } else { 64 });
    let n_clusters = args.usize("clusters", 32);
    let k = args.usize("k", 15);
    let runs = args.usize("runs", if smoke { 1 } else { 3 });
    let threads = nomad::util::parallel::num_threads();

    let mut rng = Rng::new(7);
    let ds = gaussian_mixture(n, d, 16, 12.0, 0.2, 0.5, &mut rng);
    let mut cent = Matrix::zeros(n_clusters, d);
    for c in 0..n_clusters {
        let r = rng.below(n);
        cent.row_mut(c).copy_from_slice(ds.x.row(r));
    }

    let be = NativeBackend::default();
    let par_header = format!("tiled x{threads}");
    let mut table = Table::new(
        &format!("index build — naive vs tiled engine ({n} x {d}, {n_clusters} clusters, k={k})"),
        &["Kernel", "Shape", "naive x1", "tiled x1", par_header.as_str(), "naive/tiled", "x1/xN"],
    );
    let mut rows_json: Vec<Json> = Vec::new();
    let push = |table: &mut Table,
                    rows_json: &mut Vec<Json>,
                    kernel: &str,
                    shape: String,
                    t_naive: f64,
                    t_tiled1: f64,
                    t_tiledn: f64| {
        table.row(vec![
            kernel.into(),
            shape.clone().into(),
            fmt_secs(t_naive).into(),
            fmt_secs(t_tiled1).into(),
            fmt_secs(t_tiledn).into(),
            format!("{:.2}x", t_naive / t_tiled1.max(1e-12)).into(),
            format!("{:.2}x", t_tiled1 / t_tiledn.max(1e-12)).into(),
        ]);
        rows_json.push(obj(vec![
            ("kernel", s(kernel)),
            ("shape", s(&shape)),
            ("naive_ns_per_op", num(t_naive * 1e9)),
            ("tiled_x1_ns_per_op", num(t_tiled1 * 1e9)),
            ("tiled_xn_ns_per_op", num(t_tiledn * 1e9)),
            ("speedup_naive_over_tiled_x1", num(t_naive / t_tiled1.max(1e-12))),
            ("speedup_x1_over_xn", num(t_tiled1 / t_tiledn.max(1e-12))),
        ]));
    };

    // ---- K-Means assignment ---------------------------------------------
    let t_a_naive = time_fn(0, runs, || {
        black_box(assign_naive(&ds.x, &cent));
    })
    .mean;
    let t_a_tiled1 = time_fn(0, runs, || {
        black_box(assign_tiled(&ds.x, &cent, 1));
    })
    .mean;
    let t_a_tiledn = time_fn(0, runs, || {
        black_box(assign_tiled(&ds.x, &cent, threads));
    })
    .mean;
    push(
        &mut table,
        &mut rows_json,
        "kmeans assign",
        format!("{n}x{d} vs {n_clusters}"),
        t_a_naive,
        t_a_tiled1,
        t_a_tiledn,
    );

    // ---- within-cluster kNN ---------------------------------------------
    // cluster once with the tiled path, then time only the kNN stage
    let params = IndexParams { n_clusters, k, ..Default::default() };
    let km = nomad::ann::kmeans::run(&ds.x, &params, &be, &mut rng);
    let sizes: Vec<usize> = km.clusters.iter().map(|c| c.len()).collect();
    let biggest = sizes.iter().copied().max().unwrap_or(0);
    let t_k_naive = time_fn(0, runs, || {
        black_box(within_clusters_naive(&ds.x, &km.clusters, k));
    })
    .mean;
    let t_k_tiled1 = {
        std::env::set_var("NOMAD_THREADS", "1");
        let t = time_fn(0, runs, || {
            black_box(within_clusters(&ds.x, &km.clusters, k, &be));
        })
        .mean;
        std::env::set_var("NOMAD_THREADS", threads.to_string());
        t
    };
    let t_k_tiledn = time_fn(0, runs, || {
        black_box(within_clusters(&ds.x, &km.clusters, k, &be));
    })
    .mean;
    push(
        &mut table,
        &mut rows_json,
        "within-cluster knn",
        format!("{} clusters (max {biggest}) k={k}", km.clusters.len()),
        t_k_naive,
        t_k_tiled1,
        t_k_tiledn,
    );

    // ---- end-to-end index build (tiled only at full scale) ---------------
    let t_build = time_fn(0, runs, || {
        let mut r = Rng::new(11);
        black_box(ClusterIndex::build(&ds.x, &params, &be, &mut r));
    })
    .mean;
    table.row(vec![
        "full index build".into(),
        format!("{n}x{d}").into(),
        "-".into(),
        "-".into(),
        fmt_secs(t_build).into(),
        "-".into(),
        "-".into(),
    ]);
    rows_json.push(obj(vec![
        ("kernel", s("full index build")),
        ("shape", s(&format!("{n}x{d}"))),
        ("tiled_xn_ns_per_op", num(t_build * 1e9)),
    ]));

    // ---- f32 vs int8-screened kNN (the --quantize-build scan) ------------
    // timed on the biggest cluster; the quantized path screens candidates
    // with an i32 code dot and reranks survivors with the exact f32 kernel,
    // so its output is bitwise equal (gated below) and the column is a pure
    // throughput comparison
    let sub = {
        let big = (0..km.clusters.len()).max_by_key(|&c| km.clusters[c].len()).unwrap();
        let ids: Vec<usize> = km.clusters[big].iter().map(|&m| m as usize).collect();
        ds.x.gather(&ids)
    };
    let t_q_f32 = time_fn(0, runs, || {
        black_box(self_knn_tiled(&sub, k, threads));
    })
    .mean;
    let t_q_int8 = time_fn(0, runs, || {
        black_box(quant::self_knn_quantized(&sub, k, threads));
    })
    .mean;
    table.row(vec![
        "knn quantized".into(),
        format!("{}x{d} k={k}", sub.rows).into(),
        "-".into(),
        "-".into(),
        fmt_secs(t_q_int8).into(),
        format!("f32 {}", fmt_secs(t_q_f32)).into(),
        format!("{:.2}x", t_q_f32 / t_q_int8.max(1e-12)).into(),
    ]);
    rows_json.push(obj(vec![
        ("kernel", s("knn quantized")),
        ("shape", s(&format!("{}x{d} k={k}", sub.rows))),
        ("f32_xn_ns_per_op", num(t_q_f32 * 1e9)),
        ("int8_xn_ns_per_op", num(t_q_int8 * 1e9)),
        ("speedup_f32_over_int8", num(t_q_f32 / t_q_int8.max(1e-12))),
    ]));
    let quant_equal = quant::quantized_matches_exact(&sub, k, threads);

    // ---- determinism: bitwise identical across 1/2/4 threads -------------
    let a1 = assign_tiled(&ds.x, &cent, 1);
    let det_assign = assign_tiled(&ds.x, &cent, 2) == a1 && assign_tiled(&ds.x, &cent, 4) == a1;
    let k1 = self_knn_tiled(&sub, k, 1);
    let det_knn = self_knn_tiled(&sub, k, 2) == k1 && self_knn_tiled(&sub, k, 4) == k1;
    let mut det_build = true;
    let mut first: Option<ClusterIndex> = None;
    for t in [1usize, 2, 4] {
        std::env::set_var("NOMAD_THREADS", t.to_string());
        let mut r = Rng::new(23);
        let idx = ClusterIndex::build(&ds.x, &params, &be, &mut r);
        if let Some(f) = &first {
            det_build &= idx.assign == f.assign
                && idx.nbr_idx == f.nbr_idx
                && idx.nbr_d2 == f.nbr_d2
                && idx.centroids.data == f.centroids.data;
        } else {
            first = Some(idx);
        }
    }
    std::env::set_var("NOMAD_THREADS", threads.to_string());
    let deterministic = det_assign && det_knn && det_build;

    table.print();
    println!(
        "\nbitwise identical across 1/2/4 threads: assign={det_assign} knn={det_knn} build={det_build}"
    );
    println!("quantized kNN bitwise equal to f32 engine: {quant_equal}");
    table.save_json("index_build");
    save_bench_json(
        "index_build",
        obj(vec![
            ("bench", s("index_build")),
            ("n", num(n as f64)),
            ("d", num(d as f64)),
            ("n_clusters", num(n_clusters as f64)),
            ("k", num(k as f64)),
            ("threads", num(threads as f64)),
            ("runs", num(runs as f64)),
            ("smoke", Json::Bool(smoke)),
            ("rows", arr(rows_json)),
            ("deterministic_across_threads", Json::Bool(deterministic)),
            ("quantized_bitwise_equal", Json::Bool(quant_equal)),
        ]),
    );
    if !deterministic {
        eprintln!("FAIL: tiled results changed with thread count");
        std::process::exit(1);
    }
    if !quant_equal {
        eprintln!("FAIL: int8-screened kNN diverged from the exact f32 engine");
        std::process::exit(1);
    }
}
