//! Ablations over the design choices DESIGN.md calls out:
//!  * p(j|i) model: paper reverse-rank (Eq 6) vs forward-rank vs uniform;
//!  * R̃ selection: cluster-mean negatives vs exact negatives only
//!    (Theorem-1 surrogate vs plain InfoNC-t-SNE);
//!  * PCA vs random init (paper §3.4);
//!  * early exaggeration on/off.
//!
//!   cargo bench --bench ablations  [-- --n 4000 --epochs 80]

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::WeightModel;
use nomad::ann::IndexParams;
use nomad::bench::{fmt_secs, Table};
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::text_corpus_like;
use nomad::embed::{ApproxMode, NomadParams};
use nomad::harness::{evaluate, EvalCfg};
use nomad::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    args.apply_thread_flag();
    let n = args.usize("n", 4000);
    let epochs = args.usize("epochs", 80);

    let mut rng = Rng::new(7);
    let ds = text_corpus_like(n, &mut rng);
    let eval_cfg = EvalCfg { np_sample: 250, triplets: 8000, ..Default::default() };
    let index = IndexParams { n_clusters: 32, ..Default::default() };

    let mut table = Table::new(
        &format!("Ablations — {} (n={n}, {epochs} epochs, 2 devices)", ds.name),
        &["Variant", "NP@10", "RTA", "Wall"],
    );

    let base = NomadParams { epochs, ..Default::default() };
    let variants: Vec<(&str, NomadParams)> = vec![
        ("paper default (Eq6 + means + PCA)", base.clone()),
        (
            "p(j|i): forward rank",
            NomadParams { weight_model: WeightModel::InverseRankForward, ..base.clone() },
        ),
        (
            "p(j|i): uniform",
            NomadParams { weight_model: WeightModel::Uniform, ..base.clone() },
        ),
        (
            "negatives: exact only (InfoNC-t-SNE)",
            NomadParams { approx: ApproxMode::None, ..base.clone() },
        ),
        ("init: random", NomadParams { pca_init: false, ..base.clone() }),
        (
            "early exaggeration 4x/20ep",
            NomadParams { exaggeration: 4.0, exaggeration_epochs: 20, ..base.clone() },
        ),
    ];

    for (name, params) in variants {
        let coord = NomadCoordinator::new(
            params,
            RunConfig {
                n_devices: 2,
                backend: BackendKind::Native,
                index: index.clone(),
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        let (np, rta) = evaluate(&ds, &run.positions, &eval_cfg);
        table.row(vec![
            name.into(),
            format!("{:.1}%", np * 100.0).into(),
            format!("{:.1}%", rta * 100.0).into(),
            fmt_secs(run.train_secs).into(),
        ]);
    }
    table.print();
    table.save_json("ablations");
}
