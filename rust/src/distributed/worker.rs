//! The `nomad worker` process: one simulated GPU as a real OS process.
//!
//! A worker binds a listener (TCP or Unix socket), accepts the
//! coordinator, handshakes, receives its [`Assignment`], loads **only the
//! assigned clusters** from an mmap'd shard set ([`ShardSet`]) — never the
//! corpus, never the init matrix — and then hands the connection to the
//! exact same [`run_device_loop`] the in-process device threads run.
//! Positions arrive over the wire via `DeviceCmd::Ingest`, epochs are
//! driven by the coordinator's absolute-epoch broadcast, and the
//! `(device seed, epoch, block)` RNG forks are untouched — which is why a
//! multi-process run is bitwise identical to an in-process one
//! (`tests/multiprocess.rs`, and the CI worker-smoke job with real
//! processes).

use super::device::run_device_loop;
use super::proto::{Assignment, WireMsg};
use super::transport::{worker_handshake, Endpoint, FramedTransport, Transport};
use crate::data::shard::ShardSet;
use crate::embed::native::NativeStepBackend;
use crate::embed::ClusterBlock;
use crate::ensure;
use crate::util::error::{Context, Result};
use std::path::Path;

/// A bound worker listener, either flavor of [`Endpoint`].
pub enum WorkerListener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl WorkerListener {
    /// Bind `ep`.  A stale Unix socket file (a previous worker that died
    /// without cleanup) is removed first — bind would otherwise fail with
    /// `AddrInUse` forever.
    pub fn bind(ep: &Endpoint) -> Result<WorkerListener> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = std::net::TcpListener::bind(addr.as_str())
                    .with_context(|| format!("bind {addr}"))?;
                Ok(WorkerListener::Tcp(l))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = std::os::unix::net::UnixListener::bind(path)
                    .with_context(|| format!("bind unix:{}", path.display()))?;
                Ok(WorkerListener::Unix(l))
            }
        }
    }

    /// The bound address, in [`Endpoint::parse`] syntax.  For TCP this is
    /// the *resolved* address — bind to `127.0.0.1:0` and read the kernel's
    /// port choice back (how the loopback tests avoid port collisions).
    pub fn local_addr_string(&self) -> String {
        match self {
            WorkerListener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?:?".to_string()),
            #[cfg(unix)]
            WorkerListener::Unix(l) => match l.local_addr() {
                Ok(a) => match a.as_pathname() {
                    Some(p) => format!("unix:{}", p.display()),
                    None => "unix:?".to_string(),
                },
                Err(_) => "unix:?".to_string(),
            },
        }
    }

    /// Block until the coordinator dials in; returns the framed connection.
    pub fn accept_transport(&self) -> Result<Box<dyn Transport>> {
        match self {
            WorkerListener::Tcp(l) => {
                let (s, _) = l.accept().context("accept coordinator connection")?;
                let _ = s.set_nodelay(true);
                Ok(Box::new(FramedTransport::new(s)))
            }
            #[cfg(unix)]
            WorkerListener::Unix(l) => {
                let (s, _) = l.accept().context("accept coordinator connection")?;
                Ok(Box::new(FramedTransport::new(s)))
            }
        }
    }
}

/// Check the coordinator's assignment against the shard manifest before
/// loading anything: a coordinator driving a different dataset or seed
/// must fail loudly here, not produce a silently-wrong embedding.
fn validate_assignment(a: &Assignment, shards: &ShardSet) -> Result<()> {
    let m = &shards.manifest;
    ensure!(
        a.n_total == m.n,
        "assignment is for n={} points, shard set holds {}",
        a.n_total,
        m.n
    );
    ensure!(
        a.seed == m.seed,
        "assignment seed {} != shard set seed {} (different run)",
        a.seed,
        m.seed
    );
    for &c in &a.clusters {
        ensure!(
            (c as usize) < m.clusters.len(),
            "assigned cluster {c} out of range (shard set has {})",
            m.clusters.len()
        );
    }
    Ok(())
}

/// Serve one coordinator session over an accepted connection: handshake,
/// receive the assignment, load the assigned blocks from the shard set (in
/// assignment order — the block-index RNG forks depend on it), acknowledge
/// with block/point counts, then run the shared device loop to `Stop`.
pub fn serve_session(
    transport: &mut dyn Transport,
    shards: &ShardSet,
    verbose: bool,
) -> Result<()> {
    worker_handshake(transport)?;
    let a = match transport.recv()? {
        WireMsg::Assign(a) => a,
        other => crate::bail!("worker: expected an assignment, got {other:?}"),
    };
    validate_assignment(&a, shards)?;

    let mut blocks: Vec<ClusterBlock> = Vec::with_capacity(a.clusters.len());
    for &c in &a.clusters {
        blocks.push(shards.load_block(c as usize, a.n_total, a.m_noise, a.negs)?);
    }
    let n_points: usize = blocks.iter().map(|b| b.n_real).sum();
    if verbose {
        eprintln!(
            "worker: device {} assigned {} clusters / {} points",
            a.device,
            blocks.len(),
            n_points
        );
    }
    transport.send(WireMsg::Assigned {
        device: a.device,
        n_blocks: blocks.len(),
        n_points,
    })?;

    let backend = NativeStepBackend::default();
    run_device_loop(
        a.device,
        &mut blocks,
        a.n_total,
        a.m_noise,
        a.seed,
        a.n_active,
        &backend,
        transport,
    )
}

/// The `nomad worker` entry point: open the shard set, bind, serve one
/// coordinator session, exit.  One session per process keeps lifetimes
/// simple — the coordinator's `Stop` is the worker's exit.
pub fn run_worker(listen: &Endpoint, shards_dir: &Path, verbose: bool) -> Result<()> {
    let shards = ShardSet::open(shards_dir)
        .with_context(|| format!("open shard set at {}", shards_dir.display()))?;
    let listener = WorkerListener::bind(listen)?;
    if verbose {
        eprintln!(
            "worker: listening on {} ({} clusters / {} points in shard set)",
            listener.local_addr_string(),
            shards.manifest.clusters.len(),
            shards.manifest.n
        );
    }
    let mut transport = listener.accept_transport()?;
    let out = serve_session(&mut *transport, &shards, verbose);
    // a dead socket file should not outlive the worker
    #[cfg(unix)]
    if let Endpoint::Unix(path) = listen {
        let _ = std::fs::remove_file(path);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::device::{DeviceCmd, DeviceReply};
    use crate::distributed::proto::Role;
    use crate::distributed::transport::{channel_pair, connect, coordinator_handshake};
    use std::sync::Arc;
    use std::time::Duration;

    fn test_shards(name: &str) -> ShardSet {
        use crate::ann::backend::NativeBackend;
        use crate::ann::graph::{edge_weights, WeightModel};
        use crate::ann::{ClusterIndex, IndexParams};
        use crate::checkpoint::DatasetSpec;
        use crate::data::gaussian_mixture;
        use crate::data::shard::write_shards;
        use crate::util::rng::Rng;

        let dir = std::env::temp_dir().join("nomad_worker_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(9);
        let ds = gaussian_mixture(350, 8, 4, 8.0, 0.2, 0.5, &mut rng);
        let ip = IndexParams { n_clusters: 4, k: 5, ..Default::default() };
        let idx = ClusterIndex::build(&ds.x, &ip, &NativeBackend::default(), &mut rng);
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        let spec =
            DatasetSpec { kind: "synthetic".into(), source: "test".into(), n: 350, seed: 9 };
        write_shards(&dir, &idx, &ew, 8, 9, WeightModel::InverseRankForward, &ip, &spec)
            .unwrap();
        ShardSet::open(&dir).unwrap()
    }

    fn assignment(shards: &ShardSet, clusters: Vec<u32>) -> Assignment {
        Assignment {
            device: 0,
            n_active: 1,
            n_total: shards.manifest.n,
            negs: 4,
            seed: shards.manifest.seed,
            m_noise: 5.0,
            clusters,
        }
    }

    #[test]
    fn session_over_channel_serves_commands() {
        let shards = test_shards("session");
        let n = shards.manifest.n;
        let (mut coord, mut worker_end) = channel_pair();
        let a = assignment(&shards, vec![0, 2]);
        let expect_points: usize =
            shards.manifest.clusters[0].n + shards.manifest.clusters[2].n;

        let server = std::thread::spawn(move || {
            serve_session(&mut worker_end, &shards, false).unwrap();
        });

        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        match coord.recv().unwrap() {
            WireMsg::Assigned { device, n_blocks, n_points } => {
                assert_eq!(device, 0);
                assert_eq!(n_blocks, 2);
                assert_eq!(n_points, expect_points);
            }
            other => panic!("expected Assigned, got {other:?}"),
        }

        // ingest a position table, then export it back
        let table: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.01).collect();
        coord
            .send(WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(table.clone()) }))
            .unwrap();
        assert_eq!(
            coord.recv().unwrap(),
            WireMsg::Reply(DeviceReply::Ingested { device: 0 })
        );
        coord.send(WireMsg::Cmd(DeviceCmd::Export)).unwrap();
        match coord.recv().unwrap() {
            WireMsg::Reply(DeviceReply::Exported { positions, .. }) => {
                assert_eq!(positions.len(), expect_points);
                for (g, p) in positions {
                    assert_eq!(p[0], table[g as usize * 2]);
                    assert_eq!(p[1], table[g as usize * 2 + 1]);
                }
            }
            other => panic!("expected Exported, got {other:?}"),
        }
        coord.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn mismatched_assignment_is_refused() {
        let shards = test_shards("refuse");
        let (mut coord, mut worker_end) = channel_pair();
        let mut a = assignment(&shards, vec![0]);
        a.seed ^= 1; // different run

        let server =
            std::thread::spawn(move || serve_session(&mut worker_end, &shards, false));
        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        let err = server.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
    }

    #[test]
    fn out_of_range_cluster_is_refused() {
        let shards = test_shards("range");
        let (mut coord, mut worker_end) = channel_pair();
        let a = assignment(&shards, vec![99]);
        let server =
            std::thread::spawn(move || serve_session(&mut worker_end, &shards, false));
        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn tcp_listener_reports_resolved_port_and_accepts() {
        let shards = test_shards("tcp");
        let listener = WorkerListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = listener.local_addr_string();
        assert!(!addr.ends_with(":0"), "resolved port, got {addr}");

        let server = std::thread::spawn(move || {
            let mut t = listener.accept_transport().unwrap();
            serve_session(&mut *t, &shards, false)
        });
        let ep = Endpoint::parse(&addr).unwrap();
        let mut c = connect(&ep, Duration::from_secs(5)).unwrap();
        // drive just the handshake prefix, then hang up: the worker must
        // surface the dropped connection as an error, not a panic
        c.send(WireMsg::Hello { role: Role::Coordinator }).unwrap();
        match c.recv().unwrap() {
            WireMsg::Hello { role: Role::Worker } => {}
            other => panic!("expected worker hello, got {other:?}"),
        }
        drop(c);
        assert!(server.join().unwrap().is_err());
    }
}
