//! The `nomad worker` process: one simulated GPU as a real OS process.
//!
//! A worker binds a listener (TCP or Unix socket), accepts the
//! coordinator, handshakes, receives its [`Assignment`], loads **only the
//! assigned clusters** from an mmap'd shard set ([`ShardSet`]) — never the
//! corpus, never the init matrix — and then hands the connection to the
//! exact same [`run_device_loop`] the in-process device threads run.
//! Positions arrive over the wire via `DeviceCmd::Ingest`, epochs are
//! driven by the coordinator's absolute-epoch broadcast, and the
//! `(device seed, epoch, block)` RNG forks are untouched — which is why a
//! multi-process run is bitwise identical to an in-process one
//! (`tests/multiprocess.rs`, and the CI worker-smoke job with real
//! processes).

use super::device::run_device_loop;
use super::fault::{FaultInjector, FaultPlan};
use super::proto::{Assignment, WireMsg};
use super::transport::{worker_handshake, Endpoint, FramedTransport, Transport, WireStream};
use crate::data::shard::ShardSet;
use crate::embed::native::NativeStepBackend;
use crate::embed::ClusterBlock;
use crate::ensure;
use crate::obs::metrics;
use crate::util::error::{Context, Result};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How a worker process behaves across coordinator sessions.
#[derive(Clone, Debug)]
pub struct WorkerCfg {
    pub verbose: bool,
    /// Read/write deadline from accept until the assignment is acknowledged
    /// — a half-open or slow-loris coordinator connection times out here
    /// instead of wedging the worker before the handshake completes.
    pub handshake_timeout: Duration,
    /// Read/write deadline once a session is established (`None` blocks
    /// forever, the pre-deadline behavior).
    pub session_timeout: Option<Duration>,
    /// Exit after this many accepted sessions (`None` = serve until a
    /// coordinator sends `Stop`).  `Some(1)` makes a worker die with its
    /// first session — the chaos tests' "killed worker".
    pub max_sessions: Option<usize>,
    /// Scripted fault plan per accepted session index (tests only; absent
    /// entries serve cleanly).
    pub faults: Vec<FaultPlan>,
}

impl Default for WorkerCfg {
    fn default() -> WorkerCfg {
        WorkerCfg {
            verbose: false,
            handshake_timeout: Duration::from_secs(10),
            session_timeout: Some(Duration::from_secs(600)),
            max_sessions: None,
            faults: Vec::new(),
        }
    }
}

/// A bound worker listener, either flavor of [`Endpoint`].
pub enum WorkerListener {
    Tcp(std::net::TcpListener),
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixListener),
}

impl WorkerListener {
    /// Bind `ep`.  A stale Unix socket file (a previous worker that died
    /// without cleanup) is removed first — bind would otherwise fail with
    /// `AddrInUse` forever.
    pub fn bind(ep: &Endpoint) -> Result<WorkerListener> {
        match ep {
            Endpoint::Tcp(addr) => {
                let l = std::net::TcpListener::bind(addr.as_str())
                    .with_context(|| format!("bind {addr}"))?;
                Ok(WorkerListener::Tcp(l))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let _ = std::fs::remove_file(path);
                let l = std::os::unix::net::UnixListener::bind(path)
                    .with_context(|| format!("bind unix:{}", path.display()))?;
                Ok(WorkerListener::Unix(l))
            }
        }
    }

    /// The bound address, in [`Endpoint::parse`] syntax.  For TCP this is
    /// the *resolved* address — bind to `127.0.0.1:0` and read the kernel's
    /// port choice back (how the loopback tests avoid port collisions).
    pub fn local_addr_string(&self) -> String {
        match self {
            WorkerListener::Tcp(l) => l
                .local_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?:?".to_string()),
            #[cfg(unix)]
            WorkerListener::Unix(l) => match l.local_addr() {
                Ok(a) => match a.as_pathname() {
                    Some(p) => format!("unix:{}", p.display()),
                    None => "unix:?".to_string(),
                },
                Err(_) => "unix:?".to_string(),
            },
        }
    }

    /// Block until the coordinator dials in; returns the framed connection.
    pub fn accept_transport(&self) -> Result<Box<dyn Transport>> {
        match self {
            WorkerListener::Tcp(l) => {
                let (s, _) = l.accept().context("accept coordinator connection")?;
                let _ = s.set_nodelay(true);
                Ok(Box::new(FramedTransport::new(s)))
            }
            #[cfg(unix)]
            WorkerListener::Unix(l) => {
                let (s, _) = l.accept().context("accept coordinator connection")?;
                Ok(Box::new(FramedTransport::new(s)))
            }
        }
    }

    /// Switch the listener's accept into (non)blocking mode.
    pub fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            WorkerListener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            WorkerListener::Unix(l) => l.set_nonblocking(nb),
        }
        .map_err(|e| crate::util::error::Error::msg(format!("set listener nonblocking: {e}")))
    }

    /// Non-blocking accept: `Ok(None)` when nobody is dialing.  The
    /// accepted stream is switched back to blocking mode (deadlines are
    /// applied per session) and wrapped in the session's fault plan when
    /// one is scripted.
    pub fn try_accept(&self, plan: Option<&FaultPlan>) -> Result<Option<Box<dyn Transport>>> {
        fn wrap<S: WireStream + 'static>(s: S, plan: Option<&FaultPlan>) -> Box<dyn Transport> {
            match plan {
                Some(p) => Box::new(FaultInjector::new(s, p.clone(), "worker")),
                None => Box::new(FramedTransport::new(s)),
            }
        }
        let would_block = |e: &std::io::Error| {
            matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::Interrupted
            )
        };
        match self {
            WorkerListener::Tcp(l) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)
                        .map_err(|e| crate::util::error::Error::msg(format!("accept: {e}")))?;
                    let _ = s.set_nodelay(true);
                    Ok(Some(wrap(s, plan)))
                }
                Err(ref e) if would_block(e) => Ok(None),
                Err(e) => Err(crate::util::error::Error::msg(format!("accept: {e}"))),
            },
            #[cfg(unix)]
            WorkerListener::Unix(l) => match l.accept() {
                Ok((s, _)) => {
                    s.set_nonblocking(false)
                        .map_err(|e| crate::util::error::Error::msg(format!("accept: {e}")))?;
                    Ok(Some(wrap(s, plan)))
                }
                Err(ref e) if would_block(e) => Ok(None),
                Err(e) => Err(crate::util::error::Error::msg(format!("accept: {e}"))),
            },
        }
    }
}

/// Check the coordinator's assignment against the shard manifest before
/// loading anything: a coordinator driving a different dataset or seed
/// must fail loudly here, not produce a silently-wrong embedding.
fn validate_assignment(a: &Assignment, shards: &ShardSet) -> Result<()> {
    let m = &shards.manifest;
    ensure!(
        a.n_total == m.n,
        "assignment is for n={} points, shard set holds {}",
        a.n_total,
        m.n
    );
    ensure!(
        a.seed == m.seed,
        "assignment seed {} != shard set seed {} (different run)",
        a.seed,
        m.seed
    );
    for &c in &a.clusters {
        ensure!(
            (c as usize) < m.clusters.len(),
            "assigned cluster {c} out of range (shard set has {})",
            m.clusters.len()
        );
    }
    Ok(())
}

/// Serve one coordinator session over an accepted connection: handshake,
/// receive the assignment, load the assigned blocks from the shard set (in
/// assignment order — the block-index RNG forks depend on it), acknowledge
/// with block/point counts, then run the shared device loop to `Stop`.
///
/// The handshake phase (hello through `Assigned`) runs under
/// `cfg.handshake_timeout`; the established session under
/// `cfg.session_timeout` — neither a silent socket nor a wedged
/// coordinator can pin this thread forever.
pub fn serve_session(
    transport: &mut dyn Transport,
    shards: &ShardSet,
    cfg: &WorkerCfg,
) -> Result<()> {
    transport.set_timeouts(Some(cfg.handshake_timeout), Some(cfg.handshake_timeout))?;
    worker_handshake(transport)?;
    let a = match transport.recv()? {
        WireMsg::Assign(a) => a,
        other => crate::bail!("worker: expected an assignment, got {other:?}"),
    };
    validate_assignment(&a, shards)?;

    let mut blocks: Vec<ClusterBlock> = Vec::with_capacity(a.clusters.len());
    for &c in &a.clusters {
        blocks.push(shards.load_block(c as usize, a.n_total, a.m_noise, a.negs)?);
    }
    let n_points: usize = blocks.iter().map(|b| b.n_real).sum();
    if cfg.verbose {
        eprintln!(
            "worker: device {} assigned {} clusters / {} points",
            a.device,
            blocks.len(),
            n_points
        );
    }
    transport.send(WireMsg::Assigned {
        device: a.device,
        n_blocks: blocks.len(),
        n_points,
    })?;
    transport.set_timeouts(cfg.session_timeout, cfg.session_timeout)?;

    let backend = NativeStepBackend::default();
    run_device_loop(
        a.device,
        &mut blocks,
        a.n_total,
        a.m_noise,
        a.seed,
        a.n_active,
        &backend,
        transport,
    )
}

/// Accept-and-serve loop over an already-bound listener.  Sessions run on
/// their own threads (a faulted session must not block a coordinator
/// re-dialing after recovery); the worker exits once a session completed
/// with the coordinator's `Stop` — or, when `cfg.max_sessions` caps the
/// accept count, once the last accepted session ends, with an error if
/// none of them was stopped cleanly.
pub fn serve_listener(
    listener: WorkerListener,
    shards: Arc<ShardSet>,
    cfg: &WorkerCfg,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    // the path to unlink on exit, captured before the listener moves
    #[cfg(unix)]
    let sock_path = match &listener {
        WorkerListener::Unix(l) => {
            l.local_addr().ok().and_then(|a| a.as_pathname().map(|p| p.to_path_buf()))
        }
        _ => None,
    };
    let got_stop = Arc::new(AtomicBool::new(false));
    let active = Arc::new(AtomicUsize::new(0));
    let mut listener = Some(listener);
    let mut started = 0usize;
    let mut threads = Vec::new();
    loop {
        let accepting = cfg.max_sessions.map_or(true, |m| started < m);
        if !accepting && listener.is_some() {
            // close the listener so a re-dialing coordinator is refused
            // immediately instead of queueing on a dead worker
            listener = None;
            #[cfg(unix)]
            if let Some(p) = &sock_path {
                let _ = std::fs::remove_file(p);
            }
        }
        if (got_stop.load(Ordering::SeqCst) || !accepting)
            && active.load(Ordering::SeqCst) == 0
        {
            break;
        }
        if let Some(l) = &listener {
            if let Some(mut transport) = l.try_accept(cfg.faults.get(started))? {
                started += 1;
                let shards = Arc::clone(&shards);
                let got_stop = Arc::clone(&got_stop);
                let active = Arc::clone(&active);
                let scfg = cfg.clone();
                active.fetch_add(1, Ordering::SeqCst);
                threads.push(std::thread::spawn(move || {
                    let active_gauge = metrics::gauge(
                        "nomad_worker_active_sessions",
                        "Coordinator sessions currently being served.",
                        &[],
                    );
                    active_gauge.add(1.0);
                    let outcome = match serve_session(&mut *transport, &shards, &scfg) {
                        Ok(()) => {
                            got_stop.store(true, Ordering::SeqCst);
                            "stop"
                        }
                        Err(e) => {
                            if scfg.verbose {
                                eprintln!("worker: session ended: {e}");
                            }
                            "error"
                        }
                    };
                    metrics::counter(
                        "nomad_worker_sessions_total",
                        "Coordinator sessions served, by how they ended.",
                        &[("outcome", outcome)],
                    )
                    .inc();
                    active_gauge.add(-1.0);
                    active.fetch_sub(1, Ordering::SeqCst);
                }));
                continue; // another coordinator may already be dialing
            }
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    for t in threads {
        let _ = t.join();
    }
    #[cfg(unix)]
    if let Some(p) = &sock_path {
        let _ = std::fs::remove_file(p);
    }
    ensure!(
        got_stop.load(Ordering::SeqCst),
        "worker exited without a coordinator Stop ({started} session(s) served)"
    );
    Ok(())
}

/// The `nomad worker` entry point: open the shard set, bind, serve
/// coordinator sessions until one ends with `Stop` (see
/// [`serve_listener`]).
pub fn run_worker(listen: &Endpoint, shards_dir: &Path, cfg: &WorkerCfg) -> Result<()> {
    let shards = Arc::new(
        ShardSet::open(shards_dir)
            .with_context(|| format!("open shard set at {}", shards_dir.display()))?,
    );
    let listener = WorkerListener::bind(listen)?;
    if cfg.verbose {
        eprintln!(
            "worker: listening on {} ({} clusters / {} points in shard set)",
            listener.local_addr_string(),
            shards.manifest.clusters.len(),
            shards.manifest.n
        );
    }
    serve_listener(listener, shards, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::device::{DeviceCmd, DeviceReply};
    use crate::distributed::proto::Role;
    use crate::distributed::transport::{channel_pair, connect, coordinator_handshake};
    use std::sync::Arc;
    use std::time::Duration;

    fn test_shards(name: &str) -> ShardSet {
        use crate::ann::backend::NativeBackend;
        use crate::ann::graph::{edge_weights, WeightModel};
        use crate::ann::{ClusterIndex, IndexParams};
        use crate::checkpoint::DatasetSpec;
        use crate::data::gaussian_mixture;
        use crate::data::shard::write_shards;
        use crate::util::rng::Rng;

        let dir = std::env::temp_dir().join("nomad_worker_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Rng::new(9);
        let ds = gaussian_mixture(350, 8, 4, 8.0, 0.2, 0.5, &mut rng);
        let ip = IndexParams { n_clusters: 4, k: 5, ..Default::default() };
        let idx = ClusterIndex::build(&ds.x, &ip, &NativeBackend::default(), &mut rng);
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        let spec =
            DatasetSpec { kind: "synthetic".into(), source: "test".into(), n: 350, seed: 9 };
        write_shards(&dir, &idx, &ew, 8, 9, WeightModel::InverseRankForward, &ip, &spec)
            .unwrap();
        ShardSet::open(&dir).unwrap()
    }

    fn assignment(shards: &ShardSet, clusters: Vec<u32>) -> Assignment {
        Assignment {
            device: 0,
            n_active: 1,
            n_total: shards.manifest.n,
            negs: 4,
            seed: shards.manifest.seed,
            m_noise: 5.0,
            clusters,
        }
    }

    #[test]
    fn session_over_channel_serves_commands() {
        let shards = test_shards("session");
        let n = shards.manifest.n;
        let (mut coord, mut worker_end) = channel_pair();
        let a = assignment(&shards, vec![0, 2]);
        let expect_points: usize =
            shards.manifest.clusters[0].n + shards.manifest.clusters[2].n;

        let server = std::thread::spawn(move || {
            serve_session(&mut worker_end, &shards, &WorkerCfg::default()).unwrap();
        });

        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        match coord.recv().unwrap() {
            WireMsg::Assigned { device, n_blocks, n_points } => {
                assert_eq!(device, 0);
                assert_eq!(n_blocks, 2);
                assert_eq!(n_points, expect_points);
            }
            other => panic!("expected Assigned, got {other:?}"),
        }

        // ingest a position table, then export it back
        let table: Vec<f32> = (0..n * 2).map(|i| i as f32 * 0.01).collect();
        coord
            .send(WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(table.clone()) }))
            .unwrap();
        assert_eq!(
            coord.recv().unwrap(),
            WireMsg::Reply(DeviceReply::Ingested { device: 0 })
        );
        coord.send(WireMsg::Cmd(DeviceCmd::Export)).unwrap();
        match coord.recv().unwrap() {
            WireMsg::Reply(DeviceReply::Exported { positions, .. }) => {
                assert_eq!(positions.len(), expect_points);
                for (g, p) in positions {
                    assert_eq!(p[0], table[g as usize * 2]);
                    assert_eq!(p[1], table[g as usize * 2 + 1]);
                }
            }
            other => panic!("expected Exported, got {other:?}"),
        }
        coord.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn mismatched_assignment_is_refused() {
        let shards = test_shards("refuse");
        let (mut coord, mut worker_end) = channel_pair();
        let mut a = assignment(&shards, vec![0]);
        a.seed ^= 1; // different run

        let server = std::thread::spawn(move || {
            serve_session(&mut worker_end, &shards, &WorkerCfg::default())
        });
        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        let err = server.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("seed"), "{err}");
    }

    #[test]
    fn out_of_range_cluster_is_refused() {
        let shards = test_shards("range");
        let (mut coord, mut worker_end) = channel_pair();
        let a = assignment(&shards, vec![99]);
        let server = std::thread::spawn(move || {
            serve_session(&mut worker_end, &shards, &WorkerCfg::default())
        });
        coordinator_handshake(&mut coord).unwrap();
        coord.send(WireMsg::Assign(a)).unwrap();
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn tcp_listener_reports_resolved_port_and_accepts() {
        let shards = test_shards("tcp");
        let listener = WorkerListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = listener.local_addr_string();
        assert!(!addr.ends_with(":0"), "resolved port, got {addr}");

        let server = std::thread::spawn(move || {
            let mut t = listener.accept_transport().unwrap();
            serve_session(&mut *t, &shards, &WorkerCfg::default())
        });
        let ep = Endpoint::parse(&addr).unwrap();
        let mut c = connect(&ep, Duration::from_secs(5)).unwrap();
        // drive just the handshake prefix, then hang up: the worker must
        // surface the dropped connection as an error, not a panic
        c.send(WireMsg::Hello { role: Role::Coordinator }).unwrap();
        match c.recv().unwrap() {
            WireMsg::Hello { role: Role::Worker } => {}
            other => panic!("expected worker hello, got {other:?}"),
        }
        drop(c);
        assert!(server.join().unwrap().is_err());
    }

    #[test]
    fn half_open_connection_times_out_instead_of_wedging() {
        let shards = Arc::new(test_shards("halfopen"));
        let listener = WorkerListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = listener.local_addr_string();
        let cfg = WorkerCfg {
            handshake_timeout: Duration::from_millis(200),
            max_sessions: Some(1),
            ..Default::default()
        };
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = std::thread::spawn(move || {
            let _ = tx.send(serve_listener(listener, shards, &cfg));
        });
        // a slow-loris coordinator: dial, then send nothing and stay open
        let _idle = std::net::TcpStream::connect(addr.as_str()).unwrap();
        let out = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("worker must exit on its own, not wedge on the silent socket");
        let e = out.unwrap_err().to_string();
        assert!(e.contains("without a coordinator Stop"), "{e}");
        worker.join().unwrap();
    }

    #[test]
    fn worker_survives_a_dead_session_and_serves_the_next_coordinator() {
        let shards = Arc::new(test_shards("redial"));
        let a = assignment(&shards, vec![1]);
        let listener = WorkerListener::bind(&Endpoint::Tcp("127.0.0.1:0".into())).unwrap();
        let addr = listener.local_addr_string();
        let cfg =
            WorkerCfg { handshake_timeout: Duration::from_millis(500), ..Default::default() };
        let worker_shards = Arc::clone(&shards);
        let (tx, rx) = std::sync::mpsc::channel();
        let worker = std::thread::spawn(move || {
            let _ = tx.send(serve_listener(listener, worker_shards, &cfg));
        });

        let ep = Endpoint::parse(&addr).unwrap();
        // session 1: the coordinator dies mid-handshake
        {
            let mut c = connect(&ep, Duration::from_secs(5)).unwrap();
            c.send(WireMsg::Hello { role: Role::Coordinator }).unwrap();
        }
        // session 2: a clean establish-and-stop — the worker must still be
        // accepting after the first session's error
        let mut c = connect(&ep, Duration::from_secs(5)).unwrap();
        coordinator_handshake(&mut *c).unwrap();
        c.send(WireMsg::Assign(a)).unwrap();
        match c.recv().unwrap() {
            WireMsg::Assigned { n_blocks, .. } => assert_eq!(n_blocks, 1),
            other => panic!("expected Assigned, got {other:?}"),
        }
        c.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap();
        let out = rx.recv_timeout(Duration::from_secs(30)).expect("worker exits after Stop");
        assert!(out.is_ok(), "{out:?}");
        worker.join().unwrap();
    }
}
