//! The multi-device runtime (paper Fig 2).
//!
//! A *device* is a long-lived OS thread **or process** owning a set of
//! [`ClusterBlock`]s (whole K-Means clusters — the paper's sharding unit)
//! and its own step backend (for the XLA path each device owns a private
//! PJRT client, since a real deployment gives each GPU its own PJRT
//! device).  Either way it runs [`device::run_device_loop`] over a
//! [`transport::Transport`] — an in-process channel pair, or a TCP/Unix
//! socket framed by [`proto`] when the device is a `nomad worker` process
//! streaming its blocks from an mmap'd shard set (DESIGN.md §12).  The
//! coordinator drives epoch-synchronous training:
//!
//! ```text
//! per epoch:   leader ──Epoch{epoch, lr, means}──▶ every device  (bcast)
//!              device: one NOMAD step per local block
//!              device ──EpochDone{means, loss}──▶ leader       (gather)
//!              leader: rebuild the global means table          (all-gather)
//! ```
//!
//! Devices also answer `Export` (positions out — snapshots, checkpoints,
//! final collection) and `Ingest` (positions in — checkpoint resume); the
//! epoch index travels in the broadcast so block RNG streams fork from
//! `(device, epoch, block)` regardless of which epoch a run starts at
//! (DESIGN.md §11).
//!
//! Only the R x 3 floats of cluster means+weights cross device boundaries —
//! exactly the communication pattern that lets NOMAD scale; [`comm_model`]
//! converts the measured byte counts into modeled H100-node wall-clock so
//! the paper's speedup *shape* can be reproduced on CPU hardware.

pub mod comm_model;
pub mod device;
pub mod fault;
pub mod fuzz;
pub mod proto;
pub mod sharder;
pub mod transport;
pub mod worker;

/// One all-gathered cluster mean.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeanEntry {
    pub cluster_id: u32,
    pub mean: [f32; 2],
    /// |M| * p(m in cluster)
    pub weight: f32,
}

/// Bytes for one mean entry on the wire (id + 2 floats + weight).
pub const MEAN_ENTRY_BYTES: u64 = 16;
