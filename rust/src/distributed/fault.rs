//! Deterministic fault injection and fault classification (DESIGN.md §13).
//!
//! A [`FaultInjector`] wraps a raw socket stream with its own [`proto`]
//! framing and fires a scripted [`FaultAction`] when a given frame index
//! crosses it in a given direction — so every failure mode a week-long
//! distributed run can hit (dropped frames, stalls, bit corruption, dead
//! peers) is reproducible in a unit test, byte for byte, run after run.
//! Frame indices count from 0 per direction: on the coordinator side of a
//! link, send frame 0 is `Hello`, 1 is `Assign`, 2 is `Ingest`, 3 is the
//! first `Epoch`, 4 the first `Export` — one scalar selects a protocol
//! phase to break (`tests/chaos.rs` sweeps it).
//!
//! [`FaultKind::classify`] is the other half: it maps any transport-layer
//! error (injected or organic) onto the coarse failure classes the
//! coordinator's recovery loop handles, by matching the stable substrings
//! [`proto`] and the transports put in their messages ("timed out",
//! "connection reset", "crc mismatch", ...).  Every recovery is recorded
//! as a [`FaultEvent`] in `CommStats` and the run manifest.

use super::proto::{self, WireMsg, HEADER_BYTES};
use super::transport::{Transport, WireStream};
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;
use std::io::Write;
use std::time::Duration;

/// Which direction of the wrapped stream a rule watches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    Send,
    Recv,
}

/// What happens to the selected frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// the frame silently never travels (lost datagram / dead NIC queue)
    Drop,
    /// the frame travels late (congestion); everything else is normal
    Delay(Duration),
    /// the frame travels with one payload (or crc) byte flipped
    Corrupt,
    /// the peer wedges: sleep this long, then fail as timed out and
    /// poison the link
    Hang(Duration),
    /// the peer dies: fail as connection-reset and poison the link
    Disconnect,
}

/// One scripted fault: `action` fires when frame number `frame` (0-based,
/// counted per direction) crosses in direction `dir`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultRule {
    pub dir: Dir,
    pub frame: u64,
    pub action: FaultAction,
}

/// A deterministic fault script for one link.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// A plan with a single rule.
    pub fn one(dir: Dir, frame: u64, action: FaultAction) -> FaultPlan {
        FaultPlan { rules: vec![FaultRule { dir, frame, action }] }
    }

    /// A seeded random single-fault plan: one of the *fail-fast* actions
    /// (corrupt / hang / disconnect — never a silent drop, whose only
    /// detector is the epoch deadline) at a frame in `0..max_frame`, on a
    /// random direction.  Same seed, same plan, always.
    pub fn seeded(seed: u64, max_frame: u64, hang: Duration) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17);
        let dir = if rng.below(2) == 0 { Dir::Send } else { Dir::Recv };
        let frame = rng.below(max_frame.max(1) as usize) as u64;
        let action = match rng.below(3) {
            0 => FaultAction::Corrupt,
            1 => FaultAction::Hang(hang),
            _ => FaultAction::Disconnect,
        };
        FaultPlan::one(dir, frame, action)
    }

    /// The action scripted for this (direction, frame), if any.
    pub fn action_at(&self, dir: Dir, frame: u64) -> Option<FaultAction> {
        self.rules
            .iter()
            .find(|r| r.dir == dir && r.frame == frame)
            .map(|r| r.action)
    }
}

/// The coarse failure classes the recovery loop distinguishes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// a deadline expired (read/write timeout, epoch deadline)
    Timeout,
    /// the peer hung up (reset, closed, broken pipe, EOF mid-frame)
    Disconnect,
    /// a frame arrived but its crc did not check out
    Corruption,
    /// framing was intact but the content violated the protocol (bad
    /// magic/version/type, unexpected message for the phase)
    Protocol,
    Other,
}

impl FaultKind {
    /// Classify a transport-layer error by the stable substrings the
    /// proto/transport layers put in their messages.
    pub fn classify(e: &Error) -> FaultKind {
        let s = e.to_string();
        if s.contains("timed out") {
            FaultKind::Timeout
        } else if s.contains("connection reset")
            || s.contains("connection closed")
            || s.contains("hung up")
        {
            FaultKind::Disconnect
        } else if s.contains("crc mismatch") {
            FaultKind::Corruption
        } else if s.contains("magic")
            || s.contains("version")
            || s.contains("frame type")
            || s.contains("expected")
        {
            FaultKind::Protocol
        } else {
            FaultKind::Other
        }
    }

    /// Stable name for manifests and logs.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Timeout => "timeout",
            FaultKind::Disconnect => "disconnect",
            FaultKind::Corruption => "corruption",
            FaultKind::Protocol => "protocol",
            FaultKind::Other => "other",
        }
    }
}

/// One classified fault the coordinator observed and recovered from (or
/// died on).  Surfaces in `CommStats::faults` and the run manifest.
#[derive(Clone, Debug)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// logical device whose link faulted (usize::MAX when unattributable)
    pub device: usize,
    /// the epoch training restarted from after the rollback
    pub restart_epoch: usize,
    /// the underlying error text
    pub detail: String,
}

/// A [`Transport`] over a raw stream that executes a [`FaultPlan`].
///
/// Runs the same [`proto`] framing as `FramedTransport`, plus the
/// scripted faults.  Drop still *accounts* the frame bytes (the sender
/// believes it sent); Corrupt flips one byte the crc covers, so the peer
/// sees exactly the "crc mismatch" a real flipped bit would cause; Hang
/// and Disconnect poison the link — every later call fails like a dead
/// socket would.
pub struct FaultInjector<S: WireStream> {
    stream: S,
    plan: FaultPlan,
    /// names the wrapped side in injected-error messages ("worker", ...)
    tag: &'static str,
    sent_frames: u64,
    recv_frames: u64,
    sent: u64,
    received: u64,
    poisoned: bool,
}

impl<S: WireStream> FaultInjector<S> {
    pub fn new(stream: S, plan: FaultPlan, tag: &'static str) -> FaultInjector<S> {
        FaultInjector {
            stream,
            plan,
            tag,
            sent_frames: 0,
            recv_frames: 0,
            sent: 0,
            received: 0,
            poisoned: false,
        }
    }

    fn poisoned_err<T>(&self) -> Result<T> {
        crate::bail!("{}: connection reset by injected fault", self.tag)
    }
}

impl<S: WireStream> Transport for FaultInjector<S> {
    fn send(&mut self, msg: WireMsg) -> Result<()> {
        if self.poisoned {
            return self.poisoned_err();
        }
        let frame_no = self.sent_frames;
        self.sent_frames += 1;
        match self.plan.action_at(Dir::Send, frame_no) {
            Some(FaultAction::Drop) => {
                // the frame vanishes, but the sender's accounting (and its
                // belief that the send succeeded) is that of a normal send
                self.sent += proto::frame_len(&msg) as u64;
                Ok(())
            }
            Some(FaultAction::Corrupt) => {
                let mut frame = proto::encode(&msg);
                // flip a bit the crc covers: first payload byte, or the
                // crc field itself for empty payloads — never the length
                // field, so framing stays aligned for later frames
                let idx = if frame.len() > HEADER_BYTES { HEADER_BYTES } else { 12 };
                frame[idx] ^= 0x40;
                self.stream
                    .write_all(&frame)
                    .and_then(|()| self.stream.flush())
                    .map_err(|e| Error::msg(format!("write frame: {e}")))?;
                self.sent += frame.len() as u64;
                Ok(())
            }
            Some(FaultAction::Hang(d)) => {
                std::thread::sleep(d);
                self.poisoned = true;
                crate::bail!("{}: send timed out (injected hang at frame {frame_no})", self.tag)
            }
            Some(FaultAction::Disconnect) => {
                self.poisoned = true;
                crate::bail!(
                    "{}: connection reset (injected disconnect at frame {frame_no})",
                    self.tag
                )
            }
            Some(FaultAction::Delay(d)) => {
                std::thread::sleep(d);
                let n = proto::write_frame(&mut self.stream, &msg)?;
                self.stream
                    .flush()
                    .map_err(|e| Error::msg(format!("flush frame: {e}")))?;
                self.sent += n as u64;
                Ok(())
            }
            None => {
                let n = proto::write_frame(&mut self.stream, &msg)?;
                self.stream
                    .flush()
                    .map_err(|e| Error::msg(format!("flush frame: {e}")))?;
                self.sent += n as u64;
                Ok(())
            }
        }
    }

    fn recv(&mut self) -> Result<WireMsg> {
        loop {
            if self.poisoned {
                return self.poisoned_err();
            }
            let frame_no = self.recv_frames;
            self.recv_frames += 1;
            match self.plan.action_at(Dir::Recv, frame_no) {
                Some(FaultAction::Drop) => {
                    // read the real frame off the wire and discard it, so
                    // framing stays aligned and the *next* recv sees the
                    // next frame — the peer's send "was lost"
                    let (_msg, n) = proto::read_frame(&mut self.stream)?;
                    self.received += n as u64;
                    continue;
                }
                Some(FaultAction::Corrupt) => {
                    // the frame arrives but one bit flipped in transit:
                    // consume it, then fail exactly as the crc check would
                    let (_msg, n) = proto::read_frame(&mut self.stream)?;
                    self.received += n as u64;
                    crate::bail!(
                        "{}: frame crc mismatch (injected corruption at frame {frame_no})",
                        self.tag
                    )
                }
                Some(FaultAction::Hang(d)) => {
                    std::thread::sleep(d);
                    self.poisoned = true;
                    crate::bail!(
                        "{}: recv timed out (injected hang at frame {frame_no})",
                        self.tag
                    )
                }
                Some(FaultAction::Disconnect) => {
                    self.poisoned = true;
                    crate::bail!(
                        "{}: connection reset (injected disconnect at frame {frame_no})",
                        self.tag
                    )
                }
                Some(FaultAction::Delay(d)) => {
                    std::thread::sleep(d);
                    let (msg, n) = proto::read_frame(&mut self.stream)?;
                    self.received += n as u64;
                    return Ok(msg);
                }
                None => {
                    let (msg, n) = proto::read_frame(&mut self.stream)?;
                    self.received += n as u64;
                    return Ok(msg);
                }
            }
        }
    }

    fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        self.stream.set_stream_timeouts(read, write)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::device::DeviceCmd;
    use crate::distributed::transport::FramedTransport;
    use std::net::{TcpListener, TcpStream};

    fn tcp_pair() -> (TcpStream, TcpStream) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (client, server)
    }

    #[test]
    fn classification_matches_stable_substrings() {
        let cases = [
            ("device 1: recv timed out after 3.0s", FaultKind::Timeout),
            ("read frame header: connection reset/closed", FaultKind::Disconnect),
            ("channel transport: peer hung up", FaultKind::Disconnect),
            ("frame crc mismatch: computed 0, header says 1", FaultKind::Corruption),
            ("bad frame magic [58, 4d, 44, 46]", FaultKind::Protocol),
            ("unknown frame type 61166", FaultKind::Protocol),
            ("expected EpochDone, got Hello", FaultKind::Protocol),
            ("no space left on device", FaultKind::Other),
        ];
        for (msg, want) in cases {
            assert_eq!(FaultKind::classify(&Error::msg(msg)), want, "{msg}");
        }
    }

    #[test]
    fn seeded_plans_are_deterministic_and_fail_fast() {
        for seed in 0..32u64 {
            let a = FaultPlan::seeded(seed, 5, Duration::from_millis(10));
            let b = FaultPlan::seeded(seed, 5, Duration::from_millis(10));
            assert_eq!(a.rules, b.rules, "seed {seed} must replay");
            assert_eq!(a.rules.len(), 1);
            assert!(a.rules[0].frame < 5);
            assert!(!matches!(a.rules[0].action, FaultAction::Drop | FaultAction::Delay(_)));
        }
        // seeds actually vary the plan
        let plans: Vec<FaultPlan> =
            (0..32).map(|s| FaultPlan::seeded(s, 5, Duration::from_millis(10))).collect();
        assert!(plans.windows(2).any(|w| w[0].rules != w[1].rules));
    }

    #[test]
    fn corrupt_send_trips_the_peer_crc_check() {
        let (client, server) = tcp_pair();
        let mut inj =
            FaultInjector::new(client, FaultPlan::one(Dir::Send, 0, FaultAction::Corrupt), "t");
        let peer = std::thread::spawn(move || {
            let mut t = FramedTransport::new(server);
            let first = t.recv();
            (first, t.recv())
        });
        inj.send(WireMsg::Cmd(DeviceCmd::Export)).unwrap();
        drop(inj);
        let (first, _second) = peer.join().unwrap();
        let e = first.unwrap_err().to_string();
        assert!(e.contains("crc mismatch"), "{e}");
    }

    #[test]
    fn dropped_send_frame_never_arrives_but_later_frames_do() {
        let (client, server) = tcp_pair();
        let mut inj =
            FaultInjector::new(client, FaultPlan::one(Dir::Send, 0, FaultAction::Drop), "t");
        let peer = std::thread::spawn(move || FramedTransport::new(server).recv());
        inj.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap(); // dropped
        inj.send(WireMsg::Cmd(DeviceCmd::Export)).unwrap(); // arrives first
        assert!(inj.bytes_sent() > 0, "dropped frames still account bytes");
        match peer.join().unwrap().unwrap() {
            WireMsg::Cmd(DeviceCmd::Export) => {}
            other => panic!("peer should have seen Export, got {other:?}"),
        }
    }

    #[test]
    fn recv_drop_skips_to_the_next_frame() {
        let (client, server) = tcp_pair();
        let mut inj =
            FaultInjector::new(client, FaultPlan::one(Dir::Recv, 0, FaultAction::Drop), "t");
        let peer = std::thread::spawn(move || {
            let mut t = FramedTransport::new(server);
            t.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap();
            t.send(WireMsg::Cmd(DeviceCmd::Export)).unwrap();
        });
        match inj.recv().unwrap() {
            WireMsg::Cmd(DeviceCmd::Export) => {}
            other => panic!("frame 0 should have been dropped, got {other:?}"),
        }
        peer.join().unwrap();
    }

    #[test]
    fn disconnect_and_hang_poison_the_link_with_classified_errors() {
        let (client, _server) = tcp_pair();
        let mut inj = FaultInjector::new(
            client,
            FaultPlan::one(Dir::Send, 0, FaultAction::Disconnect),
            "worker",
        );
        let e = inj.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap_err();
        assert_eq!(FaultKind::classify(&e), FaultKind::Disconnect);
        // poisoned: every later op fails the same way
        let e2 = inj.recv().unwrap_err();
        assert_eq!(FaultKind::classify(&e2), FaultKind::Disconnect);

        let (client, _server) = tcp_pair();
        let mut inj = FaultInjector::new(
            client,
            FaultPlan::one(Dir::Recv, 0, FaultAction::Hang(Duration::from_millis(5))),
            "worker",
        );
        let e = inj.recv().unwrap_err();
        assert_eq!(FaultKind::classify(&e), FaultKind::Timeout);
        assert_eq!(
            FaultKind::classify(&inj.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap_err()),
            FaultKind::Disconnect,
            "poisoned links look like dead sockets"
        );
    }
}
