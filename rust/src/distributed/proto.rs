//! Versioned binary wire protocol for coordinator <-> device traffic.
//!
//! Every message travels as one **frame**:
//!
//! ```text
//! offset  size  field
//!      0     4  magic      "NMDF"
//!      4     2  version    u16 LE (PROTO_VERSION)
//!      6     2  msg type   u16 LE
//!      8     4  payload length u32 LE
//!     12     4  crc32 over (msg type ∥ payload length ∥ payload)
//!     16     n  payload (little-endian fixed-width fields)
//! ```
//!
//! The version rides in **every** header, so a coordinator/worker mismatch
//! fails on the first frame with a clear error instead of a garbled
//! payload.  The crc32 guards the payload the same way the checkpoint
//! store guards its `.npy` state files (DESIGN.md §11): a flipped bit is
//! an `Err`, never a panic and never silently wrong floats.  It also
//! covers the type and length header fields — two commands share the
//! empty payload (`Export`/`Stop`), so a flipped type bit must not alias
//! one into the other; magic and version are checked by value instead.
//! Decoding is hardened the way the npy reader is — claimed lengths are
//! bounds-checked before any allocation, truncated or trailing bytes are
//! errors.
//!
//! Float fields round-trip bitwise (`to_le_bytes`/`from_le_bytes`), which
//! is what lets a TCP/Unix-socket run reproduce an in-process run exactly.

use super::device::{DeviceCmd, DeviceReply};
use super::MeanEntry;
use crate::ensure;
use crate::util::error::{Context, Result};
use crate::viz::png::Crc32;
use std::io::{Read, Write};
use std::sync::Arc;

/// Frame magic: "NMDF" (NoMaD Frame).
pub const MAGIC: [u8; 4] = *b"NMDF";
/// Protocol version carried in every frame header.
pub const PROTO_VERSION: u16 = 1;
/// Fixed header size in bytes.
pub const HEADER_BYTES: usize = 16;
/// Upper bound on a payload (1 GiB) — a corrupt length field must not
/// trigger a pathological allocation.
pub const MAX_PAYLOAD: u32 = 1 << 30;

const TY_HELLO: u16 = 1;
const TY_ASSIGN: u16 = 2;
const TY_ASSIGNED: u16 = 3;
const TY_EPOCH: u16 = 4;
const TY_EXPORT: u16 = 5;
const TY_INGEST: u16 = 6;
const TY_STOP: u16 = 7;
const TY_EPOCH_DONE: u16 = 8;
const TY_EXPORTED: u16 = 9;
const TY_INGESTED: u16 = 10;

/// Who is speaking in the [`WireMsg::Hello`] handshake.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Coordinator,
    Worker,
}

/// The coordinator's session-opening work order: which device a worker
/// plays, and which clusters (in shard order — block RNG streams fork by
/// block *index*) it must load from its shard set.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub device: usize,
    /// devices that own at least one block (thread-budget divisor)
    pub n_active: usize,
    /// full dataset size (for p(m in r) = |r|/n)
    pub n_total: usize,
    pub negs: usize,
    pub seed: u64,
    pub m_noise: f64,
    /// cluster ids in assignment order
    pub clusters: Vec<u32>,
}

/// Everything that crosses a device boundary.
///
/// Handshake and assignment are wire-level concerns, so they live here
/// rather than in [`DeviceCmd`]/[`DeviceReply`] — the epoch loop itself
/// speaks exactly the same command/reply enums whether the transport is a
/// channel or a socket.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    Hello { role: Role },
    Assign(Assignment),
    Assigned { device: usize, n_blocks: usize, n_points: usize },
    Cmd(DeviceCmd),
    Reply(DeviceReply),
}

// ---------------------------------------------------------------- encode

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new(cap: usize) -> Enc {
        Enc { buf: Vec::with_capacity(cap) }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn means(&mut self, means: &[MeanEntry]) {
        self.u32(means.len() as u32);
        for e in means {
            self.u32(e.cluster_id);
            self.f32(e.mean[0]);
            self.f32(e.mean[1]);
            self.f32(e.weight);
        }
    }
}

fn msg_type(msg: &WireMsg) -> u16 {
    match msg {
        WireMsg::Hello { .. } => TY_HELLO,
        WireMsg::Assign(_) => TY_ASSIGN,
        WireMsg::Assigned { .. } => TY_ASSIGNED,
        WireMsg::Cmd(DeviceCmd::Epoch { .. }) => TY_EPOCH,
        WireMsg::Cmd(DeviceCmd::Export) => TY_EXPORT,
        WireMsg::Cmd(DeviceCmd::Ingest { .. }) => TY_INGEST,
        WireMsg::Cmd(DeviceCmd::Stop) => TY_STOP,
        WireMsg::Reply(DeviceReply::EpochDone { .. }) => TY_EPOCH_DONE,
        WireMsg::Reply(DeviceReply::Exported { .. }) => TY_EXPORTED,
        WireMsg::Reply(DeviceReply::Ingested { .. }) => TY_INGESTED,
    }
}

/// Stable lowercase name of a message's frame type — the `type` label on
/// the transport's per-message obs counters (DESIGN.md §15).
pub fn msg_kind(msg: &WireMsg) -> &'static str {
    match msg {
        WireMsg::Hello { .. } => "hello",
        WireMsg::Assign(_) => "assign",
        WireMsg::Assigned { .. } => "assigned",
        WireMsg::Cmd(DeviceCmd::Epoch { .. }) => "epoch",
        WireMsg::Cmd(DeviceCmd::Export) => "export",
        WireMsg::Cmd(DeviceCmd::Ingest { .. }) => "ingest",
        WireMsg::Cmd(DeviceCmd::Stop) => "stop",
        WireMsg::Reply(DeviceReply::EpochDone { .. }) => "epoch_done",
        WireMsg::Reply(DeviceReply::Exported { .. }) => "exported",
        WireMsg::Reply(DeviceReply::Ingested { .. }) => "ingested",
    }
}

/// Payload size in bytes, computed arithmetically (no serialization).
/// Must agree exactly with [`encode`]'s output — the channel transport
/// uses it to account would-be wire bytes without paying for encoding.
fn payload_len(msg: &WireMsg) -> usize {
    match msg {
        WireMsg::Hello { .. } => 1,
        WireMsg::Assign(a) => 4 + 4 + 8 + 4 + 8 + 8 + 4 + 4 * a.clusters.len(),
        WireMsg::Assigned { .. } => 4 + 4 + 8,
        WireMsg::Cmd(DeviceCmd::Epoch { means, .. }) => 8 + 4 + 4 + 4 + 16 * means.len(),
        WireMsg::Cmd(DeviceCmd::Export) | WireMsg::Cmd(DeviceCmd::Stop) => 0,
        WireMsg::Cmd(DeviceCmd::Ingest { positions }) => 8 + 4 * positions.len(),
        WireMsg::Reply(DeviceReply::EpochDone { means, .. }) => {
            4 + 8 + 8 + 8 + 8 + 4 + 16 * means.len()
        }
        WireMsg::Reply(DeviceReply::Exported { positions, .. }) => 4 + 8 + 12 * positions.len(),
        WireMsg::Reply(DeviceReply::Ingested { .. }) => 4,
    }
}

/// Total frame size (header + payload) this message encodes to.
pub fn frame_len(msg: &WireMsg) -> usize {
    HEADER_BYTES + payload_len(msg)
}

fn encode_payload(msg: &WireMsg) -> Vec<u8> {
    let mut e = Enc::new(payload_len(msg));
    match msg {
        WireMsg::Hello { role } => {
            e.u8(match role {
                Role::Coordinator => 0,
                Role::Worker => 1,
            });
        }
        WireMsg::Assign(a) => {
            e.u32(a.device as u32);
            e.u32(a.n_active as u32);
            e.u64(a.n_total as u64);
            e.u32(a.negs as u32);
            e.u64(a.seed);
            e.f64(a.m_noise);
            e.u32(a.clusters.len() as u32);
            for &c in &a.clusters {
                e.u32(c);
            }
        }
        WireMsg::Assigned { device, n_blocks, n_points } => {
            e.u32(*device as u32);
            e.u32(*n_blocks as u32);
            e.u64(*n_points as u64);
        }
        WireMsg::Cmd(DeviceCmd::Epoch { epoch, lr, exaggeration, means }) => {
            e.u64(*epoch as u64);
            e.f32(*lr);
            e.f32(*exaggeration);
            e.means(means);
        }
        WireMsg::Cmd(DeviceCmd::Export) | WireMsg::Cmd(DeviceCmd::Stop) => {}
        WireMsg::Cmd(DeviceCmd::Ingest { positions }) => {
            e.u64(positions.len() as u64);
            for &v in positions.iter() {
                e.f32(v);
            }
        }
        WireMsg::Reply(DeviceReply::EpochDone {
            device,
            means,
            loss_sum,
            loss_weight,
            step_secs,
            flops,
        }) => {
            e.u32(*device as u32);
            e.f64(*loss_sum);
            e.f64(*loss_weight);
            e.f64(*step_secs);
            e.f64(*flops);
            e.means(means);
        }
        WireMsg::Reply(DeviceReply::Exported { device, positions }) => {
            e.u32(*device as u32);
            e.u64(positions.len() as u64);
            for (g, p) in positions {
                e.u32(*g);
                e.f32(p[0]);
                e.f32(p[1]);
            }
        }
        WireMsg::Reply(DeviceReply::Ingested { device }) => {
            e.u32(*device as u32);
        }
    }
    debug_assert_eq!(e.buf.len(), payload_len(msg), "payload_len drifted from encode");
    e.buf
}

/// The frame checksum: crc32 over the type and length header fields plus
/// the payload, so every bit `parse_header` cannot reject by value is
/// still guarded.
fn frame_crc(ty: u16, payload: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(&ty.to_le_bytes());
    c.update(&(payload.len() as u32).to_le_bytes());
    c.update(payload);
    c.finish()
}

/// Encode a full frame (header + payload).
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let payload = encode_payload(msg);
    let ty = msg_type(msg);
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&PROTO_VERSION.to_le_bytes());
    out.extend_from_slice(&ty.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&frame_crc(ty, &payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

// ---------------------------------------------------------------- decode

struct Dec<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, off: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.off.checked_add(n);
        let s = match end.and_then(|e| self.b.get(self.off..e)) {
            Some(s) => s,
            None => crate::bail!(
                "frame payload truncated: need {n} bytes at offset {}, have {}",
                self.off,
                self.b.len()
            ),
        };
        self.off += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }
    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.u32()?.to_le_bytes()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.u64()?.to_le_bytes()))
    }
    fn usize32(&mut self) -> Result<usize> {
        Ok(self.u32()? as usize)
    }
    fn usize64(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).context("64-bit count overflows usize")
    }
    /// A claimed element count, sanity-bounded by the bytes actually left
    /// in the payload so corrupt counts cannot drive huge allocations.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usize32()?;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.b.len() - self.off,
            "claimed count {n} exceeds remaining payload"
        );
        Ok(n)
    }
    fn count64(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usize64()?;
        ensure!(
            n.saturating_mul(elem_bytes) <= self.b.len() - self.off,
            "claimed count {n} exceeds remaining payload"
        );
        Ok(n)
    }
    fn means(&mut self) -> Result<Vec<MeanEntry>> {
        let n = self.count(16)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(MeanEntry {
                cluster_id: self.u32()?,
                mean: [self.f32()?, self.f32()?],
                weight: self.f32()?,
            });
        }
        Ok(out)
    }
    fn done(&self) -> Result<()> {
        ensure!(
            self.off == self.b.len(),
            "frame payload has {} trailing bytes",
            self.b.len() - self.off
        );
        Ok(())
    }
}

fn decode_payload(ty: u16, payload: &[u8]) -> Result<WireMsg> {
    let mut d = Dec::new(payload);
    let msg = match ty {
        TY_HELLO => {
            let role = match d.u8()? {
                0 => Role::Coordinator,
                1 => Role::Worker,
                other => crate::bail!("unknown hello role {other}"),
            };
            WireMsg::Hello { role }
        }
        TY_ASSIGN => {
            let device = d.usize32()?;
            let n_active = d.usize32()?;
            let n_total = d.usize64()?;
            let negs = d.usize32()?;
            let seed = d.u64()?;
            let m_noise = d.f64()?;
            let n = d.count(4)?;
            let mut clusters = Vec::with_capacity(n);
            for _ in 0..n {
                clusters.push(d.u32()?);
            }
            WireMsg::Assign(Assignment { device, n_active, n_total, negs, seed, m_noise, clusters })
        }
        TY_ASSIGNED => WireMsg::Assigned {
            device: d.usize32()?,
            n_blocks: d.usize32()?,
            n_points: d.usize64()?,
        },
        TY_EPOCH => {
            let epoch = d.usize64()?;
            let lr = d.f32()?;
            let exaggeration = d.f32()?;
            let means = Arc::new(d.means()?);
            WireMsg::Cmd(DeviceCmd::Epoch { epoch, lr, exaggeration, means })
        }
        TY_EXPORT => WireMsg::Cmd(DeviceCmd::Export),
        TY_STOP => WireMsg::Cmd(DeviceCmd::Stop),
        TY_INGEST => {
            let n = d.count64(4)?;
            let mut positions = Vec::with_capacity(n);
            for _ in 0..n {
                positions.push(d.f32()?);
            }
            WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(positions) })
        }
        TY_EPOCH_DONE => {
            let device = d.usize32()?;
            let loss_sum = d.f64()?;
            let loss_weight = d.f64()?;
            let step_secs = d.f64()?;
            let flops = d.f64()?;
            let means = d.means()?;
            WireMsg::Reply(DeviceReply::EpochDone {
                device,
                means,
                loss_sum,
                loss_weight,
                step_secs,
                flops,
            })
        }
        TY_EXPORTED => {
            let device = d.usize32()?;
            let n = d.count64(12)?;
            let mut positions = Vec::with_capacity(n);
            for _ in 0..n {
                positions.push((d.u32()?, [d.f32()?, d.f32()?]));
            }
            WireMsg::Reply(DeviceReply::Exported { device, positions })
        }
        TY_INGESTED => WireMsg::Reply(DeviceReply::Ingested { device: d.usize32()? }),
        other => crate::bail!("unknown frame type {other}"),
    };
    d.done()?;
    Ok(msg)
}

/// Validated header fields: (msg type, payload length).
fn parse_header(h: &[u8; HEADER_BYTES]) -> Result<(u16, usize)> {
    ensure!(h[0..4] == MAGIC, "bad frame magic {:02x?}", &h[0..4]);
    let version = u16::from_le_bytes([h[4], h[5]]);
    ensure!(
        version == PROTO_VERSION,
        "protocol version mismatch: peer speaks v{version}, this build speaks v{PROTO_VERSION}"
    );
    let ty = u16::from_le_bytes([h[6], h[7]]);
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    ensure!(len <= MAX_PAYLOAD, "frame payload length {len} exceeds {MAX_PAYLOAD}");
    Ok((ty, len as usize))
}

/// Decode one complete frame from a byte slice (tests, fuzzing).  The
/// slice must hold exactly one frame — truncation and trailing bytes are
/// both errors.
pub fn decode(frame: &[u8]) -> Result<WireMsg> {
    ensure!(
        frame.len() >= HEADER_BYTES,
        "frame truncated: {} bytes, header needs {HEADER_BYTES}",
        frame.len()
    );
    let mut h = [0u8; HEADER_BYTES];
    h.copy_from_slice(&frame[..HEADER_BYTES]);
    let (ty, len) = parse_header(&h)?;
    let payload = &frame[HEADER_BYTES..];
    ensure!(
        payload.len() == len,
        "frame payload is {} bytes, header claims {len}",
        payload.len()
    );
    let want = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
    let got = frame_crc(ty, payload);
    ensure!(got == want, "frame crc mismatch: computed {got:08x}, header says {want:08x}");
    decode_payload(ty, payload)
}

/// Normalize an io error into the stable phrases `FaultKind::classify`
/// keys on: deadline expiry reads "timed out", a lost peer reads
/// "connection reset"/"connection closed", everything else keeps its own
/// message.  (`WouldBlock` is what a socket read timeout surfaces as on
/// unix; its Display text — "Resource temporarily unavailable" — says
/// nothing about deadlines, hence the rewrite.)
fn io_ctx(op: &str, e: std::io::Error) -> crate::util::error::Error {
    use std::io::ErrorKind as K;
    let what = match e.kind() {
        K::TimedOut | K::WouldBlock => "timed out".to_string(),
        K::ConnectionReset | K::ConnectionAborted | K::BrokenPipe | K::NotConnected => {
            "connection reset by peer".to_string()
        }
        K::UnexpectedEof => "connection closed mid-frame".to_string(),
        _ => e.to_string(),
    };
    crate::util::error::Error::msg(format!("{op}: {what}"))
}

/// Write one frame; returns the bytes written.
pub fn write_frame(w: &mut impl Write, msg: &WireMsg) -> Result<usize> {
    let frame = encode(msg);
    w.write_all(&frame).map_err(|e| io_ctx("write frame", e))?;
    Ok(frame.len())
}

/// Read one frame; returns the message and the bytes consumed.
pub fn read_frame(r: &mut impl Read) -> Result<(WireMsg, usize)> {
    let mut h = [0u8; HEADER_BYTES];
    r.read_exact(&mut h).map_err(|e| io_ctx("read frame header", e))?;
    let (ty, len) = parse_header(&h)?;
    // the header's length field passed the MAX_PAYLOAD bound, but a hostile
    // peer can still claim far more than it sends — grow the buffer as the
    // bytes actually arrive instead of trusting the claim up front
    let mut payload = Vec::with_capacity(len.min(1 << 20));
    let took = r
        .take(len as u64)
        .read_to_end(&mut payload)
        .map_err(|e| io_ctx("read frame payload", e))?;
    ensure!(took == len, "read frame payload: connection closed mid-frame ({took} of {len} bytes)");
    let want = u32::from_le_bytes([h[12], h[13], h[14], h[15]]);
    let got = frame_crc(ty, &payload);
    ensure!(got == want, "frame crc mismatch: computed {got:08x}, header says {want:08x}");
    let msg = decode_payload(ty, &payload)?;
    Ok((msg, HEADER_BYTES + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn sample_msgs() -> Vec<WireMsg> {
        let means = vec![
            MeanEntry { cluster_id: 0, mean: [1.5, -2.25], weight: 0.125 },
            MeanEntry { cluster_id: 7, mean: [-0.0, f32::MIN_POSITIVE], weight: 3.0 },
        ];
        vec![
            WireMsg::Hello { role: Role::Coordinator },
            WireMsg::Hello { role: Role::Worker },
            WireMsg::Assign(Assignment {
                device: 3,
                n_active: 2,
                n_total: 100_000,
                negs: 8,
                seed: u64::MAX,
                m_noise: 5.5,
                clusters: vec![9, 4, 17],
            }),
            WireMsg::Assigned { device: 3, n_blocks: 3, n_points: 41_234 },
            WireMsg::Cmd(DeviceCmd::Epoch {
                epoch: 123,
                lr: 0.75,
                exaggeration: 4.0,
                means: Arc::new(means.clone()),
            }),
            WireMsg::Cmd(DeviceCmd::Export),
            WireMsg::Cmd(DeviceCmd::Ingest {
                positions: Arc::new(vec![0.0, -1.5, f32::NAN, 1.0e-38]),
            }),
            WireMsg::Cmd(DeviceCmd::Stop),
            WireMsg::Reply(DeviceReply::EpochDone {
                device: 1,
                means,
                loss_sum: -123.456,
                loss_weight: 99.5,
                step_secs: 0.001,
                flops: 1.0e12,
            }),
            WireMsg::Reply(DeviceReply::Exported {
                device: 0,
                positions: vec![(0, [1.0, 2.0]), (42, [-3.5, 0.0])],
            }),
            WireMsg::Reply(DeviceReply::Ingested { device: 5 }),
        ]
    }

    /// NaN-tolerant structural equality (PartialEq is false for NaN floats,
    /// but the wire must still round-trip their bits exactly).
    fn bits_equal(a: &WireMsg, b: &WireMsg) -> bool {
        encode(a) == encode(b)
    }

    #[test]
    fn roundtrip_every_variant() {
        for msg in sample_msgs() {
            let frame = encode(&msg);
            let back = decode(&frame).unwrap();
            assert!(bits_equal(&msg, &back), "{msg:?} != {back:?}");
        }
    }

    #[test]
    fn frame_len_matches_encoding() {
        for msg in sample_msgs() {
            assert_eq!(frame_len(&msg), encode(&msg).len(), "{msg:?}");
        }
    }

    #[test]
    fn stream_roundtrip_back_to_back_frames() {
        let msgs = sample_msgs();
        let mut buf = Vec::new();
        for m in &msgs {
            write_frame(&mut buf, m).unwrap();
        }
        let mut r = &buf[..];
        for m in &msgs {
            let (back, n) = read_frame(&mut r).unwrap();
            assert!(bits_equal(m, &back));
            assert_eq!(n, frame_len(m));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_anywhere_is_an_error_not_a_panic() {
        for msg in sample_msgs() {
            let frame = encode(&msg);
            for cut in 0..frame.len() {
                assert!(decode(&frame[..cut]).is_err(), "cut at {cut} must fail");
            }
        }
    }

    #[test]
    fn single_bit_corruption_is_detected() {
        // flip one bit in every byte position; header corruption trips the
        // magic/version/length checks, payload corruption trips the crc
        let msg = &sample_msgs()[2];
        let frame = encode(msg);
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0x01;
            match decode(&bad) {
                Err(_) => {}
                Ok(back) => {
                    // a flipped bit that still decodes must not decode to
                    // the original (e.g. impossible here, but be explicit)
                    panic!("corrupt byte {i} decoded as {back:?}");
                }
            }
        }
    }

    #[test]
    fn wrong_version_is_rejected_with_both_versions_named() {
        let mut frame = encode(&WireMsg::Cmd(DeviceCmd::Stop));
        frame[4] = 2; // version 2
        let e = decode(&frame).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
        assert!(e.contains('2') && e.contains('1'), "{e}");
    }

    #[test]
    fn unknown_type_and_bad_magic_rejected() {
        let mut frame = encode(&WireMsg::Cmd(DeviceCmd::Export));
        frame[6] = 0xEE;
        frame[7] = 0xEE;
        // the crc covers the type, so the raw edit trips it...
        assert!(decode(&frame).unwrap_err().to_string().contains("crc"));
        // ...and with a consistent crc the type check must fire
        frame[12..16].copy_from_slice(&frame_crc(0xEEEE, &[]).to_le_bytes());
        assert!(decode(&frame).unwrap_err().to_string().contains("type"));

        let mut frame = encode(&WireMsg::Cmd(DeviceCmd::Export));
        frame[0] = b'X';
        assert!(decode(&frame).unwrap_err().to_string().contains("magic"));
    }

    #[test]
    fn absurd_length_rejected_before_allocation() {
        let mut frame = encode(&WireMsg::Cmd(DeviceCmd::Stop));
        frame[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode(&frame).is_err());
        // and through the streaming reader too
        let mut r = &frame[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn corrupt_interior_count_rejected() {
        // Epoch payload: the means count lives after epoch+lr+exag; blow it
        // up without fixing the crc -> crc catches it; fix the crc -> the
        // count/remaining-bytes check catches it
        let msg = WireMsg::Cmd(DeviceCmd::Epoch {
            epoch: 1,
            lr: 0.5,
            exaggeration: 1.0,
            means: Arc::new(vec![MeanEntry { cluster_id: 0, mean: [0.0, 0.0], weight: 1.0 }]),
        });
        let mut frame = encode(&msg);
        let count_off = HEADER_BYTES + 8 + 4 + 4;
        frame[count_off..count_off + 4].copy_from_slice(&1_000_000u32.to_le_bytes());
        assert!(decode(&frame).is_err(), "crc must catch the edit");
        let fixed_crc = frame_crc(TY_EPOCH, &frame[HEADER_BYTES..]);
        frame[12..16].copy_from_slice(&fixed_crc.to_le_bytes());
        let e = decode(&frame).unwrap_err().to_string();
        assert!(e.contains("count") || e.contains("truncated"), "{e}");
    }

    #[test]
    fn trailing_payload_bytes_rejected() {
        let msg = WireMsg::Reply(DeviceReply::Ingested { device: 2 });
        let mut frame = encode(&msg);
        frame.extend_from_slice(&[0u8; 4]);
        // header now disagrees with the slice length
        assert!(decode(&frame).is_err());
        // make the header agree and fix the crc: the payload decoder must
        // still reject the 4 unconsumed bytes
        let len = (frame.len() - HEADER_BYTES) as u32;
        frame[8..12].copy_from_slice(&len.to_le_bytes());
        let fixed_crc = frame_crc(TY_INGESTED, &frame[HEADER_BYTES..]);
        frame[12..16].copy_from_slice(&fixed_crc.to_le_bytes());
        let e = decode(&frame).unwrap_err().to_string();
        assert!(e.contains("trailing"), "{e}");
    }

    #[test]
    fn floats_roundtrip_bitwise() {
        let weird = vec![0.1f32, -0.0, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, 1.0e-45];
        let msg = WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(weird.clone()) });
        match decode(&encode(&msg)).unwrap() {
            WireMsg::Cmd(DeviceCmd::Ingest { positions }) => {
                assert_eq!(positions.len(), weird.len());
                for (a, b) in positions.iter().zip(&weird) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant {other:?}"),
        }
    }
}
