//! Message transports: in-process channel pair and framed socket streams.
//!
//! The coordinator's epoch loop speaks [`WireMsg`] over a [`Transport`]
//! and never learns which one it got:
//!
//! * [`channel_pair`] — two crossed mpsc channels.  Messages move without
//!   serialization (the simulated multi-device mode), but every send/recv
//!   still accounts the exact frame bytes the message *would* occupy on a
//!   socket ([`proto::frame_len`]), so `CommStats` wire-byte numbers are
//!   comparable across placements.
//! * [`FramedTransport`] — a real byte stream (TCP or Unix socket) framed
//!   by [`proto`]; counts the bytes actually written/read.
//!
//! Both transports also publish per-message-type frame counts, bytes,
//! and call latency to the obs registry (DESIGN.md §15), so `/metrics`
//! and the distributed bench report from the same accounting the
//! `CommStats` totals are built on.
//!
//! [`Endpoint`] parses the CLI's worker address syntax (`host:port`, or
//! `unix:/path/to.sock`) and [`connect`] dials it with retry, so a
//! coordinator can race worker startup in CI without a sleep-loop script.

use super::fault::{FaultInjector, FaultPlan};
use super::proto::{self, Role, WireMsg};
use crate::obs::metrics;
use crate::util::clock::{self, Stopwatch};
use crate::util::error::{Context, Error, Result};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// A bidirectional, ordered, reliable message pipe.
pub trait Transport: Send {
    fn send(&mut self, msg: WireMsg) -> Result<()>;
    /// Blocking receive of the next message.
    fn recv(&mut self) -> Result<WireMsg>;
    /// Deadlines for subsequent operations; `None` blocks forever (the
    /// default).  An expired deadline surfaces as an error whose text
    /// contains "timed out" (see `FaultKind::classify`).
    fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()>;
    /// Cumulative frame bytes sent (real or would-be).
    fn bytes_sent(&self) -> u64;
    /// Cumulative frame bytes received (real or would-be).
    fn bytes_received(&self) -> u64;
}

/// A raw byte stream a [`FramedTransport`] (or a
/// [`FaultInjector`]) can frame: read/write plus kernel-level deadline
/// control.  Implemented for [`TcpStream`] and `UnixStream`.
pub trait WireStream: Read + Write + Send {
    /// Apply read/write timeouts to the underlying descriptor.  `None`
    /// blocks forever; zero durations are clamped up (the OS rejects 0).
    fn set_stream_timeouts(
        &mut self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()>;
}

/// The smallest timeout the OS accepts (`set_read_timeout(Some(0))` is an
/// error by contract); an already-expired deadline becomes this.
fn clamp_timeout(d: Option<Duration>) -> Option<Duration> {
    d.map(|d| d.max(Duration::from_millis(1)))
}

impl WireStream for TcpStream {
    fn set_stream_timeouts(
        &mut self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()> {
        self.set_read_timeout(clamp_timeout(read))
            .map_err(|e| Error::msg(format!("set read timeout: {e}")))?;
        self.set_write_timeout(clamp_timeout(write))
            .map_err(|e| Error::msg(format!("set write timeout: {e}")))
    }
}

#[cfg(unix)]
impl WireStream for std::os::unix::net::UnixStream {
    fn set_stream_timeouts(
        &mut self,
        read: Option<Duration>,
        write: Option<Duration>,
    ) -> Result<()> {
        self.set_read_timeout(clamp_timeout(read))
            .map_err(|e| Error::msg(format!("set read timeout: {e}")))?;
        self.set_write_timeout(clamp_timeout(write))
            .map_err(|e| Error::msg(format!("set write timeout: {e}")))
    }
}

/// Record one completed frame move on the obs registry (DESIGN.md §15):
/// count, bytes, and time in the transport call, labeled by direction and
/// message type.  Telemetry only — values flow out of the transport, never
/// back into it.
fn account_frame(dir: &'static str, kind: &'static str, bytes: u64, secs: f64) {
    if !metrics::enabled() {
        return;
    }
    let labels = &[("dir", dir), ("type", kind)];
    metrics::counter(
        "nomad_frames_total",
        "Wire frames moved, by direction and message type.",
        labels,
    )
    .inc();
    metrics::counter(
        "nomad_frame_bytes_total",
        "Wire frame bytes moved (real or would-be), by direction and message type.",
        labels,
    )
    .add(bytes);
    metrics::histogram(
        "nomad_frame_seconds",
        "Time spent inside transport send/recv calls.",
        &metrics::DURATION_BUCKETS_S,
        labels,
    )
    .observe(secs);
}

// ------------------------------------------------------------- channels

/// One end of an in-process transport (see [`channel_pair`]).
pub struct ChannelTransport {
    tx: Sender<WireMsg>,
    rx: Receiver<WireMsg>,
    read_timeout: Option<Duration>,
    sent: u64,
    received: u64,
}

/// Two crossed unbounded channels: what end A sends, end B receives.
pub fn channel_pair() -> (ChannelTransport, ChannelTransport) {
    let (a_tx, b_rx) = std::sync::mpsc::channel();
    let (b_tx, a_rx) = std::sync::mpsc::channel();
    (
        ChannelTransport { tx: a_tx, rx: a_rx, read_timeout: None, sent: 0, received: 0 },
        ChannelTransport { tx: b_tx, rx: b_rx, read_timeout: None, sent: 0, received: 0 },
    )
}

impl Transport for ChannelTransport {
    fn send(&mut self, msg: WireMsg) -> Result<()> {
        let t0 = Stopwatch::start();
        let kind = proto::msg_kind(&msg);
        let bytes = proto::frame_len(&msg) as u64;
        self.sent += bytes;
        self.tx.send(msg).ok().context("channel transport: peer hung up")?;
        account_frame("send", kind, bytes, t0.secs());
        Ok(())
    }

    fn recv(&mut self) -> Result<WireMsg> {
        let t0 = Stopwatch::start();
        let msg = match self.read_timeout {
            None => self.rx.recv().ok().context("channel transport: peer hung up")?,
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(m) => m,
                Err(RecvTimeoutError::Timeout) => crate::bail!(
                    "channel transport: recv timed out after {:.3}s",
                    d.as_secs_f64()
                ),
                Err(RecvTimeoutError::Disconnected) => {
                    crate::bail!("channel transport: peer hung up")
                }
            },
        };
        let bytes = proto::frame_len(&msg) as u64;
        self.received += bytes;
        account_frame("recv", proto::msg_kind(&msg), bytes, t0.secs());
        Ok(msg)
    }

    fn set_timeouts(&mut self, read: Option<Duration>, _write: Option<Duration>) -> Result<()> {
        // sends on an unbounded channel cannot block, so only the read
        // side has a deadline to honour
        self.read_timeout = read;
        Ok(())
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// -------------------------------------------------------------- streams

/// A [`Transport`] over a socket byte stream ([`WireStream`]), using the
/// length-prefixed frames of [`proto`].
pub struct FramedTransport<S: WireStream> {
    stream: S,
    sent: u64,
    received: u64,
}

impl<S: WireStream> FramedTransport<S> {
    pub fn new(stream: S) -> FramedTransport<S> {
        FramedTransport { stream, sent: 0, received: 0 }
    }
}

impl<S: WireStream> Transport for FramedTransport<S> {
    fn send(&mut self, msg: WireMsg) -> Result<()> {
        let t0 = Stopwatch::start();
        let kind = proto::msg_kind(&msg);
        let n = proto::write_frame(&mut self.stream, &msg)?;
        self.stream
            .flush()
            .map_err(|e| Error::msg(format!("flush frame: {e}")))?;
        self.sent += n as u64;
        account_frame("send", kind, n as u64, t0.secs());
        Ok(())
    }

    fn recv(&mut self) -> Result<WireMsg> {
        let t0 = Stopwatch::start();
        let (msg, n) = proto::read_frame(&mut self.stream)?;
        self.received += n as u64;
        account_frame("recv", proto::msg_kind(&msg), n as u64, t0.secs());
        Ok(msg)
    }

    fn set_timeouts(&mut self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        self.stream.set_stream_timeouts(read, write)
    }

    fn bytes_sent(&self) -> u64 {
        self.sent
    }

    fn bytes_received(&self) -> u64 {
        self.received
    }
}

// ------------------------------------------------------------ endpoints

/// A worker address: `host:port` (TCP) or `unix:/path/to.sock`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    Tcp(String),
    #[cfg(unix)]
    Unix(std::path::PathBuf),
}

impl Endpoint {
    pub fn parse(spec: &str) -> Result<Endpoint> {
        if let Some(path) = spec.strip_prefix("unix:") {
            #[cfg(unix)]
            {
                crate::ensure!(!path.is_empty(), "empty unix socket path in '{spec}'");
                return Ok(Endpoint::Unix(std::path::PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            crate::bail!("unix socket endpoints are not supported on this platform");
        }
        crate::ensure!(
            spec.contains(':'),
            "worker endpoint '{spec}' is neither host:port nor unix:/path"
        );
        Ok(Endpoint::Tcp(spec.to_string()))
    }
}

impl std::fmt::Display for Endpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Endpoint::Tcp(a) => write!(f, "{a}"),
            #[cfg(unix)]
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
        }
    }
}

/// First retry delay of [`connect`]'s backoff schedule.
const BACKOFF_FIRST: Duration = Duration::from_millis(25);
/// Backoff delays double up to this cap.
const BACKOFF_CAP: Duration = Duration::from_millis(800);

/// Wrap a freshly-dialed stream: plain framing, or fault-injected framing
/// when a test scripted a [`FaultPlan`] for this link.
fn wrap_stream<S: WireStream + 'static>(
    stream: S,
    plan: Option<&FaultPlan>,
) -> Box<dyn Transport> {
    match plan {
        Some(p) => Box::new(FaultInjector::new(stream, p.clone(), "coordinator")),
        None => Box::new(FramedTransport::new(stream)),
    }
}

/// Dial a worker, retrying until `patience` runs out — worker processes
/// launched in parallel with the coordinator (the CI smoke job) need a
/// moment to bind their listeners.  Retries follow a deterministic capped
/// exponential backoff (25 ms doubling to 800 ms); a zero `patience`
/// means exactly one attempt.  The final error names the attempt count.
pub fn connect(ep: &Endpoint, patience: Duration) -> Result<Box<dyn Transport>> {
    connect_with(ep, patience, None)
}

/// [`connect`] with an optional coordinator-side [`FaultPlan`] applied to
/// the resulting link (fault-injection tests only).
pub fn connect_with(
    ep: &Endpoint,
    patience: Duration,
    plan: Option<&FaultPlan>,
) -> Result<Box<dyn Transport>> {
    let t0 = Stopwatch::start();
    let by = clock::deadline_in(Some(patience)).expect("some timeout gives some deadline");
    let mut backoff = BACKOFF_FIRST;
    let mut attempts: u32 = 0;
    loop {
        attempts += 1;
        let attempt: Result<Box<dyn Transport>> = match ep {
            Endpoint::Tcp(addr) => TcpStream::connect(addr.as_str())
                .map_err(Error::msg)
                .map(|s| {
                    let _ = s.set_nodelay(true);
                    wrap_stream(s, plan)
                }),
            #[cfg(unix)]
            Endpoint::Unix(path) => std::os::unix::net::UnixStream::connect(path)
                .map_err(Error::msg)
                .map(|s| wrap_stream(s, plan)),
        };
        match attempt {
            Ok(t) => return Ok(t),
            Err(_) if !clock::expired(by) => {
                std::thread::sleep(backoff.min(clock::remaining_until(by)));
                backoff = (backoff * 2).min(BACKOFF_CAP);
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!(
                        "connect to worker at {ep} after {attempts} attempt(s) over {:.1}s",
                        t0.secs()
                    )
                });
            }
        }
    }
}

// ------------------------------------------------------------ handshake

/// Coordinator side of the version/role handshake: say hello, expect a
/// worker back.  Any version mismatch already failed in the frame header.
pub fn coordinator_handshake(t: &mut dyn Transport) -> Result<()> {
    t.send(WireMsg::Hello { role: Role::Coordinator })?;
    match t.recv()? {
        WireMsg::Hello { role: Role::Worker } => Ok(()),
        other => crate::bail!("handshake: expected worker hello, got {other:?}"),
    }
}

/// Worker side: expect the coordinator's hello, answer with ours.
pub fn worker_handshake(t: &mut dyn Transport) -> Result<()> {
    match t.recv()? {
        WireMsg::Hello { role: Role::Coordinator } => {}
        other => crate::bail!("handshake: expected coordinator hello, got {other:?}"),
    }
    t.send(WireMsg::Hello { role: Role::Worker })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distributed::device::DeviceCmd;
    use crate::distributed::MeanEntry;
    use std::sync::Arc;
    use std::time::Instant;

    fn epoch_msg() -> WireMsg {
        WireMsg::Cmd(DeviceCmd::Epoch {
            epoch: 3,
            lr: 0.5,
            exaggeration: 1.0,
            means: Arc::new(vec![MeanEntry { cluster_id: 1, mean: [0.5, -0.5], weight: 2.0 }]),
        })
    }

    #[test]
    fn channel_pair_moves_messages_and_counts_frame_bytes() {
        let (mut a, mut b) = channel_pair();
        let msg = epoch_msg();
        let want = proto::frame_len(&msg) as u64;
        a.send(msg.clone()).unwrap();
        let got = b.recv().unwrap();
        assert_eq!(got, msg);
        assert_eq!(a.bytes_sent(), want);
        assert_eq!(b.bytes_received(), want);
        assert_eq!(a.bytes_received(), 0);

        b.send(WireMsg::Reply(crate::distributed::device::DeviceReply::Ingested {
            device: 0,
        }))
        .unwrap();
        a.recv().unwrap();
        assert_eq!(a.bytes_received(), b.bytes_sent());
    }

    #[test]
    fn dropped_peer_is_an_error_not_a_panic() {
        let (mut a, b) = channel_pair();
        drop(b);
        assert!(a.send(epoch_msg()).is_err());
        assert!(a.recv().is_err());
    }

    #[test]
    fn framed_tcp_roundtrip_counts_real_bytes() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = FramedTransport::new(s);
            let msg = t.recv().unwrap();
            t.send(msg).unwrap(); // echo
            (t.bytes_sent(), t.bytes_received())
        });
        let mut c = FramedTransport::new(TcpStream::connect(addr).unwrap());
        let msg = epoch_msg();
        let want = proto::frame_len(&msg) as u64;
        c.send(msg.clone()).unwrap();
        let back = c.recv().unwrap();
        assert_eq!(back, msg);
        assert_eq!(c.bytes_sent(), want);
        assert_eq!(c.bytes_received(), want);
        let (srv_sent, srv_recv) = server.join().unwrap();
        assert_eq!((srv_sent, srv_recv), (want, want));
    }

    #[cfg(unix)]
    #[test]
    fn framed_unix_socket_roundtrip_and_handshake() {
        let dir = std::env::temp_dir().join("nomad_transport_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("hs_{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let listener = std::os::unix::net::UnixListener::bind(&path).unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = FramedTransport::new(s);
            worker_handshake(&mut t).unwrap();
            matches!(t.recv().unwrap(), WireMsg::Cmd(DeviceCmd::Stop))
        });
        let ep = Endpoint::parse(&format!("unix:{}", path.display())).unwrap();
        let mut c = connect(&ep, Duration::from_secs(5)).unwrap();
        coordinator_handshake(&mut *c).unwrap();
        c.send(WireMsg::Cmd(DeviceCmd::Stop)).unwrap();
        assert!(server.join().unwrap());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn endpoint_parsing() {
        let tcp = Endpoint::parse("127.0.0.1:9000").unwrap();
        assert_eq!(tcp, Endpoint::Tcp("127.0.0.1:9000".into()));
        assert!(Endpoint::parse("no-port-here").is_err());
        #[cfg(unix)]
        {
            assert_eq!(
                Endpoint::parse("unix:/tmp/w0.sock").unwrap(),
                Endpoint::Unix("/tmp/w0.sock".into())
            );
            assert!(Endpoint::parse("unix:").is_err());
        }
    }

    #[test]
    fn connect_gives_up_after_patience() {
        // a port from the dynamic range with nothing listening; patience of
        // zero means exactly one attempt
        let ep = Endpoint::Tcp("127.0.0.1:1".into());
        let t0 = Instant::now();
        assert!(connect(&ep, Duration::from_millis(0)).is_err());
        assert!(t0.elapsed() < Duration::from_secs(30));
    }

    #[test]
    fn connect_error_reports_the_attempt_count() {
        let ep = Endpoint::Tcp("127.0.0.1:1".into());
        let err = connect(&ep, Duration::from_millis(60)).unwrap_err().to_string();
        assert!(err.contains("attempt"), "no attempt count in: {err}");
        assert!(err.contains("127.0.0.1:1"), "no endpoint in: {err}");
    }

    #[test]
    fn channel_recv_honours_the_read_deadline() {
        let (mut a, _b) = channel_pair();
        a.set_timeouts(Some(Duration::from_millis(20)), None).unwrap();
        let err = a.recv().unwrap_err().to_string();
        assert!(err.contains("timed out"), "not a timeout error: {err}");
        // clearing the deadline goes back to blocking mode — verified by
        // a peerless recv reporting the hangup instead of a timeout
        drop(_b);
        a.set_timeouts(None, None).unwrap();
        let err = a.recv().unwrap_err().to_string();
        assert!(err.contains("hung up"), "not a hangup error: {err}");
    }
}
