//! Device worker: a long-lived thread owning a shard of cluster blocks.
//!
//! Each worker owns its own [`StepBackend`] instance, created *inside* the
//! thread (the XLA backend wraps a PJRT client, which is not `Send` — and a
//! real multi-GPU deployment gives each device its own PJRT client anyway).
//! Communication with the leader is over a [`Transport`] carrying
//! [`WireMsg`] frames: the epoch broadcast (learning rate + the
//! all-gathered means table) and the per-epoch gather (fresh local means +
//! loss + timing).  The same [`run_device_loop`] serves an in-process
//! channel transport ([`spawn_device`]) and a `nomad worker` process's
//! socket ([`super::worker`]).
//!
//! # Intra-device parallelism
//!
//! When the backend is thread-safe ([`StepBackend::as_sync`]) the epoch
//! loop steps the device's blocks concurrently with
//! [`par_map_mut`](crate::util::parallel::par_map_mut) (dynamic chunking —
//! blocks are ragged), splitting the machine's worker budget between the
//! block level and the head loop inside each step.  Every block draws its
//! negatives from an RNG forked deterministically from
//! `(device seed, epoch, block index)`, so results are identical from run
//! to run and independent of both the thread count and the scheduling
//! order.  The worker budget is `NOMAD_THREADS` (or the machine's
//! parallelism) divided by the number of devices that actually own blocks
//! ([`intra_device_budget`]) — empty shards (a `n_devices > n_clusters`
//! run) hold no share — so a multi-device simulation neither oversubscribes
//! the host nor idles workers on do-nothing device threads.

use super::proto::WireMsg;
use super::transport::{channel_pair, Transport};
use super::MeanEntry;
use crate::embed::{ClusterBlock, StepBackend, StepInputs};
use crate::obs::trace::{self, NO_BLOCK};
use crate::util::clock::{self, Stopwatch};
use crate::util::error::Result;
use crate::util::parallel::{num_threads, par_map_mut};
use crate::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

/// Leader -> device commands.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceCmd {
    /// Run one epoch over all local blocks.
    Epoch {
        /// absolute epoch index — the block RNG forks from
        /// `(device, epoch, block)`, so a resumed run that starts at
        /// epoch `e` draws exactly the streams the uninterrupted run
        /// would have drawn (DESIGN.md §11)
        epoch: usize,
        lr: f32,
        /// attractive-weight multiplier (early exaggeration; 1.0 = off)
        exaggeration: f32,
        /// full means table (every cluster in the run)
        means: Arc<Vec<MeanEntry>>,
    },
    /// Export (global_id, position) for every real point — snapshots,
    /// checkpoints, and the final collection.  Read-only.
    Export,
    /// Overwrite local block positions from a full n x 2 table indexed by
    /// global id (checkpoint resume).  Replies [`DeviceReply::Ingested`]
    /// so the leader can barrier before the first epoch.
    Ingest { positions: Arc<Vec<f32>> },
    /// Shut down.
    Stop,
}

/// Device -> leader replies.
#[derive(Clone, Debug, PartialEq)]
pub enum DeviceReply {
    EpochDone {
        device: usize,
        /// fresh means of the local clusters
        means: Vec<MeanEntry>,
        /// sum of block losses weighted by block valid counts
        loss_sum: f64,
        loss_weight: f64,
        /// pure step compute time
        step_secs: f64,
        /// force-kernel FLOPs executed this epoch (for the cost model)
        flops: f64,
    },
    Exported {
        device: usize,
        positions: Vec<(u32, [f32; 2])>,
    },
    Ingested {
        device: usize,
    },
}

/// The leader's end of one device's [`Transport`] — the same struct
/// whether the device is an in-process thread (then `join` holds its
/// handle) or a remote worker process (then `join` is `None`).
pub struct DeviceLink {
    pub device: usize,
    pub transport: Box<dyn Transport>,
    pub join: Option<std::thread::JoinHandle<()>>,
    /// steady-state read/write deadline the link reverts to after a
    /// [`recv_reply_by`](DeviceLink::recv_reply_by) tightens it
    pub io_timeout: Option<std::time::Duration>,
}

impl DeviceLink {
    pub fn send_cmd(&mut self, cmd: DeviceCmd) -> Result<()> {
        self.transport.send(WireMsg::Cmd(cmd))
    }

    /// Blocking receive of the device's next reply.
    pub fn recv_reply(&mut self) -> Result<DeviceReply> {
        match self.transport.recv()? {
            WireMsg::Reply(r) => Ok(r),
            other => crate::bail!("device {}: expected a reply, got {other:?}", self.device),
        }
    }

    /// Receive the next reply before the absolute deadline `by`, however
    /// much of it is left — the coordinator's epoch barrier is one shared
    /// deadline, not a fresh per-device allowance.  Restores the link's
    /// steady-state timeout afterwards.
    pub fn recv_reply_by(&mut self, by: Instant) -> Result<DeviceReply> {
        let remaining = clock::remaining_until(by);
        if remaining.is_zero() {
            crate::bail!("device {}: epoch deadline expired (recv timed out)", self.device);
        }
        self.transport.set_timeouts(Some(remaining), None)?;
        let out = self.recv_reply();
        // best-effort restore; a link whose reset fails is about to be
        // torn down by the error path anyway
        let _ = self.transport.set_timeouts(self.io_timeout, self.io_timeout);
        out
    }

    /// Total frame bytes moved over this link, both directions.
    pub fn wire_bytes(&self) -> u64 {
        self.transport.bytes_sent() + self.transport.bytes_received()
    }

    /// Send `Stop` and reap the worker thread (remote workers just see the
    /// connection close after the `Stop` frame).  Errors are ignored: a
    /// device that already hung up is already stopped.
    pub fn stop(&mut self) {
        let _ = self.send_cmd(DeviceCmd::Stop);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

/// Split the host's worker threads across the devices that actually own
/// blocks.  `active_devices` must count only non-empty shards: a device
/// with no blocks does no step work, so giving it a share (as dividing by
/// the *spawned* count would) just idles workers whenever
/// `n_devices > n_clusters`.
pub fn intra_device_budget(total_threads: usize, active_devices: usize) -> usize {
    (total_threads / active_devices.max(1)).max(1)
}

/// Spawn a device worker.
///
/// `make_backend` runs once inside the worker thread to build the step
/// backend (native, or XLA with a thread-private PJRT client).
/// `n_active_devices` is the number of simulated devices that own at least
/// one block, used to split the host's worker threads fairly across the
/// device threads that have work.
pub fn spawn_device(
    device: usize,
    mut blocks: Vec<ClusterBlock>,
    n_total: usize,
    m_noise: f64,
    seed: u64,
    n_active_devices: usize,
    make_backend: Box<dyn FnOnce() -> Box<dyn StepBackend> + Send>,
) -> DeviceLink {
    let (leader_end, mut device_end) = channel_pair();
    let join = std::thread::Builder::new()
        .name(format!("nomad-dev{device}"))
        .spawn(move || {
            let backend = make_backend();
            // a transport error here means the leader hung up (normal when
            // the coordinator unwinds early) — nothing useful to report
            let _ = run_device_loop(
                device,
                &mut blocks,
                n_total,
                m_noise,
                seed,
                n_active_devices,
                &*backend,
                &mut device_end,
            );
        })
        .expect("spawn device thread");
    DeviceLink { device, transport: Box::new(leader_end), join: Some(join), io_timeout: None }
}

/// The device-side command loop, shared **verbatim** between in-process
/// threads ([`spawn_device`]) and `nomad worker` processes
/// ([`super::worker`]) — running the same code over either transport is
/// what makes multi-process runs bitwise identical to in-process runs.
///
/// Returns on `Stop` (Ok) or on a transport error (leader hung up).
#[allow(clippy::too_many_arguments)]
pub fn run_device_loop(
    device: usize,
    blocks: &mut [ClusterBlock],
    n_total: usize,
    m_noise: f64,
    seed: u64,
    n_active_devices: usize,
    backend: &dyn StepBackend,
    transport: &mut dyn Transport,
) -> Result<()> {
    // root of this device's RNG tree; never advanced, only forked
    // per (epoch, block) so neither stepping order nor the epoch a
    // run (re)starts at can change results
    let rng_root = Rng::new(seed).fork(device as u64 + 1);

    loop {
        let cmd = match transport.recv()? {
            WireMsg::Cmd(cmd) => cmd,
            other => crate::bail!("device {device}: expected a command, got {other:?}"),
        };
        match cmd {
            DeviceCmd::Stop => return Ok(()),
            DeviceCmd::Export => {
                let mut positions = Vec::new();
                for b in blocks.iter() {
                    for (l, &g) in b.global_ids.iter().enumerate() {
                        positions.push((g, [b.pos[l * 2], b.pos[l * 2 + 1]]));
                    }
                }
                transport.send(WireMsg::Reply(DeviceReply::Exported { device, positions }))?;
            }
            DeviceCmd::Ingest { positions } => {
                for b in blocks.iter_mut() {
                    for (l, &g) in b.global_ids.iter().enumerate() {
                        let g = g as usize;
                        b.pos[l * 2] = positions[g * 2];
                        b.pos[l * 2 + 1] = positions[g * 2 + 1];
                    }
                }
                transport.send(WireMsg::Reply(DeviceReply::Ingested { device }))?;
            }
            DeviceCmd::Epoch { epoch, lr, exaggeration, means } => {
                let budget = intra_device_budget(num_threads(), n_active_devices);
                let eroot = rng_root.fork(epoch as u64);
                let t0 = Stopwatch::start();

                // (weighted loss, weight, flops) per block, in order
                let _step_span = trace::span(device as i64, epoch as u64, NO_BLOCK, "step");
                let results: Vec<(f64, f64, f64)> = match backend.as_sync() {
                    Some(shared) if budget > 1 && blocks.len() > 1 => {
                        let block_threads = budget.min(blocks.len());
                        let step_threads = (budget / block_threads).max(1);
                        par_map_mut(blocks, block_threads, |bi, b| {
                            let _sp =
                                trace::span(device as i64, epoch as u64, bi as i64, "block_step");
                            let mut brng = eroot.fork(bi as u64);
                            step_block(
                                shared,
                                b,
                                lr,
                                exaggeration,
                                &means,
                                &mut brng,
                                step_threads,
                            )
                        })
                    }
                    _ => blocks
                        .iter_mut()
                        .enumerate()
                        .map(|(bi, b)| {
                            let _sp =
                                trace::span(device as i64, epoch as u64, bi as i64, "block_step");
                            let mut brng = eroot.fork(bi as u64);
                            step_block(backend, b, lr, exaggeration, &means, &mut brng, budget)
                        })
                        .collect(),
                };
                drop(_step_span);

                let mut loss_sum = 0.0f64;
                let mut loss_weight = 0.0f64;
                let mut flops = 0.0f64;
                for (ls, lw, fl) in &results {
                    loss_sum += *ls;
                    loss_weight += *lw;
                    flops += *fl;
                }
                let step_secs = t0.secs();
                let fresh: Vec<MeanEntry> = blocks
                    .iter()
                    .map(|b| MeanEntry {
                        cluster_id: b.cluster_id,
                        mean: b.mean(),
                        weight: b.mean_weight(n_total, m_noise),
                    })
                    .collect();
                transport.send(WireMsg::Reply(DeviceReply::EpochDone {
                    device,
                    means: fresh,
                    loss_sum,
                    loss_weight,
                    step_secs,
                    flops,
                }))?;
                // EpochDone is this device's epoch barrier — spill the
                // thread-local span buffer to the shared sink here
                trace::flush_thread();
            }
        }
    }
}

/// Step one block: build its remote-means view, apply (cached) early
/// exaggeration, run the backend, restore the weights.  Returns
/// `(weighted loss, weight, flops)`.
fn step_block<B: StepBackend + ?Sized>(
    backend: &B,
    b: &mut ClusterBlock,
    lr: f32,
    exaggeration: f32,
    means: &[MeanEntry],
    rng: &mut Rng,
    threads: usize,
) -> (f64, f64, f64) {
    // remote view, SoA for the gather engine's mean microkernel: every
    // cluster except this block's.  Zero-weight entries contribute exactly
    // nothing to the negative mass or the repulsion, so they are dropped
    // here — under `ApproxMode::None` every weight is 0.0 and the per-head
    // O(R) mean pass vanishes instead of being paid for nothing.
    let cap = means.len().saturating_sub(1);
    let mut meanx_buf: Vec<f32> = Vec::with_capacity(cap);
    let mut meany_buf: Vec<f32> = Vec::with_capacity(cap);
    let mut meanw_buf: Vec<f32> = Vec::with_capacity(cap);
    for e in means {
        if e.cluster_id != b.cluster_id && e.weight != 0.0 {
            meanx_buf.push(e.mean[0]);
            meany_buf.push(e.mean[1]);
            meanw_buf.push(e.weight);
        }
    }

    // early exaggeration: swap in a cached scaled copy of the attractive
    // weights for this step; the cache is tagged with the multiplier it was
    // built from and rebuilt whenever the (possibly annealed) factor moves
    let exaggerated = exaggeration != 1.0;
    if exaggerated {
        let stale = match &b.nbr_w_exag {
            Some((m, _)) => *m != exaggeration,
            None => true,
        };
        if stale {
            b.nbr_w_exag =
                Some((exaggeration, b.nbr_w.iter().map(|w| w * exaggeration).collect()));
        }
        let (m, scaled) = b.nbr_w_exag.take().unwrap();
        b.nbr_w_exag = Some((m, std::mem::replace(&mut b.nbr_w, scaled)));
    } else if b.nbr_w_exag.is_some() {
        // exaggeration window over: drop the cache
        b.nbr_w_exag = None;
    }

    let inputs =
        StepInputs { mean_x: &meanx_buf, mean_y: &meany_buf, mean_w: &meanw_buf, lr, threads };
    let l = backend.step(b, &inputs, rng);

    if exaggerated {
        let (m, orig) = b.nbr_w_exag.take().unwrap();
        b.nbr_w_exag = Some((m, std::mem::replace(&mut b.nbr_w, orig)));
    }

    let flops =
        super::comm_model::step_flops(b.n_real, b.k, meanw_buf.len(), b.negs);
    (l * b.n_real as f64, b.n_real as f64, flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::native::NativeStepBackend;
    use crate::embed::EdgeTranspose;

    /// A hand-built 4-row block (2 real points linked to each other).
    fn mini_block() -> ClusterBlock {
        let nbr_idx = vec![1, 0, 2, 3];
        let nbr_w = vec![1.0, 1.0, 0.0, 0.0];
        let neg_idx = vec![0; 4];
        let nbr_in = EdgeTranspose::build(&nbr_idx, 4, 1, |e| nbr_w[e] != 0.0);
        let neg_in = EdgeTranspose::build(&neg_idx, 4, 1, |_| true);
        ClusterBlock {
            cluster_id: 0,
            global_ids: vec![0, 1],
            size: 4,
            n_real: 2,
            pos: vec![0.0, 0.0, 1.0, 0.5, 0.0, 0.0, 0.0, 0.0],
            nbr_idx,
            nbr_w,
            nbr_w_exag: None,
            nbr_in,
            neg_idx,
            neg_w: 0.5,
            neg_in,
            valid: vec![1.0, 1.0, 0.0, 0.0],
            k: 1,
            negs: 1,
        }
    }

    #[test]
    fn budget_splits_across_active_devices_only() {
        // 8 workers, 2 non-empty shards: each active device gets 4 —
        // dividing by a *spawned* count of 8 would have left them with 1
        assert_eq!(intra_device_budget(8, 2), 4);
        assert_eq!(intra_device_budget(8, 8), 1);
        assert_eq!(intra_device_budget(8, 3), 2);
        // degenerate inputs stay sane
        assert_eq!(intra_device_budget(8, 0), 8);
        assert_eq!(intra_device_budget(1, 5), 1);
        assert_eq!(intra_device_budget(0, 2), 1);
    }

    fn remote_means() -> Vec<MeanEntry> {
        vec![
            MeanEntry { cluster_id: 0, mean: [0.0, 0.0], weight: 1.0 },
            MeanEntry { cluster_id: 1, mean: [3.0, -2.0], weight: 2.0 },
        ]
    }

    #[test]
    fn exaggeration_cache_rebuilt_on_multiplier_change() {
        let backend = NativeStepBackend::default();
        let mut b = mini_block();
        let orig_w = b.nbr_w.clone();
        let means = remote_means();

        let mut rng = Rng::new(1);
        step_block(&backend, &mut b, 0.1, 4.0, &means, &mut rng, 1);
        let (tag, cached) = b.nbr_w_exag.clone().unwrap();
        assert_eq!(tag, 4.0);
        for (c, o) in cached.iter().zip(&orig_w) {
            assert!((c - o * 4.0).abs() < 1e-6, "cache holds 4x weights");
        }

        // annealed multiplier: the cache must be rebuilt, not reused
        let mut rng = Rng::new(2);
        step_block(&backend, &mut b, 0.1, 2.0, &means, &mut rng, 1);
        let (tag, cached) = b.nbr_w_exag.clone().unwrap();
        assert_eq!(tag, 2.0);
        for (c, o) in cached.iter().zip(&orig_w) {
            assert!((c - o * 2.0).abs() < 1e-6, "cache rebuilt with 2x weights");
        }
        // originals restored after the step
        assert_eq!(b.nbr_w, orig_w);

        // window over: cache dropped
        let mut rng = Rng::new(3);
        step_block(&backend, &mut b, 0.1, 1.0, &means, &mut rng, 1);
        assert!(b.nbr_w_exag.is_none());
        assert_eq!(b.nbr_w, orig_w);
    }

    #[test]
    fn exaggerated_step_equals_manually_scaled_weights() {
        let backend = NativeStepBackend::default();
        let means = remote_means();

        let mut via_cache = mini_block();
        let mut rng1 = Rng::new(7);
        let l1 = step_block(&backend, &mut via_cache, 0.2, 3.0, &means, &mut rng1, 1).0;

        let mut manual = mini_block();
        for w in manual.nbr_w.iter_mut() {
            *w *= 3.0;
        }
        let mut rng2 = Rng::new(7);
        let l2 = step_block(&backend, &mut manual, 0.2, 1.0, &means, &mut rng2, 1).0;

        assert_eq!(via_cache.pos, manual.pos, "positions must match");
        assert!((l1 - l2).abs() < 1e-12);
    }

    #[test]
    fn step_block_drops_zero_weight_means() {
        // a zero-weight remote entry (ApproxMode::None publishes only
        // those) must neither change the step nor be paid for in the
        // O(R) mean pass — the view builder filters it out entirely
        let backend = NativeStepBackend::default();
        let with_zero = vec![
            MeanEntry { cluster_id: 0, mean: [0.0, 0.0], weight: 1.0 },
            MeanEntry { cluster_id: 1, mean: [3.0, -2.0], weight: 2.0 },
            MeanEntry { cluster_id: 2, mean: [9.0, 9.0], weight: 0.0 },
        ];
        let without: Vec<MeanEntry> = with_zero[..2].to_vec();

        let mut a = mini_block();
        let mut rng1 = Rng::new(5);
        let la = step_block(&backend, &mut a, 0.3, 1.0, &with_zero, &mut rng1, 1).0;
        let mut b = mini_block();
        let mut rng2 = Rng::new(5);
        let lb = step_block(&backend, &mut b, 0.3, 1.0, &without, &mut rng2, 1).0;
        assert_eq!(a.pos, b.pos);
        assert_eq!(la.to_bits(), lb.to_bits());
    }

    #[test]
    fn spawned_device_serves_the_full_command_cycle() {
        let make: Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> =
            Box::new(|| Box::new(NativeStepBackend::default()) as Box<dyn StepBackend>);
        let mut link = spawn_device(0, vec![mini_block()], 2, 0.5, 42, 1, make);

        // ingest fresh positions
        let table = Arc::new(vec![1.0f32, 2.0, 3.0, 4.0]);
        link.send_cmd(DeviceCmd::Ingest { positions: table }).unwrap();
        assert_eq!(link.recv_reply().unwrap(), DeviceReply::Ingested { device: 0 });

        // one epoch
        link.send_cmd(DeviceCmd::Epoch {
            epoch: 0,
            lr: 0.1,
            exaggeration: 1.0,
            means: Arc::new(remote_means()),
        })
        .unwrap();
        match link.recv_reply().unwrap() {
            DeviceReply::EpochDone { device, means, loss_weight, .. } => {
                assert_eq!(device, 0);
                assert_eq!(means.len(), 1);
                assert_eq!(loss_weight, 2.0);
            }
            other => panic!("expected EpochDone, got {other:?}"),
        }

        // export: both real rows come back, ids intact
        link.send_cmd(DeviceCmd::Export).unwrap();
        match link.recv_reply().unwrap() {
            DeviceReply::Exported { positions, .. } => {
                assert_eq!(positions.len(), 2);
                assert_eq!(positions[0].0, 0);
                assert_eq!(positions[1].0, 1);
            }
            other => panic!("expected Exported, got {other:?}"),
        }

        assert!(link.wire_bytes() > 0, "channel links still account frame bytes");
        link.stop();
    }

    #[test]
    fn epoch_deadline_surfaces_as_a_classified_timeout() {
        use crate::distributed::fault::FaultKind;
        use std::time::Duration;

        let (leader_end, _device_end) = channel_pair();
        let mut link = DeviceLink {
            device: 3,
            transport: Box::new(leader_end),
            join: None,
            io_timeout: None,
        };
        // a silent device: the deadline must fire, classified as a timeout
        let t0 = Instant::now();
        let e = link.recv_reply_by(Instant::now() + Duration::from_millis(30)).unwrap_err();
        assert_eq!(FaultKind::classify(&e), FaultKind::Timeout, "{e}");
        assert!(t0.elapsed() < Duration::from_secs(10));
        // an already-expired deadline fails immediately, without a recv
        let e = link.recv_reply_by(Instant::now()).unwrap_err();
        assert_eq!(FaultKind::classify(&e), FaultKind::Timeout, "{e}");
    }

    #[test]
    fn step_block_excludes_own_cluster_mean() {
        let backend = NativeStepBackend::default();
        let means = remote_means();
        let mut with_table = mini_block();
        let mut rng1 = Rng::new(5);
        step_block(&backend, &mut with_table, 0.3, 1.0, &means, &mut rng1, 1);

        // hand-built inputs with only the remote cluster
        let mut direct = mini_block();
        let mut rng2 = Rng::new(5);
        let inputs = StepInputs {
            mean_x: &[3.0],
            mean_y: &[-2.0],
            mean_w: &[2.0],
            lr: 0.3,
            threads: 1,
        };
        backend.step(&mut direct, &inputs, &mut rng2);
        assert_eq!(with_table.pos, direct.pos);
    }
}
