//! Device worker: a long-lived thread owning a shard of cluster blocks.
//!
//! Each worker owns its own [`StepBackend`] instance, created *inside* the
//! thread (the XLA backend wraps a PJRT client, which is not `Send` — and a
//! real multi-GPU deployment gives each device its own PJRT client anyway).
//! Communication with the leader is over channels carrying plain data:
//! the epoch broadcast (learning rate + the all-gathered means table) and
//! the per-epoch gather (fresh local means + loss + timing).

use super::MeanEntry;
use crate::embed::{ClusterBlock, StepBackend, StepInputs};
use crate::util::rng::Rng;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Leader -> device commands.
pub enum DeviceCmd {
    /// Run one epoch over all local blocks.
    Epoch {
        lr: f32,
        /// attractive-weight multiplier (early exaggeration; 1.0 = off)
        exaggeration: f32,
        /// full means table (every cluster in the run)
        means: Arc<Vec<MeanEntry>>,
    },
    /// Send back (global_id, position) for every real point.
    Collect,
    /// Shut down.
    Stop,
}

/// Device -> leader replies.
pub enum DeviceReply {
    EpochDone {
        device: usize,
        /// fresh means of the local clusters
        means: Vec<MeanEntry>,
        /// sum of block losses weighted by block valid counts
        loss_sum: f64,
        loss_weight: f64,
        /// pure step compute time
        step_secs: f64,
        /// force-kernel FLOPs executed this epoch (for the cost model)
        flops: f64,
    },
    Collected {
        device: usize,
        positions: Vec<(u32, [f32; 2])>,
    },
}

/// Handle owned by the leader.
pub struct DeviceHandle {
    pub device: usize,
    pub cmd: Sender<DeviceCmd>,
    pub join: std::thread::JoinHandle<()>,
}

/// Spawn a device worker.
///
/// `make_backend` runs once inside the worker thread to build the step
/// backend (native, or XLA with a thread-private PJRT client).
pub fn spawn_device(
    device: usize,
    mut blocks: Vec<ClusterBlock>,
    n_total: usize,
    m_noise: f64,
    seed: u64,
    make_backend: Box<dyn FnOnce() -> Box<dyn StepBackend> + Send>,
    reply: Sender<DeviceReply>,
) -> DeviceHandle {
    let (cmd_tx, cmd_rx): (Sender<DeviceCmd>, Receiver<DeviceCmd>) = std::sync::mpsc::channel();
    let join = std::thread::Builder::new()
        .name(format!("nomad-dev{device}"))
        .spawn(move || {
            let backend = make_backend();
            let mut rng = Rng::new(seed).fork(device as u64 + 1);
            // scratch buffers for the remote-means view (excluding own cluster)
            let mut means_buf: Vec<f32> = Vec::new();
            let mut meanw_buf: Vec<f32> = Vec::new();

            while let Ok(cmd) = cmd_rx.recv() {
                match cmd {
                    DeviceCmd::Stop => break,
                    DeviceCmd::Collect => {
                        let mut positions = Vec::new();
                        for b in &blocks {
                            for (l, &g) in b.global_ids.iter().enumerate() {
                                positions.push((g, [b.pos[l * 2], b.pos[l * 2 + 1]]));
                            }
                        }
                        let _ = reply.send(DeviceReply::Collected { device, positions });
                    }
                    DeviceCmd::Epoch { lr, exaggeration, means } => {
                        let mut loss_sum = 0.0f64;
                        let mut loss_weight = 0.0f64;
                        let mut flops = 0.0f64;
                        let t0 = Instant::now();
                        for b in blocks.iter_mut() {
                            // remote view: every cluster except this block's
                            means_buf.clear();
                            meanw_buf.clear();
                            for e in means.iter() {
                                if e.cluster_id != b.cluster_id {
                                    means_buf.push(e.mean[0]);
                                    means_buf.push(e.mean[1]);
                                    meanw_buf.push(e.weight);
                                }
                            }
                            // early exaggeration: swap in a cached scaled
                            // copy of the attractive weights for this step
                            let exaggerated = exaggeration != 1.0;
                            if exaggerated {
                                if b.nbr_w_exag.is_none() {
                                    b.nbr_w_exag =
                                        Some(b.nbr_w.iter().map(|w| w * exaggeration).collect());
                                }
                                let cache = b.nbr_w_exag.take().unwrap();
                                b.nbr_w_exag = Some(std::mem::replace(&mut b.nbr_w, cache));
                            }
                            let inputs = StepInputs {
                                means: &means_buf,
                                mean_w: &meanw_buf,
                                lr,
                            };
                            let l = backend.step(b, &inputs, &mut rng);
                            if exaggerated {
                                let orig = b.nbr_w_exag.take().unwrap();
                                b.nbr_w_exag = Some(std::mem::replace(&mut b.nbr_w, orig));
                            }
                            loss_sum += l * b.n_real as f64;
                            loss_weight += b.n_real as f64;
                            flops += super::comm_model::step_flops(
                                b.n_real,
                                b.k,
                                meanw_buf.len(),
                                b.negs,
                            );
                        }
                        let step_secs = t0.elapsed().as_secs_f64();
                        let fresh: Vec<MeanEntry> = blocks
                            .iter()
                            .map(|b| MeanEntry {
                                cluster_id: b.cluster_id,
                                mean: b.mean(),
                                weight: b.mean_weight(n_total, m_noise),
                            })
                            .collect();
                        let _ = reply.send(DeviceReply::EpochDone {
                            device,
                            means: fresh,
                            loss_sum,
                            loss_weight,
                            step_secs,
                            flops,
                        });
                    }
                }
            }
        })
        .expect("spawn device thread");
    DeviceHandle { device, cmd: cmd_tx, join }
}

