//! Analytic communication + compute cost model.
//!
//! The paper's headline numbers come from an 8xH100 NVLink node we do not
//! have; the *structure* of its scaling argument is (a) positive forces are
//! communication-free, (b) negative forces need only an all-gather of R
//! cluster means per epoch.  This model turns the simulator's exact
//! per-epoch work and byte counts into modeled wall-clock on a
//! parameterized GPU node, which the scaling benches report alongside the
//! measured CPU time (DESIGN.md §3 documents this substitution).

/// Hardware profile for the modeled node.
#[derive(Clone, Debug)]
pub struct HwProfile {
    /// achieved FLOP/s per device on the force kernels (f32, VPU-bound)
    pub flops_per_dev: f64,
    /// all-gather bus bandwidth, bytes/s (NVLink-class)
    pub allgather_bw: f64,
    /// per-collective latency, seconds
    pub collective_lat: f64,
    /// fixed per-epoch launch/sync overhead per device, seconds
    pub epoch_overhead: f64,
    /// how much faster one modeled device runs the force kernels than one
    /// CPU core of this testbed (used to translate *measured* per-device
    /// step seconds into modeled device seconds)
    pub cpu_to_dev_speedup: f64,
}

impl HwProfile {
    /// An H100 SXM node profile (achievable, not peak: gather-heavy f32
    /// VPU work sustains a few percent of the 67 TFLOP/s f32 peak).
    pub fn h100() -> HwProfile {
        HwProfile {
            flops_per_dev: 2.0e12,
            allgather_bw: 300.0e9,
            collective_lat: 20e-6,
            epoch_overhead: 30e-6,
            cpu_to_dev_speedup: 100.0,
        }
    }
}

/// Per-epoch work description, measured by the simulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct EpochWork {
    /// force-kernel FLOPs on the busiest device
    pub max_dev_flops: f64,
    /// total FLOPs across devices (for efficiency accounting)
    pub total_flops: f64,
    /// measured wall seconds of the busiest device's step work this epoch
    /// (preferred over the FLOP estimate when > 0)
    pub max_dev_secs: f64,
    /// bytes all-gathered (means table)
    pub allgather_bytes: u64,
    pub n_devices: usize,
}

/// FLOPs for one block step: per valid head, K positive edges (~12 flops
/// each incl. gradient), R mean negatives (~10), NEG exact negatives (~12),
/// plus the update.  Constants are calibrated from the native kernel's
/// operation count; only *ratios* across configurations matter.
pub fn step_flops(n_real: usize, k: usize, r: usize, negs: usize) -> f64 {
    let per_head = 12.0 * k as f64 + 10.0 * r as f64 + 12.0 * negs as f64 + 4.0;
    n_real as f64 * per_head
}

/// Modeled wall-clock seconds for one epoch.  Compute time comes from the
/// *measured* busiest-device step seconds (scaled by the CPU->device
/// speedup) when available, else from the FLOP estimate.
pub fn epoch_time(hw: &HwProfile, w: &EpochWork) -> f64 {
    let compute = if w.max_dev_secs > 0.0 {
        w.max_dev_secs / hw.cpu_to_dev_speedup
    } else {
        w.max_dev_flops / hw.flops_per_dev
    };
    // ring all-gather: every device receives the full table once
    let comm = hw.collective_lat + w.allgather_bytes as f64 / hw.allgather_bw;
    compute + comm + hw.epoch_overhead
}

/// Modeled per-epoch time when the same workload is scaled to `scale` x
/// more points per device (paper-scale extrapolation: compute and table
/// bytes scale linearly in points; clusters held fixed).
pub fn epoch_time_scaled(hw: &HwProfile, w: &EpochWork, scale: f64) -> f64 {
    let scaled = EpochWork {
        max_dev_flops: w.max_dev_flops * scale,
        total_flops: w.total_flops * scale,
        max_dev_secs: w.max_dev_secs * scale,
        allgather_bytes: w.allgather_bytes,
        n_devices: w.n_devices,
    };
    epoch_time(hw, &scaled)
}

/// Modeled speedup of `n`-device over 1-device execution for a workload
/// where per-device compute divides evenly and the all-gather grows with
/// the (fixed) number of clusters.
pub fn modeled_speedup(hw: &HwProfile, one_dev: &EpochWork, n_dev: &EpochWork) -> f64 {
    epoch_time(hw, one_dev) / epoch_time(hw, n_dev)
}

/// Aggregated communication statistics over a run.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub epochs: usize,
    /// modeled all-gather traffic: R x 16 bytes received per device per
    /// epoch (what the cost model charges the interconnect for)
    pub allgather_bytes_total: u64,
    pub positive_phase_bytes_total: u64, // always 0: the design invariant
    /// **measured** frame bytes over every coordinator<->device link for
    /// the whole run, both directions (real socket bytes under remote
    /// placement; identical would-be frame bytes under in-process channel
    /// placement).  Includes the epoch broadcast, gathers, ingests and
    /// exports — compare against `allgather_bytes_total` to see how much
    /// of the wire is the means table.
    pub wire_bytes_total: u64,
    /// per-epoch deltas of the measured wire bytes, one entry per trained
    /// epoch (snapshot/checkpoint exports land in the epoch they follow)
    pub wire_epoch_bytes: Vec<u64>,
    pub modeled_secs_total: f64,
    pub measured_secs_total: f64,
    /// every classified fault the coordinator observed, in order — a run
    /// that hit faults and recovered reports them here and in the run
    /// manifest (DESIGN.md §13)
    pub faults: Vec<super::fault::FaultEvent>,
    /// how many checkpoint-rollback recoveries the run performed
    pub recoveries: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_dominates_at_scale() {
        let hw = HwProfile::h100();
        let big = EpochWork {
            max_dev_flops: 1e12,
            total_flops: 8e12,
            max_dev_secs: 0.0,
            allgather_bytes: 256 * 16,
            n_devices: 8,
        };
        let t = epoch_time(&hw, &big);
        let comm = hw.collective_lat + big.allgather_bytes as f64 / hw.allgather_bw;
        assert!(t > 10.0 * comm, "compute must dominate: t={t} comm={comm}");
    }

    #[test]
    fn speedup_near_linear_when_compute_bound() {
        let hw = HwProfile::h100();
        let one = EpochWork {
            max_dev_flops: 8e11,
            total_flops: 8e11,
            max_dev_secs: 0.0,
            allgather_bytes: 0,
            n_devices: 1,
        };
        let eight = EpochWork {
            max_dev_flops: 1e11,
            total_flops: 8e11,
            max_dev_secs: 0.0,
            allgather_bytes: 256 * 16,
            n_devices: 8,
        };
        let s = modeled_speedup(&hw, &one, &eight);
        assert!(s > 6.0 && s <= 8.0, "speedup {s}");
    }

    #[test]
    fn measured_seconds_preferred_and_scaling_extrapolates() {
        let hw = HwProfile::h100();
        let w = EpochWork {
            max_dev_flops: 1e9,
            total_flops: 1e9,
            max_dev_secs: 1.0, // 1 CPU-second of step work
            allgather_bytes: 0,
            n_devices: 1,
        };
        let t = epoch_time(&hw, &w);
        assert!((t - (1.0 / hw.cpu_to_dev_speedup + hw.collective_lat + hw.epoch_overhead)).abs() < 1e-9);
        let t1000 = epoch_time_scaled(&hw, &w, 1000.0);
        assert!(t1000 > 900.0 * (t - hw.collective_lat - hw.epoch_overhead));
    }

    #[test]
    fn step_flops_scales_linearly_in_heads() {
        let a = step_flops(1000, 15, 64, 8);
        let b = step_flops(2000, 15, 64, 8);
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
