//! Deterministic structure-aware fuzzing for the wire decoder.
//!
//! `cargo test`-runnable, pure std: a seeded corpus of every [`WireMsg`]
//! variant is mutated with frame-structure-aware operators (bit flips,
//! length-field boundary values, truncation, extension, splicing, crc
//! zeroing/fixing) and fed to both decoders — [`proto::decode`] on the
//! slice and [`proto::read_frame`] through a reader that returns the
//! bytes in adversarially small chunks.  The decoders must return
//! `Ok`/`Err`, never panic, never allocate from a hostile length claim,
//! and must agree: a frame the slice decoder accepts is byte-exact, so
//! the streaming decoder has to accept it too.
//!
//! Everything is a pure function of the seed, so any crash reproduces
//! from two integers; crashes get promoted to regression tests in
//! `tests/wire_proto.rs`.

use super::device::{DeviceCmd, DeviceReply};
use super::proto::{self, Assignment, Role, WireMsg, HEADER_BYTES, MAX_PAYLOAD};
use super::MeanEntry;
use crate::util::rng::Rng;
use crate::viz::png::Crc32;
use std::io::Read;
use std::sync::Arc;

/// Tally of one fuzzing run (slice-decoder outcomes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FuzzOutcome {
    /// Mutated frames fed to the decoders.
    pub iters: usize,
    /// Frames the slice decoder accepted (pristine corpus warm-up included).
    pub decoded_ok: usize,
    /// Frames it rejected with an error (the canonical truncation warm-up
    /// guarantees this is nonzero for every seed).
    pub rejected: usize,
}

/// One exemplar frame per message variant — the mutation corpus.  The
/// test-only `proto::tests::sample_msgs` is `cfg(test)`, so the fuzz
/// harness carries its own.
pub fn corpus() -> Vec<WireMsg> {
    let means = vec![
        MeanEntry { cluster_id: 2, mean: [0.75, -1.5], weight: 4.0 },
        MeanEntry { cluster_id: 11, mean: [-0.0, f32::MIN_POSITIVE], weight: 0.25 },
    ];
    vec![
        WireMsg::Hello { role: Role::Coordinator },
        WireMsg::Hello { role: Role::Worker },
        WireMsg::Assign(Assignment {
            device: 1,
            n_active: 2,
            n_total: 4096,
            negs: 8,
            seed: 0xDEAD_BEEF,
            m_noise: 2.5,
            clusters: vec![3, 0, 12],
        }),
        WireMsg::Assigned { device: 1, n_blocks: 3, n_points: 2048 },
        WireMsg::Cmd(DeviceCmd::Epoch {
            epoch: 17,
            lr: 0.5,
            exaggeration: 4.0,
            means: Arc::new(means.clone()),
        }),
        WireMsg::Cmd(DeviceCmd::Export),
        WireMsg::Cmd(DeviceCmd::Ingest { positions: Arc::new(vec![1.0, -2.5, 0.0, 3.25]) }),
        WireMsg::Cmd(DeviceCmd::Stop),
        WireMsg::Reply(DeviceReply::EpochDone {
            device: 1,
            means,
            loss_sum: -12.5,
            loss_weight: 64.0,
            step_secs: 0.25,
            flops: 1.0e9,
        }),
        WireMsg::Reply(DeviceReply::Exported {
            device: 0,
            positions: vec![(7, [1.0, -1.0]), (9, [0.5, 0.25])],
        }),
        WireMsg::Reply(DeviceReply::Ingested { device: 3 }),
    ]
}

/// Recompute the header crc over the (possibly mutated) type/length
/// fields and payload, so structural mutations can still produce frames
/// that reach the payload decoder instead of dying at the crc check.
fn fix_crc(frame: &mut [u8]) {
    if frame.len() < HEADER_BYTES {
        return;
    }
    let mut c = Crc32::new();
    c.update(&frame[6..12]);
    c.update(&frame[HEADER_BYTES..]);
    let crc = c.finish().to_le_bytes();
    frame[12..16].copy_from_slice(&crc);
}

/// Apply one structure-aware mutation in place.  `donor` is another
/// corpus frame for the splice operator.
fn mutate(frame: &mut Vec<u8>, donor: &[u8], rng: &mut Rng) {
    match rng.below(7) {
        0 => {
            // flip one bit anywhere
            if !frame.is_empty() {
                let i = rng.below(frame.len());
                frame[i] ^= 1 << rng.below(8);
            }
        }
        1 => {
            // drive the length field to a boundary value
            if frame.len() >= HEADER_BYTES {
                let payload = (frame.len() - HEADER_BYTES) as u32;
                let boundary = [
                    0,
                    1,
                    payload.wrapping_sub(1),
                    payload.wrapping_add(1),
                    MAX_PAYLOAD,
                    MAX_PAYLOAD + 1,
                    u32::MAX,
                ];
                let v = boundary[rng.below(boundary.len())];
                frame[8..12].copy_from_slice(&v.to_le_bytes());
            }
        }
        2 => {
            // truncate anywhere, header included
            if !frame.is_empty() {
                let keep = rng.below(frame.len());
                frame.truncate(keep);
            }
        }
        3 => {
            // append trailing garbage
            for _ in 0..rng.below(24) + 1 {
                frame.push(rng.next_u64() as u8);
            }
        }
        4 => {
            // zero the crc field
            if frame.len() >= HEADER_BYTES {
                frame[12..16].fill(0);
            }
        }
        5 => {
            // splice: our prefix, the donor's suffix
            let cut = rng.below(frame.len().min(donor.len()).max(1));
            frame.truncate(cut);
            frame.extend_from_slice(&donor[cut.min(donor.len())..]);
        }
        _ => fix_crc(frame),
    }
}

/// A reader that hands out at most 7 bytes per `read` call, the count
/// drawn from its own rng stream — the streaming decoder must survive
/// arbitrarily fragmented delivery (short TCP reads).
struct Chunked<'a> {
    data: &'a [u8],
    off: usize,
    rng: Rng,
}

impl Read for Chunked<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let left = self.data.len() - self.off;
        if left == 0 || buf.is_empty() {
            return Ok(0);
        }
        let max = buf.len().min(left).min(7);
        let n = self.rng.below(max) + 1;
        buf[..n].copy_from_slice(&self.data[self.off..self.off + n]);
        self.off += n;
        Ok(n)
    }
}

/// Run `iters` mutated frames through both decoders.  Panics only on a
/// decoder bug: either decoder panicking internally, or the slice
/// decoder accepting a frame the streaming decoder rejects.
pub fn run(seed: u64, iters: usize) -> FuzzOutcome {
    let frames: Vec<Vec<u8>> = corpus().iter().map(proto::encode).collect();
    let mut rng = Rng::new(seed).fork(0xF0);
    let mut decoded_ok = 0usize;
    let mut rejected = 0usize;

    // warm-up establishes both counters for every seed: pristine frames
    // must decode, a canonical truncation must not
    for f in &frames {
        match proto::decode(f) {
            Ok(_) => decoded_ok += 1,
            Err(e) => panic!("pristine corpus frame rejected: {e}"),
        }
    }
    assert!(proto::decode(&frames[0][..HEADER_BYTES - 1]).is_err());
    rejected += 1;

    for i in 0..iters {
        let mut frame = frames[rng.below(frames.len())].clone();
        let donor = &frames[rng.below(frames.len())];
        for _ in 0..rng.below(3) + 1 {
            mutate(&mut frame, donor, &mut rng);
        }

        let slice_ok = match proto::decode(&frame) {
            Ok(_) => {
                decoded_ok += 1;
                true
            }
            Err(_) => {
                rejected += 1;
                false
            }
        };

        let mut r = Chunked { data: &frame, off: 0, rng: rng.fork(i as u64) };
        let stream_ok = proto::read_frame(&mut r).is_ok();
        if slice_ok {
            // the slice held exactly one valid frame, so the streaming
            // decoder has no excuse
            assert!(stream_ok, "slice decoder accepted what the stream decoder rejected");
        }
    }
    FuzzOutcome { iters, decoded_ok, rejected }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fuzz_is_deterministic_in_the_seed() {
        let a = run(42, 300);
        let b = run(42, 300);
        assert_eq!(a, b);
        assert_eq!(a.iters, 300);
        assert_eq!(a.decoded_ok + a.rejected, 300 + corpus().len() + 1);
    }

    #[test]
    fn fuzz_exercises_both_outcomes() {
        for seed in [0u64, 1, 0xBAD5EED] {
            let out = run(seed, 200);
            assert!(out.decoded_ok > 0, "seed {seed}: nothing decoded");
            assert!(out.rejected > 0, "seed {seed}: nothing rejected");
        }
    }

    #[test]
    fn chunked_reader_delivers_everything() {
        let frame = proto::encode(&WireMsg::Cmd(DeviceCmd::Export));
        let mut r = Chunked { data: &frame, off: 0, rng: Rng::new(7) };
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        assert_eq!(out, frame);
    }
}
