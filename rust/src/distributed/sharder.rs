//! Cluster-to-device sharding.
//!
//! Clusters are atomic (the whole point of the K-Means ANN index); devices
//! should carry near-equal numbers of *points*.  Greedy largest-first (LPT)
//! gives a 4/3-approximation to the optimal makespan, which is plenty —
//! the paper's own strategy is equivalent.

/// Assign clusters (by size) to `n_devices` bins; returns, per device, the
/// list of cluster ids, and balances total point counts.
pub fn shard_clusters(sizes: &[usize], n_devices: usize) -> Vec<Vec<usize>> {
    let n_devices = n_devices.max(1);
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| sizes[b].cmp(&sizes[a]).then(a.cmp(&b)));
    let mut loads = vec![0usize; n_devices];
    let mut out = vec![Vec::new(); n_devices];
    for c in order {
        let d = (0..n_devices).min_by_key(|&d| (loads[d], d)).unwrap();
        loads[d] += sizes[c];
        out[d].push(c);
    }
    out
}

/// Imbalance diagnostic: max device load / mean device load.
pub fn imbalance(sizes: &[usize], shards: &[Vec<usize>]) -> f64 {
    let loads: Vec<usize> = shards
        .iter()
        .map(|s| s.iter().map(|&c| sizes[c]).sum())
        .collect();
    let max = *loads.iter().max().unwrap_or(&0) as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_clusters_once() {
        let sizes = vec![10, 20, 30, 40, 50, 5, 5];
        let shards = shard_clusters(&sizes, 3);
        let mut seen = vec![false; sizes.len()];
        for s in &shards {
            for &c in s {
                assert!(!seen[c]);
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&v| v));
    }

    #[test]
    fn balances_loads() {
        let sizes = vec![100; 8];
        let shards = shard_clusters(&sizes, 4);
        for s in &shards {
            assert_eq!(s.len(), 2);
        }
        assert!((imbalance(&sizes, &shards) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lpt_heuristic_reasonable() {
        let sizes = vec![9, 7, 6, 5, 5, 4, 4, 3, 3, 2];
        let shards = shard_clusters(&sizes, 3);
        let imb = imbalance(&sizes, &shards);
        assert!(imb < 1.2, "imbalance {imb}");
    }

    #[test]
    fn more_devices_than_clusters() {
        let sizes = vec![10, 20];
        let shards = shard_clusters(&sizes, 5);
        let nonempty = shards.iter().filter(|s| !s.is_empty()).count();
        assert_eq!(nonempty, 2);
    }
}
