//! Cluster-to-device sharding.
//!
//! Clusters are atomic (the whole point of the K-Means ANN index); devices
//! should carry near-equal numbers of *points*.  Greedy largest-first (LPT)
//! gives a 4/3-approximation to the optimal makespan, which is plenty —
//! the paper's own strategy is equivalent.

/// Assign clusters (by size) to `n_devices` bins; returns, per device, the
/// list of cluster ids, and balances total point counts.
pub fn shard_clusters(sizes: &[usize], n_devices: usize) -> Vec<Vec<usize>> {
    let n_devices = n_devices.max(1);
    let mut order: Vec<usize> = (0..sizes.len()).collect();
    order.sort_by(|&a, &b| sizes[b].cmp(&sizes[a]).then(a.cmp(&b)));
    let mut loads = vec![0usize; n_devices];
    let mut out = vec![Vec::new(); n_devices];
    for c in order {
        let d = (0..n_devices).min_by_key(|&d| (loads[d], d)).unwrap();
        loads[d] += sizes[c];
        out[d].push(c);
    }
    out
}

/// Number of shards that actually own clusters.  Thread budgets and
/// `Assignment::n_active` divide across *these* — a `n_devices >
/// n_clusters` run spawns empty devices that must not hold a share
/// ([`super::device::intra_device_budget`]).
pub fn active_shards(shards: &[Vec<usize>]) -> usize {
    shards.iter().filter(|s| !s.is_empty()).count()
}

/// Imbalance diagnostic: max device load / mean device load, over the
/// devices that own at least one cluster.  Empty shards are excluded from
/// the mean: they are a fact of `n_devices > n_clusters` runs, not a
/// balance failure, and counting them would report a phantom imbalance of
/// `n_devices / n_clusters` for a perfectly balanced assignment.
pub fn imbalance(sizes: &[usize], shards: &[Vec<usize>]) -> f64 {
    let loads: Vec<usize> = shards
        .iter()
        .filter(|s| !s.is_empty())
        .map(|s| s.iter().map(|&c| sizes[c]).sum())
        .collect();
    let max = *loads.iter().max().unwrap_or(&0) as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_all_clusters_once() {
        let sizes = vec![10, 20, 30, 40, 50, 5, 5];
        let shards = shard_clusters(&sizes, 3);
        let mut seen = vec![false; sizes.len()];
        for s in &shards {
            for &c in s {
                assert!(!seen[c]);
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&v| v));
    }

    #[test]
    fn balances_loads() {
        let sizes = vec![100; 8];
        let shards = shard_clusters(&sizes, 4);
        for s in &shards {
            assert_eq!(s.len(), 2);
        }
        assert!((imbalance(&sizes, &shards) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lpt_heuristic_reasonable() {
        let sizes = vec![9, 7, 6, 5, 5, 4, 4, 3, 3, 2];
        let shards = shard_clusters(&sizes, 3);
        let imb = imbalance(&sizes, &shards);
        assert!(imb < 1.2, "imbalance {imb}");
    }

    #[test]
    fn more_devices_than_clusters() {
        let sizes = vec![10, 20];
        let shards = shard_clusters(&sizes, 5);
        assert_eq!(shards.len(), 5, "every requested device gets a (possibly empty) shard");
        assert_eq!(active_shards(&shards), 2);
        // the two clusters land on the two lowest device ids, largest first
        assert_eq!(shards[0], vec![1]);
        assert_eq!(shards[1], vec![0]);
        assert!(shards[2..].iter().all(|s| s.is_empty()));
        // a perfectly balanced-as-possible assignment must not report the
        // phantom 5/2 imbalance that counting empty shards would produce
        let imb = imbalance(&sizes, &shards);
        assert!((imb - 20.0 / 15.0).abs() < 1e-9, "imbalance {imb}");
    }

    #[test]
    fn zero_devices_degrades_to_one() {
        let sizes = vec![4, 4, 4];
        let shards = shard_clusters(&sizes, 0);
        assert_eq!(shards.len(), 1);
        assert_eq!(active_shards(&shards), 1);
        assert_eq!(shards[0].len(), 3);
        assert!((imbalance(&sizes, &shards) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_clusters_leaves_every_shard_empty() {
        let sizes: Vec<usize> = Vec::new();
        let shards = shard_clusters(&sizes, 3);
        assert_eq!(shards.len(), 3);
        assert_eq!(active_shards(&shards), 0);
        assert_eq!(imbalance(&sizes, &shards), 1.0, "no load, no imbalance");
    }

    #[test]
    fn zero_size_clusters_are_assigned_without_panic() {
        // empty clusters (possible under aggressive max_cluster_size
        // splits) still get a home and still count as owned work
        let sizes = vec![0, 10, 0, 5];
        let shards = shard_clusters(&sizes, 2);
        let mut seen: Vec<usize> = shards.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3]);
        assert!(imbalance(&sizes, &shards).is_finite());
    }

    #[test]
    fn assignment_is_deterministic() {
        let sizes = vec![7, 7, 7, 3, 3, 9];
        let a = shard_clusters(&sizes, 4);
        let b = shard_clusters(&sizes, 4);
        assert_eq!(a, b, "ties must break deterministically");
    }
}
