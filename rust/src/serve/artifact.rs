//! Persisted map artifact — the contract between a finished run and the
//! serving layer (DESIGN.md §10).
//!
//! A `MapArtifact` is a directory: `positions.npy` (n x 2 f32), an
//! optional `labels.npy` (n f32, integral values), and `manifest.json`
//! carrying the point count, the fitted bounds, and build provenance.
//! `nomad embed` writes one at the end of every run; `nomad serve` (and
//! the load bench) load it standalone — no dataset, index, or training
//! state required on the read path.

use crate::ensure;
use crate::linalg::Matrix;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::npy::NpyF32;
use crate::viz::View;
use std::path::Path;

/// Where the artifact came from (recorded verbatim in the manifest).
#[derive(Clone, Debug, Default)]
pub struct Provenance {
    pub dataset: String,
    pub seed: u64,
    pub epochs: usize,
    pub final_loss: f64,
}

/// A finished map, loadable standalone by the serving layer.
#[derive(Clone, Debug)]
pub struct MapArtifact {
    /// n x 2 embedding positions
    pub positions: Matrix,
    /// optional per-point labels (same length as rows)
    pub labels: Option<Vec<u32>>,
    /// fitted square bounds of the finite points (the tile-pyramid root)
    pub bounds: View,
    pub provenance: Provenance,
}

const FORMAT: &str = "nomad-map-artifact";
const VERSION: i64 = 1;

impl MapArtifact {
    /// Assemble from a finished run; bounds are fitted here.
    pub fn from_run(
        positions: Matrix,
        labels: Option<Vec<u32>>,
        provenance: Provenance,
    ) -> Result<MapArtifact> {
        ensure!(positions.cols == 2, "positions must be n x 2, got n x {}", positions.cols);
        if let Some(ls) = &labels {
            ensure!(
                ls.len() == positions.rows,
                "labels length {} != {} points",
                ls.len(),
                positions.rows
            );
        }
        let bounds = View::fit(&positions);
        Ok(MapArtifact { positions, labels, bounds, provenance })
    }

    /// Write `positions.npy` (+ `labels.npy`) + `manifest.json` to `dir`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create artifact dir {}", dir.display()))?;
        NpyF32::new(vec![self.positions.rows, 2], self.positions.data.clone())
            .save(&dir.join("positions.npy"))?;
        if let Some(ls) = &self.labels {
            let data: Vec<f32> = ls.iter().map(|&l| l as f32).collect();
            NpyF32::new(vec![ls.len()], data).save(&dir.join("labels.npy"))?;
        }
        let manifest = json::obj(vec![
            ("format", json::s(FORMAT)),
            ("version", json::num(VERSION as f64)),
            ("n_points", json::num(self.positions.rows as f64)),
            ("positions", json::s("positions.npy")),
            (
                "labels",
                if self.labels.is_some() { json::s("labels.npy") } else { Json::Null },
            ),
            (
                "bounds",
                json::obj(vec![
                    ("cx", json::num(self.bounds.cx as f64)),
                    ("cy", json::num(self.bounds.cy as f64)),
                    ("half_w", json::num(self.bounds.half_w as f64)),
                    ("half_h", json::num(self.bounds.half_h as f64)),
                ]),
            ),
            (
                "provenance",
                json::obj(vec![
                    ("dataset", json::s(&self.provenance.dataset)),
                    ("seed", json::num(self.provenance.seed as f64)),
                    ("epochs", json::num(self.provenance.epochs as f64)),
                    // a NaN/inf loss (diverged or zero-epoch run) must not
                    // serialize as a bare `NaN` token, which no JSON parser
                    // (ours included) can read back
                    (
                        "final_loss",
                        if self.provenance.final_loss.is_finite() {
                            json::num(self.provenance.final_loss)
                        } else {
                            Json::Null
                        },
                    ),
                ]),
            ),
        ]);
        std::fs::write(dir.join("manifest.json"), manifest.pretty())
            .with_context(|| format!("write {}/manifest.json", dir.display()))?;
        Ok(())
    }

    /// Load an artifact directory written by [`MapArtifact::save`].
    pub fn load(dir: &Path) -> Result<MapArtifact> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {}", mpath.display()))?;
        let v = Json::parse(&text).context("parse artifact manifest")?;
        ensure!(
            v.get("format").as_str() == Some(FORMAT),
            "not a map artifact manifest: {}",
            mpath.display()
        );
        ensure!(
            v.get("version").as_i64() == Some(VERSION),
            "unsupported artifact version {:?}",
            v.get("version").as_i64()
        );
        let n = v.get("n_points").as_usize().context("manifest n_points")?;

        let pos_file = v.get("positions").as_str().context("manifest positions")?;
        let t = NpyF32::load(&dir.join(pos_file))?;
        ensure!(
            t.shape == vec![n, 2],
            "positions shape {:?} != [{n}, 2]",
            t.shape
        );
        let positions = Matrix::from_vec(n, 2, t.data);

        let labels = match v.get("labels").as_str() {
            Some(lf) => {
                let lt = NpyF32::load(&dir.join(lf))?;
                ensure!(lt.shape == vec![n], "labels shape {:?} != [{n}]", lt.shape);
                Some(lt.data.iter().map(|&f| f as u32).collect())
            }
            None => None,
        };

        let b = v.get("bounds");
        let bounds = {
            let cx = b.get("cx").as_f64().context("bounds cx")? as f32;
            let cy = b.get("cy").as_f64().context("bounds cy")? as f32;
            let half_w = b.get("half_w").as_f64().context("bounds half_w")? as f32;
            let half_h = b.get("half_h").as_f64().context("bounds half_h")? as f32;
            let v = View { cx, cy, half_w, half_h };
            // a corrupt manifest must not poison the tile pyramid's root:
            // halves must be finite positives (`1e999` parses to +inf)
            if cx.is_finite()
                && cy.is_finite()
                && half_w.is_finite()
                && half_w > 0.0
                && half_h.is_finite()
                && half_h > 0.0
            {
                v
            } else {
                View::fit(&positions)
            }
        };

        let p = v.get("provenance");
        let provenance = Provenance {
            dataset: p.get("dataset").as_str().unwrap_or("").to_string(),
            seed: p.get("seed").as_i64().unwrap_or(0) as u64,
            epochs: p.get("epochs").as_usize().unwrap_or(0),
            final_loss: p.get("final_loss").as_f64().unwrap_or(f64::NAN),
        };

        Ok(MapArtifact { positions, labels, bounds, provenance })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("nomad_serve_artifact").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn demo_artifact(n: usize) -> MapArtifact {
        let mut data = Vec::with_capacity(n * 2);
        for i in 0..n {
            data.push(i as f32);
            data.push((i % 7) as f32);
        }
        MapArtifact::from_run(
            Matrix::from_vec(n, 2, data),
            Some((0..n as u32).map(|i| i % 5).collect()),
            Provenance { dataset: "demo".into(), seed: 42, epochs: 10, final_loss: 1.25 },
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_with_labels() {
        let dir = tmp("roundtrip");
        let art = demo_artifact(100);
        art.save(&dir).unwrap();
        let back = MapArtifact::load(&dir).unwrap();
        assert_eq!(back.positions, art.positions);
        assert_eq!(back.labels, art.labels);
        assert_eq!(back.provenance.dataset, "demo");
        assert_eq!(back.provenance.seed, 42);
        assert_eq!(back.provenance.epochs, 10);
        assert!((back.provenance.final_loss - 1.25).abs() < 1e-12);
        assert!((back.bounds.cx - art.bounds.cx).abs() < 1e-6);
        assert!((back.bounds.half_w - art.bounds.half_w).abs() < 1e-6);
    }

    #[test]
    fn roundtrip_without_labels() {
        let dir = tmp("nolabels");
        let art = MapArtifact::from_run(
            Matrix::from_vec(3, 2, vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0]),
            None,
            Provenance::default(),
        )
        .unwrap();
        art.save(&dir).unwrap();
        let back = MapArtifact::load(&dir).unwrap();
        assert!(back.labels.is_none());
        assert_eq!(back.positions.rows, 3);
    }

    #[test]
    fn rejects_bad_shapes_and_manifests() {
        // labels length mismatch at assembly
        assert!(MapArtifact::from_run(
            Matrix::zeros(4, 2),
            Some(vec![1, 2]),
            Provenance::default()
        )
        .is_err());
        // 3-column positions
        assert!(
            MapArtifact::from_run(Matrix::zeros(4, 3), None, Provenance::default()).is_err()
        );

        // missing manifest
        let dir = tmp("missing");
        assert!(MapArtifact::load(&dir).is_err());

        // wrong format marker
        let dir = tmp("badformat");
        std::fs::write(dir.join("manifest.json"), r#"{"format": "other"}"#).unwrap();
        assert!(MapArtifact::load(&dir).is_err());

        // n_points disagreeing with the npy shape
        let dir = tmp("badcount");
        demo_artifact(10).save(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        std::fs::write(dir.join("manifest.json"), text.replace("\"n_points\": 10", "\"n_points\": 9"))
            .unwrap();
        assert!(MapArtifact::load(&dir).is_err());
    }

    #[test]
    fn non_finite_loss_roundtrips_as_null() {
        // a diverged (or zero-epoch) run must still produce a loadable
        // artifact: NaN serializes as JSON null, loads back as NaN
        let dir = tmp("nanloss");
        let art = MapArtifact::from_run(
            Matrix::from_vec(2, 2, vec![0.0, 0.0, 1.0, 1.0]),
            None,
            Provenance { final_loss: f64::NAN, ..Default::default() },
        )
        .unwrap();
        art.save(&dir).unwrap();
        let back = MapArtifact::load(&dir).unwrap();
        assert!(back.provenance.final_loss.is_nan());
    }

    #[test]
    fn infinite_bounds_are_refit() {
        // `1e999` parses to +inf, which must fail the bounds guard
        let dir = tmp("infbounds");
        demo_artifact(10).save(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let text = {
            let at = text.find("\"half_w\":").unwrap();
            let end = at + text[at..].find('\n').unwrap();
            format!("{}\"half_w\": 1e999{}", &text[..at], &text[end..])
        };
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let back = MapArtifact::load(&dir).unwrap();
        assert!(back.bounds.half_w.is_finite() && back.bounds.half_w > 0.0);
    }

    #[test]
    fn corrupt_bounds_are_refit() {
        let dir = tmp("badbounds");
        demo_artifact(10).save(&dir).unwrap();
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        // zero out half_w: loader must refit instead of serving a
        // degenerate root view
        let text = {
            let at = text.find("\"half_w\":").unwrap();
            let end = at + text[at..].find('\n').unwrap();
            format!("{}\"half_w\": 0{}", &text[..at], &text[end..])
        };
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let back = MapArtifact::load(&dir).unwrap();
        assert!(back.bounds.half_w > 0.0);
    }
}
