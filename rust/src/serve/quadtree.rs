//! Static packed quadtree over a 2-D embedding (DESIGN.md §10).
//!
//! Built once in O(n log n) from a finished map's positions: points are
//! quantized to a 16-bit grid, sorted by Morton (z-order) code with the
//! point index as tie-breaker, and a flat node array is grown over the
//! sorted order.  Each internal node stores only the index of its first
//! child — the four children are contiguous, in quadrant order, so the
//! structure is a packed implicit tree over one contiguous point layout.
//!
//! Two read operations back the serving layer:
//! * [`Quadtree::range`] — all point ids inside an axis-aligned viewport
//!   rectangle (inclusive bounds), ascending id order;
//! * [`Quadtree::knn`] — the k nearest points to a query position under
//!   the same lexicographic `(d², index)` total order as the distance
//!   engine (DESIGN.md §8), so ties resolve identically everywhere.
//!
//! Non-finite points are excluded at build time; both operations match
//! the brute-force oracles ([`range_naive`], [`knn_naive`]) exactly,
//! ties included (`rust/tests/serve_quadtree.rs`).

use crate::linalg::Matrix;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Max points in a leaf before subdivision (unless the depth cap hits).
const LEAF_CAP: u32 = 64;
/// 16 bits per axis -> at most 16 subdivision levels.
const MAX_DEPTH: usize = 16;

#[derive(Clone, Debug)]
struct Node {
    /// range into the Morton-sorted point arrays
    start: u32,
    end: u32,
    /// tight bounding box of the points in the range
    min_x: f32,
    min_y: f32,
    max_x: f32,
    max_y: f32,
    /// index of the first of four contiguous children; `u32::MAX` = leaf
    first_child: u32,
}

const NO_CHILD: u32 = u32::MAX;

/// A static packed quadtree over the finite rows of an `n x 2` matrix.
#[derive(Clone, Debug)]
pub struct Quadtree {
    /// original row ids, Morton-sorted (ties by id)
    order: Vec<u32>,
    /// coordinates in sorted order (struct-of-arrays for leaf scans)
    xs: Vec<f32>,
    ys: Vec<f32>,
    nodes: Vec<Node>,
}

impl Quadtree {
    /// Build from an `n x 2` position matrix.  Rows with a non-finite
    /// coordinate are excluded from the index.
    pub fn build(positions: &Matrix) -> Quadtree {
        assert_eq!(positions.cols, 2, "quadtree expects n x 2 positions");
        let mut ids: Vec<u32> = Vec::with_capacity(positions.rows);
        for i in 0..positions.rows {
            let r = positions.row(i);
            if r[0].is_finite() && r[1].is_finite() {
                ids.push(i as u32);
            }
        }
        if ids.is_empty() {
            return Quadtree { order: vec![], xs: vec![], ys: vec![], nodes: vec![] };
        }

        // bounds for quantization
        let mut min = [f32::INFINITY; 2];
        let mut max = [f32::NEG_INFINITY; 2];
        for &id in &ids {
            let r = positions.row(id as usize);
            for d in 0..2 {
                min[d] = min[d].min(r[d]);
                max[d] = max[d].max(r[d]);
            }
        }
        let ext = [(max[0] - min[0]).max(1e-30), (max[1] - min[1]).max(1e-30)];

        // Morton codes on a 16-bit grid; sort by (code, id)
        let mut keyed: Vec<(u32, u32)> = ids
            .iter()
            .map(|&id| {
                let r = positions.row(id as usize);
                let qx = quantize(r[0], min[0], ext[0]);
                let qy = quantize(r[1], min[1], ext[1]);
                (spread_bits(qx) | (spread_bits(qy) << 1), id)
            })
            .collect();
        keyed.sort_unstable();

        let n = keyed.len();
        let mut order = Vec::with_capacity(n);
        let mut codes = Vec::with_capacity(n);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for &(code, id) in &keyed {
            codes.push(code);
            order.push(id);
            let r = positions.row(id as usize);
            xs.push(r[0]);
            ys.push(r[1]);
        }

        let mut nodes = Vec::with_capacity(2 * (n as usize / LEAF_CAP as usize + 1));
        nodes.push(make_node(0, n as u32, &xs, &ys));
        subdivide(&mut nodes, &codes, &xs, &ys, 0, 0);
        Quadtree { order, xs, ys, nodes }
    }

    /// Number of indexed (finite) points.
    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// All point ids with `min_x <= x <= max_x && min_y <= y <= max_y`,
    /// ascending id order.  An empty/inverted/non-finite rectangle yields
    /// an empty result.
    pub fn range(&self, min_x: f32, min_y: f32, max_x: f32, max_y: f32) -> Vec<u32> {
        let mut out = Vec::new();
        if self.nodes.is_empty() || !(min_x <= max_x) || !(min_y <= max_y) {
            return out;
        }
        let mut stack = vec![0u32];
        while let Some(ni) = stack.pop() {
            let node = &self.nodes[ni as usize];
            if node.start == node.end
                || node.max_x < min_x
                || node.min_x > max_x
                || node.max_y < min_y
                || node.min_y > max_y
            {
                continue;
            }
            if min_x <= node.min_x
                && node.max_x <= max_x
                && min_y <= node.min_y
                && node.max_y <= max_y
            {
                out.extend_from_slice(&self.order[node.start as usize..node.end as usize]);
            } else if node.first_child == NO_CHILD {
                for p in node.start as usize..node.end as usize {
                    let (x, y) = (self.xs[p], self.ys[p]);
                    if x >= min_x && x <= max_x && y >= min_y && y <= max_y {
                        out.push(self.order[p]);
                    }
                }
            } else {
                for c in 0..4 {
                    stack.push(node.first_child + c);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// The `k` nearest indexed points to `(qx, qy)` in embedding space,
    /// ascending under the lexicographic `(d², id)` order (ties included).
    /// Returns fewer than `k` entries only when fewer points exist; a
    /// non-finite query yields an empty result.
    pub fn knn(&self, qx: f32, qy: f32, k: usize) -> Vec<(u32, f32)> {
        if k == 0 || self.nodes.is_empty() || !qx.is_finite() || !qy.is_finite() {
            return Vec::new();
        }
        // bounded worst-first candidate set: peek() is the current worst
        let mut best: BinaryHeap<Cand> = BinaryHeap::with_capacity(k + 1);
        // best-first node frontier by min distance to the node's bbox
        let mut frontier: BinaryHeap<NodeEntry> = BinaryHeap::new();
        frontier.push(NodeEntry { d2: self.node_d2(0, qx, qy), node: 0 });
        while let Some(NodeEntry { d2, node }) = frontier.pop() {
            if best.len() == k {
                let worst = best.peek().unwrap();
                if d2.total_cmp(&worst.d2) == Ordering::Greater {
                    break; // best-first: everything later is farther still
                }
            }
            let nd = &self.nodes[node as usize];
            if nd.start == nd.end {
                continue;
            }
            if nd.first_child == NO_CHILD {
                for p in nd.start as usize..nd.end as usize {
                    let c = Cand { d2: point_d2(self.xs[p], self.ys[p], qx, qy), id: self.order[p] };
                    if best.len() < k {
                        best.push(c);
                    } else if c.cmp(best.peek().unwrap()) == Ordering::Less {
                        best.pop();
                        best.push(c);
                    }
                }
            } else {
                for c in 0..4 {
                    let child = nd.first_child + c;
                    if self.nodes[child as usize].start != self.nodes[child as usize].end {
                        frontier.push(NodeEntry { d2: self.node_d2(child, qx, qy), node: child });
                    }
                }
            }
        }
        let mut out: Vec<(u32, f32)> = best.into_iter().map(|c| (c.id, c.d2)).collect();
        out.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        out
    }

    /// Squared distance from the query to a node's bounding box (0 inside).
    fn node_d2(&self, node: u32, qx: f32, qy: f32) -> f32 {
        let n = &self.nodes[node as usize];
        let dx = (n.min_x - qx).max(0.0).max(qx - n.max_x);
        let dy = (n.min_y - qy).max(0.0).max(qy - n.max_y);
        dx * dx + dy * dy
    }
}

/// Squared point distance — the shared expression both the tree and the
/// oracle evaluate, so results are bitwise comparable.
#[inline]
pub fn point_d2(x: f32, y: f32, qx: f32, qy: f32) -> f32 {
    let dx = x - qx;
    let dy = y - qy;
    dx * dx + dy * dy
}

/// Brute-force range oracle: same inclusion rule, ascending id order.
pub fn range_naive(positions: &Matrix, min_x: f32, min_y: f32, max_x: f32, max_y: f32) -> Vec<u32> {
    let mut out = Vec::new();
    for i in 0..positions.rows {
        let r = positions.row(i);
        if !r[0].is_finite() || !r[1].is_finite() {
            continue;
        }
        if r[0] >= min_x && r[0] <= max_x && r[1] >= min_y && r[1] <= max_y {
            out.push(i as u32);
        }
    }
    out
}

/// Brute-force kNN oracle: full sort under `(d², id)`, first `k` kept.
pub fn knn_naive(positions: &Matrix, qx: f32, qy: f32, k: usize) -> Vec<(u32, f32)> {
    if k == 0 || !qx.is_finite() || !qy.is_finite() {
        return Vec::new();
    }
    let mut all: Vec<(u32, f32)> = (0..positions.rows)
        .filter(|&i| {
            let r = positions.row(i);
            r[0].is_finite() && r[1].is_finite()
        })
        .map(|i| {
            let r = positions.row(i);
            (i as u32, point_d2(r[0], r[1], qx, qy))
        })
        .collect();
    all.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    all.truncate(k);
    all
}

/// Quantize a coordinate to the 16-bit Morton grid.
fn quantize(v: f32, min: f32, ext: f32) -> u32 {
    (((v - min) / ext * 65535.0).clamp(0.0, 65535.0)) as u32
}

/// Spread the low 16 bits of `v` to the even bit positions of a u32.
fn spread_bits(mut v: u32) -> u32 {
    v &= 0xFFFF;
    v = (v | (v << 8)) & 0x00FF_00FF;
    v = (v | (v << 4)) & 0x0F0F_0F0F;
    v = (v | (v << 2)) & 0x3333_3333;
    v = (v | (v << 1)) & 0x5555_5555;
    v
}

fn make_node(start: u32, end: u32, xs: &[f32], ys: &[f32]) -> Node {
    let mut n = Node {
        start,
        end,
        min_x: f32::INFINITY,
        min_y: f32::INFINITY,
        max_x: f32::NEG_INFINITY,
        max_y: f32::NEG_INFINITY,
        first_child: NO_CHILD,
    };
    for p in start as usize..end as usize {
        n.min_x = n.min_x.min(xs[p]);
        n.max_x = n.max_x.max(xs[p]);
        n.min_y = n.min_y.min(ys[p]);
        n.max_y = n.max_y.max(ys[p]);
    }
    n
}

/// Split `node` (a range of Morton-sorted points) into its four quadrant
/// children, pushed contiguously, then recurse.  The quadrant of a point
/// at `depth` is the 2-bit field `(code >> (2*(15-depth))) & 3`, which is
/// non-decreasing inside a sorted range sharing the coarser prefix — so
/// each child is a contiguous subrange found by binary search.
fn subdivide(
    nodes: &mut Vec<Node>,
    codes: &[u32],
    xs: &[f32],
    ys: &[f32],
    node: usize,
    depth: usize,
) {
    let (start, end) = (nodes[node].start, nodes[node].end);
    if end - start <= LEAF_CAP || depth >= MAX_DEPTH {
        return;
    }
    let shift = 2 * (MAX_DEPTH - 1 - depth) as u32;
    let mut cut = [start, end, end, end, end];
    for q in 0..3u32 {
        let lo = cut[q as usize] as usize;
        let off = codes[lo..end as usize].partition_point(|&c| (c >> shift) & 3 <= q);
        cut[q as usize + 1] = lo as u32 + off as u32;
    }
    let first = nodes.len() as u32;
    nodes[node].first_child = first;
    for q in 0..4 {
        nodes.push(make_node(cut[q], cut[q + 1], xs, ys));
    }
    for q in 0..4 {
        subdivide(nodes, codes, xs, ys, (first + q) as usize, depth + 1);
    }
}

/// A candidate point ordered lexicographically by `(d², id)`.
#[derive(Clone, Copy, Debug)]
struct Cand {
    d2: f32,
    id: u32,
}

impl PartialEq for Cand {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Cand {
    fn cmp(&self, o: &Self) -> Ordering {
        self.d2.total_cmp(&o.d2).then(self.id.cmp(&o.id))
    }
}

/// A frontier node ordered so the *nearest* node pops first.
#[derive(Clone, Copy, Debug)]
struct NodeEntry {
    d2: f32,
    node: u32,
}

impl PartialEq for NodeEntry {
    fn eq(&self, o: &Self) -> bool {
        self.cmp(o) == Ordering::Equal
    }
}
impl Eq for NodeEntry {}
impl PartialOrd for NodeEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for NodeEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        // reversed: BinaryHeap is a max-heap, we want min-d2 first
        o.d2.total_cmp(&self.d2).then(o.node.cmp(&self.node))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn int_points(rng: &mut Rng, n: usize, hi: usize) -> Matrix {
        let mut m = Matrix::zeros(n, 2);
        for v in m.data.iter_mut() {
            *v = rng.below(hi) as f32;
        }
        m
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let t = Quadtree::build(&Matrix::zeros(0, 2));
        assert!(t.is_empty());
        assert!(t.range(-1.0, -1.0, 1.0, 1.0).is_empty());
        assert!(t.knn(0.0, 0.0, 5).is_empty());

        // all points identical: subdivision cannot separate them
        let m = Matrix::from_vec(10, 2, vec![3.0; 20]);
        let t = Quadtree::build(&m);
        assert_eq!(t.len(), 10);
        assert_eq!(t.range(3.0, 3.0, 3.0, 3.0).len(), 10);
        let nn = t.knn(3.0, 3.0, 4);
        assert_eq!(nn.len(), 4);
        assert_eq!(nn.iter().map(|&(id, _)| id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn nan_rows_are_excluded() {
        let m = Matrix::from_vec(
            4,
            2,
            vec![0.0, 0.0, f32::NAN, 1.0, 2.0, f32::INFINITY, 5.0, 5.0],
        );
        let t = Quadtree::build(&m);
        assert_eq!(t.len(), 2);
        assert_eq!(t.range(-10.0, -10.0, 10.0, 10.0), vec![0, 3]);
        assert_eq!(range_naive(&m, -10.0, -10.0, 10.0, 10.0), vec![0, 3]);
        let nn = t.knn(0.0, 0.0, 4);
        assert_eq!(nn.len(), 2);
        assert_eq!(nn[0].0, 0);
    }

    #[test]
    fn inverted_or_nan_rect_is_empty() {
        let mut rng = Rng::new(5);
        let m = int_points(&mut rng, 50, 8);
        let t = Quadtree::build(&m);
        assert!(t.range(5.0, 0.0, 1.0, 8.0).is_empty());
        assert!(t.range(f32::NAN, 0.0, 1.0, 8.0).is_empty());
    }

    #[test]
    fn knn_matches_oracle_small() {
        let mut rng = Rng::new(1);
        let m = int_points(&mut rng, 200, 6); // heavy ties on purpose
        let t = Quadtree::build(&m);
        for k in [1, 3, 17, 200, 300] {
            let got = t.knn(2.0, 3.0, k);
            let want = knn_naive(&m, 2.0, 3.0, k);
            assert_eq!(got, want, "k={k}");
        }
    }

    #[test]
    fn range_matches_oracle_small() {
        let mut rng = Rng::new(2);
        let m = int_points(&mut rng, 300, 10);
        let t = Quadtree::build(&m);
        assert_eq!(t.range(2.0, 3.0, 6.0, 7.0), range_naive(&m, 2.0, 3.0, 6.0, 7.0));
        // full-cover rectangle returns every point
        assert_eq!(t.range(0.0, 0.0, 10.0, 10.0).len(), 300);
    }
}
