//! Sharded LRU cache over encoded tiles (DESIGN.md §10/§11).
//!
//! Keys are `(generation, packed z/x/y)` pairs: the packed tile
//! coordinate comes from [`crate::serve::tiles::tile_key`], and the
//! generation is the serving artifact's version (the checkpoint epoch
//! under `nomad serve --watch`, 0 for a static artifact).  Keying by
//! generation means a hot-swap never serves stale tiles — entries from
//! an old generation simply stop being requested and age out through
//! normal LRU eviction.  Values are `Arc`-shared encoded PNG bytes, so a
//! hit hands back a refcount bump, never a copy.  The key space is split
//! across independently locked shards (contention scales with worker
//! count, not request count); inside a shard, recency is a monotone
//! per-shard tick: a `HashMap` holds `key -> (tick, value)` and a
//! `BTreeMap` mirrors `tick -> key`, so get/put/evict are all O(log n).
//! Hit, miss, and eviction counters are obs counters (DESIGN.md §15):
//! detached per-instance by default (so every cache — and every test
//! server — counts independently), or registered handles injected by the
//! HTTP server via [`TileCache::with_counters`] so `/stats` and
//! `/metrics` read one source of truth.

use crate::obs::metrics::Counter;
use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

/// `(artifact generation, packed z/x/y tile coordinate)`.
pub type CacheKey = (u64, u64);

const N_SHARDS: usize = 16;

#[derive(Default)]
struct Shard {
    /// key -> (recency tick, value)
    map: HashMap<CacheKey, (u64, Arc<Vec<u8>>)>,
    /// recency tick -> key (oldest first)
    by_tick: BTreeMap<u64, CacheKey>,
    tick: u64,
}

/// Cache counters snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub capacity: usize,
}

/// A sharded LRU over encoded tiles.  `capacity` is the total entry
/// budget across shards; 0 disables caching entirely (every get is a
/// miss, every put a no-op) — the load bench uses that for its
/// cache-off baseline.
pub struct TileCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_cap: usize,
    capacity: usize,
    hits: Counter,
    misses: Counter,
    evictions: Counter,
}

impl TileCache {
    pub fn new(capacity: usize) -> TileCache {
        TileCache::with_counters(
            capacity,
            Counter::detached(),
            Counter::detached(),
            Counter::detached(),
        )
    }

    /// Build a cache that counts through caller-provided obs handles
    /// (registered in the server's registry, so `/metrics` exports them).
    pub fn with_counters(
        capacity: usize,
        hits: Counter,
        misses: Counter,
        evictions: Counter,
    ) -> TileCache {
        TileCache {
            shards: (0..N_SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_cap: capacity.div_ceil(N_SHARDS),
            capacity,
            hits,
            misses,
            evictions,
        }
    }

    fn shard(&self, key: CacheKey) -> &Mutex<Shard> {
        let h = key
            .1
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(key.0.wrapping_mul(0xA24B_AED4_963E_E407));
        &self.shards[(h >> 56) as usize % N_SHARDS]
    }

    /// Look up a tile, refreshing its recency on a hit.
    pub fn get(&self, key: CacheKey) -> Option<Arc<Vec<u8>>> {
        if self.capacity == 0 {
            self.misses.inc();
            return None;
        }
        let mut guard = self.shard(key).lock().unwrap();
        let s = &mut *guard;
        s.tick += 1;
        let fresh = s.tick;
        match s.map.get_mut(&key) {
            Some(entry) => {
                let old = entry.0;
                entry.0 = fresh;
                let value = Arc::clone(&entry.1);
                s.by_tick.remove(&old);
                s.by_tick.insert(fresh, key);
                self.hits.inc();
                Some(value)
            }
            None => {
                self.misses.inc();
                None
            }
        }
    }

    /// Insert (or refresh) a tile, evicting the least-recently-used entry
    /// of the shard when over budget.
    pub fn put(&self, key: CacheKey, value: Arc<Vec<u8>>) {
        if self.capacity == 0 {
            return;
        }
        let mut guard = self.shard(key).lock().unwrap();
        let s = &mut *guard;
        s.tick += 1;
        let fresh = s.tick;
        if let Some((old, _)) = s.map.insert(key, (fresh, value)) {
            s.by_tick.remove(&old);
        }
        s.by_tick.insert(fresh, key);
        while s.map.len() > self.per_shard_cap {
            // oldest tick first; the maps are kept in lockstep
            let (_, victim) = s.by_tick.pop_first().expect("by_tick mirrors map");
            s.map.remove(&victim);
            self.evictions.inc();
        }
    }

    /// Whether caching is active (capacity > 0).  The server skips its
    /// single-flight render locks when disabled — with nothing to share
    /// through, serializing identical renders would only add contention.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.value(),
            misses: self.misses.value(),
            evictions: self.evictions.value(),
            entries: self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(b: u8) -> Arc<Vec<u8>> {
        Arc::new(vec![b; 4])
    }

    /// Shard index of a key, mirroring `TileCache::shard`.
    fn shard_of(k: CacheKey) -> usize {
        let h = k
            .1
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(k.0.wrapping_mul(0xA24B_AED4_963E_E407));
        (h >> 56) as usize % N_SHARDS
    }

    #[test]
    fn hit_miss_and_value_identity() {
        let c = TileCache::new(64);
        assert!(c.get((0, 1)).is_none());
        c.put((0, 1), val(7));
        let v = c.get((0, 1)).expect("hit");
        assert_eq!(*v, vec![7; 4]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
    }

    #[test]
    fn generations_are_distinct_keys() {
        // the same tile under a new artifact generation is a different
        // entry — the hot-swap correctness contract
        let c = TileCache::new(64);
        c.put((1, 42), val(1));
        c.put((2, 42), val(2));
        assert_eq!(*c.get((1, 42)).unwrap(), vec![1; 4]);
        assert_eq!(*c.get((2, 42)).unwrap(), vec![2; 4]);
    }

    #[test]
    fn evicts_least_recently_used_first() {
        // capacity 16 across 16 shards -> 1 entry per shard; craft keys
        // that land in one shard by brute force
        let c = TileCache::new(16);
        let target = shard_of((0, 0));
        let mut same: Vec<CacheKey> =
            (0..5_000u64).map(|k| (0u64, k)).filter(|&k| shard_of(k) == target).collect();
        assert!(same.len() >= 3, "need 3 colliding keys");
        same.truncate(3);
        let (a, b, d) = (same[0], same[1], same[2]);
        c.put(a, val(1));
        c.put(b, val(2)); // evicts a (per-shard cap 1)
        assert!(c.get(a).is_none());
        assert_eq!(*c.get(b).unwrap(), vec![2; 4]);
        c.put(d, val(3)); // evicts b
        assert!(c.get(b).is_none());
        assert_eq!(*c.get(d).unwrap(), vec![3; 4]);
        assert_eq!(c.stats().evictions, 2);
    }

    #[test]
    fn get_refreshes_recency() {
        let c = TileCache::new(2 * N_SHARDS);
        // find three keys in one shard (per-shard cap = 2)
        let target = shard_of((0, 0));
        let keys: Vec<CacheKey> = (0..10_000u64)
            .map(|k| (0u64, k))
            .filter(|&k| shard_of(k) == target)
            .take(3)
            .collect();
        assert_eq!(keys.len(), 3);
        c.put(keys[0], val(1));
        c.put(keys[1], val(2));
        assert!(c.get(keys[0]).is_some()); // refresh keys[0]; keys[1] is now LRU
        c.put(keys[2], val(3)); // evicts keys[1]
        assert!(c.get(keys[0]).is_some());
        assert!(c.get(keys[1]).is_none());
        assert!(c.get(keys[2]).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let c = TileCache::new(0);
        c.put((0, 1), val(9));
        assert!(c.get((0, 1)).is_none());
        let s = c.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.misses, 1);
    }

    #[test]
    fn concurrent_access_is_safe() {
        let c = Arc::new(TileCache::new(128));
        std::thread::scope(|sc| {
            for t in 0..8u64 {
                let c = Arc::clone(&c);
                sc.spawn(move || {
                    for i in 0..500u64 {
                        let k = (t * 131 + i) % 200;
                        if c.get((0, k)).is_none() {
                            c.put((0, k), Arc::new(vec![(k % 251) as u8; 8]));
                        }
                    }
                });
            }
        });
        let s = c.stats();
        assert!(s.hits + s.misses == 4_000);
        assert!(s.entries <= 128 + N_SHARDS);
    }
}
