//! Slippy-style `z/x/y` LOD tile pyramid over a map artifact
//! (DESIGN.md §10).
//!
//! The root tile (0/0/0) is the artifact's fitted square bounds; zoom
//! level `z` splits it into `2^z x 2^z` tiles, `x` increasing along +x
//! and `y` along +y of the embedding.  Each tile rasterizes **only** the
//! points the quadtree returns for its extent, so deep-zoom tiles touch
//! a vanishing fraction of the map.  At low zoom, where a tile would
//! cover more than `max_points` points, a density-preserving uniform
//! subsample is drawn from an RNG seeded purely by `(seed, z, x, y)` —
//! the same tile is bitwise identical across requests, threads, and
//! processes (the determinism contract the cache and the tests rely on).

use crate::linalg::Matrix;
use crate::serve::artifact::MapArtifact;
use crate::serve::quadtree::Quadtree;
use crate::util::rng::{splitmix64, Rng};
use crate::viz::{density_map, png, Raster, View};
use crate::util::error::Result;

/// Tile rendering knobs.
#[derive(Clone, Copy, Debug)]
pub struct TileConfig {
    /// tile edge in pixels
    pub tile_px: usize,
    /// thinning threshold: tiles covering more points subsample to this
    pub max_points: usize,
    /// base seed mixed into every tile's thinning stream
    pub seed: u64,
    /// deepest zoom level served; clamped to [`MAX_ZOOM_CAP`] at renderer
    /// construction so tile coordinates always fit [`tile_key`]'s packing
    pub max_zoom: u32,
}

/// Hard ceiling on zoom.  Two constraints: coordinates must fit
/// [`tile_key`]'s 29-bit fields, and — the binding one — tile centers
/// `(x + 0.5) · side/2^z` must stay exactly distinguishable after the
/// f32 cast in [`TileRenderer::tile_view`], which needs `z + 1` offset
/// bits inside f32's 24-bit significand: z ≤ 22 keeps adjacent tiles'
/// geometry distinct with a bit to spare (2^22 tiles/axis ≈ 1.7e13
/// tiles total — far beyond any practical map).
pub const MAX_ZOOM_CAP: u32 = 22;

impl Default for TileConfig {
    fn default() -> Self {
        TileConfig { tile_px: 256, max_points: 50_000, seed: 0, max_zoom: 20 }
    }
}

/// Renders map tiles from an artifact through its quadtree.
pub struct TileRenderer {
    art: MapArtifact,
    tree: Quadtree,
    /// square root extent: (min_x, min_y, side)
    root: (f32, f32, f32),
    cfg: TileConfig,
}

impl TileRenderer {
    pub fn new(art: MapArtifact, cfg: TileConfig) -> TileRenderer {
        let cfg = TileConfig { max_zoom: cfg.max_zoom.min(MAX_ZOOM_CAP), ..cfg };
        let tree = Quadtree::build(&art.positions);
        let b = &art.bounds;
        let half = b.half_w.max(b.half_h).max(1e-6);
        let root = (b.cx - half, b.cy - half, 2.0 * half);
        TileRenderer { art, tree, root, cfg }
    }

    pub fn artifact(&self) -> &MapArtifact {
        &self.art
    }

    pub fn quadtree(&self) -> &Quadtree {
        &self.tree
    }

    pub fn config(&self) -> &TileConfig {
        &self.cfg
    }

    /// The embedding-space viewport of tile `z/x/y`, or `None` when the
    /// coordinates fall outside the pyramid.
    pub fn tile_view(&self, z: u32, x: u32, y: u32) -> Option<View> {
        if z > self.cfg.max_zoom {
            return None;
        }
        let side_tiles = 1u64 << z; // z <= MAX_ZOOM_CAP, shift-safe
        if (x as u64) >= side_tiles || (y as u64) >= side_tiles {
            return None;
        }
        // center math in f64: `x as f32` alone would collapse adjacent
        // tiles once x exceeds f32's 24-bit significand
        let ts = self.root.2 as f64 / side_tiles as f64;
        Some(View {
            cx: (self.root.0 as f64 + (x as f64 + 0.5) * ts) as f32,
            cy: (self.root.1 as f64 + (y as f64 + 0.5) * ts) as f32,
            half_w: (ts / 2.0) as f32,
            half_h: (ts / 2.0) as f32,
        })
    }

    /// Rasterize tile `z/x/y`.  `None` for out-of-pyramid coordinates.
    pub fn render(&self, z: u32, x: u32, y: u32) -> Option<Raster> {
        let view = self.tile_view(z, x, y)?;
        let ids = self.tree.range(
            view.cx - view.half_w,
            view.cy - view.half_h,
            view.cx + view.half_w,
            view.cy + view.half_h,
        );
        let ids = self.thin(&ids, z, x, y);
        let sub = self.art.positions.gather(&ids);
        let sub_labels: Option<Vec<u32>> = self
            .art
            .labels
            .as_ref()
            .map(|ls| ids.iter().map(|&i| ls[i]).collect());
        Some(density_map(
            &sub,
            sub_labels.as_deref(),
            &view,
            self.cfg.tile_px,
            self.cfg.tile_px,
        ))
    }

    /// Rasterize and PNG-encode tile `z/x/y`.
    pub fn render_png(&self, z: u32, x: u32, y: u32) -> Option<Result<Vec<u8>>> {
        let r = self.render(z, x, y)?;
        Some(png::encode_rgb(r.width, r.height, &r.pixels))
    }

    /// Deterministic density-preserving thinning: when the candidate set
    /// exceeds `max_points`, draw a uniform subsample from an RNG seeded
    /// by `(seed, z, x, y)` only.  Input ids are ascending (quadtree
    /// contract); output ids are ascending too, as `usize` for `gather`.
    fn thin(&self, ids: &[u32], z: u32, x: u32, y: u32) -> Vec<usize> {
        if ids.len() <= self.cfg.max_points {
            return ids.iter().map(|&i| i as usize).collect();
        }
        let mut rng = Rng::new(tile_seed(self.cfg.seed, z, x, y));
        let mut pick = rng.sample_distinct(ids.len(), self.cfg.max_points);
        pick.sort_unstable();
        pick.into_iter().map(|p| ids[p] as usize).collect()
    }
}

/// Mix `(base, z, x, y)` into one well-spread seed.
pub fn tile_seed(base: u64, z: u32, x: u32, y: u32) -> u64 {
    let mut s = base
        ^ ((z as u64) << 58)
        ^ ((x as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
        ^ ((y as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
    splitmix64(&mut s)
}

/// Pack tile coordinates into one cache key.  Injective because served
/// coordinates satisfy `z <= MAX_ZOOM_CAP` and `x, y < 2^z <= 2^29`
/// (enforced by the renderer's zoom clamp + `tile_view` bounds check).
pub fn tile_key(z: u32, x: u32, y: u32) -> u64 {
    debug_assert!(z <= MAX_ZOOM_CAP && (x as u64) < (1 << 29) && (y as u64) < (1 << 29));
    ((z as u64) << 58) | ((x as u64) << 29) | y as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::artifact::Provenance;
    use crate::util::rng::Rng;

    fn renderer(n: usize, max_points: usize) -> TileRenderer {
        let mut rng = Rng::new(3);
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            data.push(rng.normal() * 3.0);
            data.push(rng.normal() * 3.0);
        }
        let art = MapArtifact::from_run(
            Matrix::from_vec(n, 2, data),
            Some((0..n as u32).map(|i| i % 4).collect()),
            Provenance::default(),
        )
        .unwrap();
        TileRenderer::new(
            art,
            TileConfig { tile_px: 64, max_points, ..Default::default() },
        )
    }

    #[test]
    fn root_tile_covers_all_points() {
        let r = renderer(500, 50_000);
        let raster = r.render(0, 0, 0).unwrap();
        assert_eq!((raster.width, raster.height), (64, 64));
        let lit: u32 = raster.pixels.iter().map(|&b| b as u32).sum();
        assert!(lit > 0, "root tile should not be black");
    }

    #[test]
    fn out_of_pyramid_coordinates_rejected() {
        let r = renderer(50, 50_000);
        assert!(r.render(0, 1, 0).is_none());
        assert!(r.render(2, 4, 0).is_none());
        assert!(r.render(2, 0, 4).is_none());
        assert!(r.render(99, 0, 0).is_none());
        assert!(r.render(1, 1, 1).is_some());
    }

    #[test]
    fn tiles_are_bitwise_reproducible() {
        // force thinning so the seeded path is what we reproduce
        let r = renderer(2_000, 200);
        for (z, x, y) in [(0, 0, 0), (1, 1, 0), (2, 1, 2)] {
            let a = r.render_png(z, x, y).unwrap().unwrap();
            let b = r.render_png(z, x, y).unwrap().unwrap();
            assert_eq!(a, b, "tile {z}/{x}/{y} not reproducible");
            // and from a freshly built renderer (new quadtree, new RNG use)
            let r2 = renderer(2_000, 200);
            let c = r2.render_png(z, x, y).unwrap().unwrap();
            assert_eq!(a, c, "tile {z}/{x}/{y} differs across renderer instances");
        }
    }

    #[test]
    fn children_partition_the_parent_extent() {
        let r = renderer(100, 50_000);
        let parent = r.tile_view(1, 0, 1).unwrap();
        let c00 = r.tile_view(2, 0, 2).unwrap();
        let c11 = r.tile_view(2, 1, 3).unwrap();
        assert!((c00.half_w * 2.0 - parent.half_w).abs() < 1e-5);
        // child centers sit inside the parent
        assert!((c00.cx - parent.cx).abs() <= parent.half_w);
        assert!((c11.cy - parent.cy).abs() <= parent.half_h);
    }

    #[test]
    fn thinning_caps_points_and_preserves_determinism() {
        let r = renderer(3_000, 100);
        let view = r.tile_view(0, 0, 0).unwrap();
        let ids = r.quadtree().range(
            view.cx - view.half_w,
            view.cy - view.half_h,
            view.cx + view.half_w,
            view.cy + view.half_h,
        );
        assert!(ids.len() > 100);
        let a = r.thin(&ids, 0, 0, 0);
        assert_eq!(a.len(), 100);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "thinned ids stay ascending");
        assert_eq!(a, r.thin(&ids, 0, 0, 0), "same tile, same sample");
        assert_ne!(a, r.thin(&ids, 1, 0, 0), "different tile, different sample");
    }

    #[test]
    fn extreme_max_zoom_is_clamped_to_key_space() {
        let art = MapArtifact::from_run(
            Matrix::from_vec(1, 2, vec![0.0, 0.0]),
            None,
            Provenance::default(),
        )
        .unwrap();
        let r = TileRenderer::new(art, TileConfig { max_zoom: u32::MAX, ..Default::default() });
        assert_eq!(r.config().max_zoom, MAX_ZOOM_CAP);
        // beyond the cap: rejected (would otherwise alias tile_key bits
        // or overflow the shift); at the cap: served
        assert!(r.tile_view(MAX_ZOOM_CAP + 1, 0, 0).is_none());
        assert!(r.tile_view(64, 0, 0).is_none());
        assert!(r.tile_view(MAX_ZOOM_CAP, 0, 0).is_some());
    }

    #[test]
    fn tile_key_is_injective_on_the_pyramid() {
        let mut seen = std::collections::HashSet::new();
        for z in 0..5 {
            for x in 0..(1 << z) {
                for y in 0..(1 << z) {
                    assert!(seen.insert(tile_key(z, x, y)), "collision at {z}/{x}/{y}");
                }
            }
        }
    }
}
