//! Zero-dependency threaded HTTP/1.1 map server (DESIGN.md §10).
//!
//! `std::net::TcpListener` + a fixed worker pool: one accept thread
//! pushes connections into a **bounded** queue (`sync_channel`); workers
//! pull from the shared receiver and serve one request per connection
//! (`Connection: close` — the load profile is many short loopback/edge
//! requests, and closing keeps worker state trivial).  Overflowing the
//! queue answers `503` immediately instead of building unbounded backlog.
//!
//! Routes:
//! * `GET /tiles/{z}/{x}/{y}.png` — LOD tile (cache -> render -> encode);
//! * `GET /query?x=&y=&k=`        — embedding-space k-nearest points, JSON;
//! * `GET /stats`                  — cache/latency/request counters, JSON;
//! * `GET /metrics`                — Prometheus text exposition (obs);
//! * `GET /`                       — plain-text endpoint listing.
//!
//! Telemetry flows through `obs` (DESIGN.md §15): request counters and
//! per-route latency histograms live in a per-server instance registry
//! (tests spin up many servers per process), merged with the process-wide
//! registry at `/metrics` scrape time.  The `/stats` JSON keeps its
//! original field names — it now reads from the same obs handles.
//!
//! Tiles are bitwise-deterministic (see `serve::tiles`), so the cache can
//! never serve a stale-but-different byte stream, and concurrent clients
//! always observe identical tiles.

use crate::checkpoint::RunStore;
use crate::obs::export::prometheus_text;
use crate::obs::metrics::{Counter, Gauge, Histogram, Registry, DURATION_BUCKETS_S};
use crate::serve::artifact::MapArtifact;
use crate::serve::cache::{CacheKey, TileCache};
use crate::serve::tiles::{tile_key, TileConfig, TileRenderer};
use crate::util::clock::{self, Stopwatch};
use crate::util::error::{Context, Result};
use crate::util::json::{arr, num, obj, Json};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// bind address, e.g. `127.0.0.1:8080` (port 0 picks a free port)
    pub addr: String,
    /// worker thread count
    pub workers: usize,
    /// bounded accept-queue depth; overflow answers 503
    pub backlog: usize,
    /// total tile-cache entries (0 disables the cache)
    pub cache_entries: usize,
    /// tile rendering knobs
    pub tile: TileConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:8080".to_string(),
            workers: 8,
            backlog: 64,
            cache_entries: 2048,
            tile: TileConfig::default(),
        }
    }
}

/// Per-server obs handles: an instance-scoped registry (each test server
/// must count independently) plus the handles recorded on the hot path.
/// `latency_all` is detached — it backs the `/stats` latency summary
/// across all routes; the registered per-route histograms are what
/// `/metrics` exposes.
struct ServeMetrics {
    registry: Registry,
    requests: Counter,
    tiles_served: Counter,
    queries_served: Counter,
    errors: Counter,
    swaps: Counter,
    generation: Gauge,
    cache_entries: Gauge,
    latency_all: Histogram,
    lat_tiles: Histogram,
    lat_query: Histogram,
    lat_stats: Histogram,
    lat_metrics: Histogram,
    lat_other: Histogram,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        let r = Registry::new();
        let lat = |route: &str| {
            r.histogram(
                "nomad_serve_request_seconds",
                "Request service time by route.",
                &DURATION_BUCKETS_S,
                &[("route", route)],
            )
        };
        ServeMetrics {
            requests: r.counter("nomad_serve_requests_total", "Requests accepted.", &[]),
            tiles_served: r.counter("nomad_serve_tiles_total", "Tiles served.", &[]),
            queries_served: r.counter("nomad_serve_queries_total", "kNN queries served.", &[]),
            errors: r.counter("nomad_serve_errors_total", "Requests answered 4xx/5xx.", &[]),
            swaps: r.counter("nomad_serve_swaps_total", "Artifact hot swaps completed.", &[]),
            generation: r.gauge("nomad_serve_generation", "Artifact generation served.", &[]),
            cache_entries: r.gauge("nomad_serve_cache_entries", "Live tile-cache entries.", &[]),
            latency_all: Histogram::detached(&DURATION_BUCKETS_S),
            lat_tiles: lat("/tiles"),
            lat_query: lat("/query"),
            lat_stats: lat("/stats"),
            lat_metrics: lat("/metrics"),
            lat_other: lat("other"),
            registry: r,
        }
    }

    fn record_latency(&self, route: Route, secs: f64) {
        self.latency_all.observe(secs);
        match route {
            Route::Tiles => &self.lat_tiles,
            Route::Query => &self.lat_query,
            Route::Stats => &self.lat_stats,
            Route::Metrics => &self.lat_metrics,
            Route::Other => &self.lat_other,
        }
        .observe(secs);
    }
}

#[derive(Clone, Copy)]
enum Route {
    Tiles,
    Query,
    Stats,
    Metrics,
    Other,
}

/// Stripes for the single-flight render locks: enough that unrelated
/// cold tiles rarely serialize, few enough to cost nothing.
const RENDER_STRIPES: usize = 64;

/// Shared server state: the current `(generation, renderer)` pair behind
/// an `RwLock` so `nomad serve --watch` can hot-swap to a newer checkpoint
/// artifact without restarting (DESIGN.md §11), the generation-keyed tile
/// cache, and counters.
pub struct ServerState {
    /// `(artifact generation, renderer)` — swapped atomically as a pair so
    /// a request never mixes one generation's tiles with another's cache
    /// slots; the generation is the checkpoint epoch under `--watch`, 0
    /// for a static artifact
    renderer: RwLock<(u64, Arc<TileRenderer>)>,
    cache: TileCache,
    /// per-key-stripe single-flight locks for cold-tile renders
    render_locks: Vec<Mutex<()>>,
    metrics: ServeMetrics,
}

impl ServerState {
    /// Snapshot the current generation + renderer (cheap: one Arc bump).
    fn current(&self) -> (u64, Arc<TileRenderer>) {
        let g = self.renderer.read().unwrap();
        (g.0, Arc::clone(&g.1))
    }

    /// The generation currently being served.
    pub fn generation(&self) -> u64 {
        self.renderer.read().unwrap().0
    }

    /// Replace the serving artifact.  Requests already holding the old
    /// renderer finish against it; new requests see the new generation.
    pub fn swap(&self, generation: u64, renderer: TileRenderer) {
        let mut g = self.renderer.write().unwrap();
        *g = (generation, Arc::new(renderer));
        drop(g);
        self.metrics.swaps.inc();
        self.metrics.generation.set(generation as f64);
    }

    /// Counters + latency snapshot as the `/stats` JSON payload.  The
    /// field names are a stable contract (regression-tested); the values
    /// now come from the obs handles (latency quantiles are
    /// bucket-interpolated instead of the old exact last-4096 ring).
    pub fn stats_json(&self) -> Json {
        let c = self.cache.stats();
        let lat = &self.metrics.latency_all;
        obj(vec![
            ("generation", num(self.generation() as f64)),
            ("swaps", num(self.metrics.swaps.value() as f64)),
            ("requests", num(self.metrics.requests.value() as f64)),
            ("tiles_served", num(self.metrics.tiles_served.value() as f64)),
            ("queries_served", num(self.metrics.queries_served.value() as f64)),
            ("errors", num(self.metrics.errors.value() as f64)),
            (
                "cache",
                obj(vec![
                    ("hits", num(c.hits as f64)),
                    ("misses", num(c.misses as f64)),
                    ("evictions", num(c.evictions as f64)),
                    ("entries", num(c.entries as f64)),
                    ("capacity", num(c.capacity as f64)),
                ]),
            ),
            (
                "latency",
                obj(vec![
                    ("count", num(lat.count() as f64)),
                    ("p50_ms", num(lat.quantile(0.5) * 1e3)),
                    ("p99_ms", num(lat.quantile(0.99) * 1e3)),
                    ("max_ms", num(lat.max() * 1e3)),
                ]),
            ),
        ])
    }

    /// `/metrics` body: the process-wide registry merged with this
    /// server's instance registry.  Point-in-time gauges (generation,
    /// cache occupancy) are mirrored just before the snapshot.
    pub fn prometheus(&self) -> String {
        self.metrics.generation.set(self.generation() as f64);
        self.metrics.cache_entries.set(self.cache.stats().entries as f64);
        let snap = crate::obs::metrics::snapshot().merge(self.metrics.registry.snapshot());
        prometheus_text(&snap)
    }
}

/// A running map server; `stop()` for a clean shutdown, `wait()` to block
/// until one happens (the CLI's serve loop).
pub struct ServerHandle {
    pub addr: SocketAddr,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// run-store poller for `--watch` mode (absent for a static artifact)
    watcher: Option<JoinHandle<()>>,
}

impl ServerHandle {
    pub fn state(&self) -> &ServerState {
        &self.state
    }

    /// Signal shutdown, wake the acceptor, join every thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept() with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
    }

    /// Block until the accept thread exits (i.e. forever, absent a stop
    /// signal from another thread or a fatal listener error).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Build the read path for a static `artifact` and start serving
/// (generation 0, no watcher).
pub fn start(artifact: MapArtifact, cfg: &ServeConfig) -> Result<ServerHandle> {
    let renderer = TileRenderer::new(artifact, cfg.tile);
    start_with(renderer, 0, cfg, None)
}

/// Serve a training run's **newest checkpoint artifact**, hot-swapping to
/// newer checkpoints as the run writes them (DESIGN.md §11): a watcher
/// thread polls the run store's manifest every `poll`; when a newer
/// checkpoint with a materialized artifact appears, it loads + indexes the
/// artifact off-lock and swaps it in.  The tile cache is keyed by
/// `(generation, tile)`, so viewers always see tiles of exactly one epoch.
///
/// Errors if `run_dir` is not a run store or holds no checkpoint artifact
/// yet — the CLI waits for the first checkpoint before calling this.
pub fn start_watching(run_dir: &Path, cfg: &ServeConfig, poll: Duration) -> Result<ServerHandle> {
    let store = RunStore::open(run_dir)?;
    let epoch = newest_artifact_epoch(&store)
        .context("run store has no checkpoint with a map artifact yet")?;
    let art = MapArtifact::load(&store.artifact_dir(epoch))?;
    let renderer = TileRenderer::new(art, cfg.tile);
    start_with(renderer, epoch as u64, cfg, Some((run_dir.to_path_buf(), poll)))
}

/// Newest checkpoint epoch whose `artifact/` directory exists.
fn newest_artifact_epoch(store: &RunStore) -> Option<usize> {
    store
        .checkpoints()
        .iter()
        .rev()
        .copied()
        .find(|&e| store.artifact_dir(e).join("manifest.json").exists())
}

/// Shared startup path for [`start`] and [`start_watching`].
fn start_with(
    renderer: TileRenderer,
    generation: u64,
    cfg: &ServeConfig,
    watch: Option<(PathBuf, Duration)>,
) -> Result<ServerHandle> {
    let metrics = ServeMetrics::new();
    metrics.generation.set(generation as f64);
    // the cache counts through obs handles registered in this server's
    // instance registry, so `/metrics` and `/stats` read one source
    let cache = TileCache::with_counters(
        cfg.cache_entries,
        metrics.registry.counter("nomad_serve_cache_hits_total", "Tile-cache hits.", &[]),
        metrics.registry.counter("nomad_serve_cache_misses_total", "Tile-cache misses.", &[]),
        metrics.registry.counter(
            "nomad_serve_cache_evictions_total",
            "Tile-cache LRU evictions.",
            &[],
        ),
    );
    let state = Arc::new(ServerState {
        renderer: RwLock::new((generation, Arc::new(renderer))),
        cache,
        render_locks: (0..RENDER_STRIPES).map(|_| Mutex::new(())).collect(),
        metrics,
    });
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr().context("local_addr")?;
    let stop = Arc::new(AtomicBool::new(false));

    let (tx, rx) = sync_channel::<TcpStream>(cfg.backlog.max(1));
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(cfg.workers.max(1));
    for _ in 0..cfg.workers.max(1) {
        let rx = Arc::clone(&rx);
        let state = Arc::clone(&state);
        workers.push(std::thread::spawn(move || worker_loop(&rx, &state)));
    }

    let stop2 = Arc::clone(&stop);
    let accept = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(_) => continue,
            };
            match tx.try_send(stream) {
                Ok(()) => {}
                Err(TrySendError::Full(mut stream)) => {
                    // bounded queue: shed load instead of queueing unboundedly
                    let _ = respond(&mut stream, 503, "Service Unavailable", "text/plain", b"busy\n");
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        // dropping tx disconnects the workers' receiver
    });

    let watcher = match watch {
        Some((run_dir, poll)) => {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            let tile_cfg = cfg.tile;
            let handle = std::thread::Builder::new()
                .name("nomad-watch".to_string())
                .spawn(move || watch_loop(&run_dir, poll, &state, &stop, tile_cfg))
                .context("spawn watcher thread")?;
            Some(handle)
        }
        None => None,
    };

    Ok(ServerHandle { addr, state, stop, accept: Some(accept), workers, watcher })
}

/// Poll the run store for newer checkpoint artifacts and swap them in.
/// Load/build happens outside the renderer lock; a partially pruned or
/// unreadable checkpoint is skipped and retried on the next tick (the
/// store publishes checkpoints atomically, so this is defensive only).
fn watch_loop(
    run_dir: &Path,
    poll: Duration,
    state: &ServerState,
    stop: &AtomicBool,
    tile_cfg: TileConfig,
) {
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(poll);
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let store = match RunStore::open(run_dir) {
            Ok(s) => s,
            Err(_) => continue, // manifest mid-rewrite or store gone
        };
        let newest = match newest_artifact_epoch(&store) {
            Some(e) => e,
            None => continue,
        };
        if (newest as u64) <= state.generation() {
            continue;
        }
        match MapArtifact::load(&store.artifact_dir(newest)) {
            Ok(art) => state.swap(newest as u64, TileRenderer::new(art, tile_cfg)),
            Err(_) => continue,
        }
    }
}

fn worker_loop(rx: &Arc<Mutex<Receiver<TcpStream>>>, state: &Arc<ServerState>) {
    loop {
        let stream = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match stream {
            Ok(s) => handle_conn(s, state),
            Err(_) => break, // acceptor gone
        }
    }
}

fn handle_conn(mut stream: TcpStream, state: &ServerState) {
    // bound both directions so a slow (or stalled) client can never wedge a
    // worker: reads are additionally capped by read_request's deadline, and
    // the write timeout unblocks write_all when the peer stops draining
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream) {
        Some(r) => r,
        None => return, // unreadable/empty request: nothing to answer
    };
    let t0 = Stopwatch::start();
    state.metrics.requests.inc();

    let (method, target) = match parse_request_line(&req) {
        Some(mt) => mt,
        None => {
            state.metrics.errors.inc();
            let _ = respond(&mut stream, 400, "Bad Request", "text/plain", b"bad request\n");
            state.metrics.record_latency(Route::Other, t0.secs());
            return;
        }
    };
    if method != "GET" {
        state.metrics.errors.inc();
        let _ = respond(
            &mut stream,
            405,
            "Method Not Allowed",
            "text/plain",
            b"GET only\n",
        );
        state.metrics.record_latency(Route::Other, t0.secs());
        return;
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };

    let (route, ok) = if let Some(rest) = path.strip_prefix("/tiles/") {
        (Route::Tiles, serve_tile(&mut stream, state, rest))
    } else if path == "/query" {
        (Route::Query, serve_query(&mut stream, state, query))
    } else if path == "/stats" {
        let body = state.stats_json().pretty().into_bytes();
        (Route::Stats, respond(&mut stream, 200, "OK", "application/json", &body).is_ok())
    } else if path == "/metrics" {
        let body = state.prometheus().into_bytes();
        let ctype = "text/plain; version=0.0.4; charset=utf-8";
        (Route::Metrics, respond(&mut stream, 200, "OK", ctype, &body).is_ok())
    } else if path == "/" {
        let body = b"nomad map server\n\
                     GET /tiles/{z}/{x}/{y}.png\n\
                     GET /query?x=&y=&k=\n\
                     GET /stats\n\
                     GET /metrics\n";
        (Route::Other, respond(&mut stream, 200, "OK", "text/plain", body).is_ok())
    } else {
        state.metrics.errors.inc();
        (Route::Other, respond(&mut stream, 404, "Not Found", "text/plain", b"not found\n").is_ok())
    };
    let _ = ok;

    state.metrics.record_latency(route, t0.secs());
}

/// `GET /tiles/{z}/{x}/{y}.png`
fn serve_tile(stream: &mut TcpStream, state: &ServerState, rest: &str) -> bool {
    let coords = parse_tile_path(rest);
    let (z, x, y) = match coords {
        Some(c) => c,
        None => {
            state.metrics.errors.inc();
            return respond(stream, 404, "Not Found", "text/plain", b"bad tile path\n").is_ok();
        }
    };
    // pin one (generation, renderer) pair for the whole request: a
    // concurrent hot-swap must never mix generations between the pyramid
    // check, the cache key, and the render
    let (generation, renderer) = state.current();
    // validate against the pyramid before touching the cache: tile_key's
    // packing is only injective for in-pyramid coordinates
    if renderer.tile_view(z, x, y).is_none() {
        state.metrics.errors.inc();
        return respond(stream, 404, "Not Found", "text/plain", b"tile out of range\n").is_ok();
    }
    let key: CacheKey = (generation, tile_key(z, x, y));
    let bytes = match state.cache.get(key) {
        Some(b) => b,
        None => {
            // single-flight: a thundering herd on one cold tile renders it
            // once and shares the Arc, instead of N redundant render+encode
            // passes (tiles are deterministic, so this is purely a cost
            // optimization — correctness never depended on it).  Skipped
            // when the cache is disabled: there is nothing to share through.
            let enabled = state.cache.enabled();
            let _flight = enabled.then(|| {
                let mixed = key
                    .1
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(key.0.wrapping_mul(0xA24B_AED4_963E_E407));
                let stripe = (mixed >> 58) as usize % RENDER_STRIPES;
                state.render_locks[stripe].lock().unwrap()
            });
            let refilled = if enabled { state.cache.get(key) } else { None };
            match refilled {
                Some(b) => b, // filled by a concurrent request while we waited
                None => match renderer.render_png(z, x, y) {
                    None => {
                        state.metrics.errors.inc();
                        return respond(
                            stream,
                            404,
                            "Not Found",
                            "text/plain",
                            b"tile out of range\n",
                        )
                        .is_ok();
                    }
                    Some(Err(e)) => {
                        state.metrics.errors.inc();
                        let msg = format!("encode error: {e}\n");
                        return respond(
                            stream,
                            500,
                            "Internal Server Error",
                            "text/plain",
                            msg.as_bytes(),
                        )
                        .is_ok();
                    }
                    Some(Ok(b)) => {
                        let b = Arc::new(b);
                        state.cache.put(key, Arc::clone(&b));
                        b
                    }
                },
            }
        }
    };
    state.metrics.tiles_served.inc();
    respond(stream, 200, "OK", "image/png", &bytes).is_ok()
}

/// `GET /query?x=&y=&k=`
fn serve_query(stream: &mut TcpStream, state: &ServerState, query: &str) -> bool {
    let qx = query_param(query, "x").and_then(|v| v.parse::<f32>().ok());
    let qy = query_param(query, "y").and_then(|v| v.parse::<f32>().ok());
    let k = match query_param(query, "k") {
        None => Some(10usize),
        Some(v) => v.parse::<usize>().ok(),
    };
    let (qx, qy, k) = match (qx, qy, k) {
        // non-finite coordinates are rejected too: Rust's float parser
        // accepts "NaN"/"inf", but echoing them through json::num would
        // emit a bare `NaN` token — a 200 with an unparsable body
        (Some(a), Some(b), Some(c)) if a.is_finite() && b.is_finite() => (a, b, c.min(1000)),
        _ => {
            state.metrics.errors.inc();
            let body = br#"{"error": "need finite numeric x=, y= and optional k="}"#;
            return respond(stream, 400, "Bad Request", "application/json", body).is_ok();
        }
    };
    let (_generation, renderer) = state.current();
    let art = renderer.artifact();
    let hits = renderer.quadtree().knn(qx, qy, k);
    let results: Vec<Json> = hits
        .iter()
        .map(|&(id, d2)| {
            let row = art.positions.row(id as usize);
            let mut fields = vec![
                ("id", num(id as f64)),
                ("x", num(row[0] as f64)),
                ("y", num(row[1] as f64)),
                ("d2", num(d2 as f64)),
            ];
            if let Some(ls) = &art.labels {
                fields.push(("label", num(ls[id as usize] as f64)));
            }
            obj(fields)
        })
        .collect();
    let body = obj(vec![
        ("x", num(qx as f64)),
        ("y", num(qy as f64)),
        ("k", num(k as f64)),
        ("results", arr(results)),
    ])
    .to_string()
    .into_bytes();
    state.metrics.queries_served.inc();
    respond(stream, 200, "OK", "application/json", &body).is_ok()
}

/// Parse `{z}/{x}/{y}.png`.
fn parse_tile_path(rest: &str) -> Option<(u32, u32, u32)> {
    let mut parts = rest.split('/');
    let z = parts.next()?.parse::<u32>().ok()?;
    let x = parts.next()?.parse::<u32>().ok()?;
    let last = parts.next()?;
    if parts.next().is_some() {
        return None;
    }
    let y = last.strip_suffix(".png")?.parse::<u32>().ok()?;
    Some((z, x, y))
}

/// First value of `name` in an `a=1&b=2` query string (no %-decoding:
/// every parameter this server takes is numeric).
fn query_param<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=')?;
        (k == name).then_some(v)
    })
}

/// Read until the header terminator (or 16 KiB, or EOF/timeout, or an
/// overall deadline — a drip-feeding client that stays under the per-read
/// timeout must still release the worker).
fn read_request(stream: &mut TcpStream) -> Option<Vec<u8>> {
    let deadline = clock::deadline_in(Some(Duration::from_secs(10))).expect("some timeout");
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    loop {
        if clock::expired(deadline) {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 16 * 1024 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    if buf.is_empty() {
        None
    } else {
        Some(buf)
    }
}

/// `GET /path HTTP/1.1` -> `("GET", "/path")`.
fn parse_request_line(req: &[u8]) -> Option<(&str, &str)> {
    let line_end = req.windows(2).position(|w| w == b"\r\n")?;
    let line = std::str::from_utf8(&req[..line_end]).ok()?;
    let mut it = line.split_whitespace();
    let method = it.next()?;
    let target = it.next()?;
    Some((method, target))
}

fn respond(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    ctype: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// Minimal blocking HTTP GET over a fresh connection — the in-tree client
/// the integration tests and the `serve_load` bench share.  Returns
/// `(status, body)`.
pub fn http_get(addr: &str, path: &str) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    let _ = stream.set_nodelay(true);
    stream
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: nomad\r\nConnection: close\r\n\r\n").as_bytes())
        .context("write request")?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("read response")?;
    let split = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .context("no header terminator in response")?;
    let head = std::str::from_utf8(&raw[..split]).context("response head utf8")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .context("no status code")?
        .parse()
        .context("status code parse")?;
    Ok((status, raw[split + 4..].to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::serve::artifact::Provenance;
    use crate::serve::quadtree;
    use crate::util::rng::Rng;

    fn demo_artifact(n: usize) -> MapArtifact {
        let mut rng = Rng::new(17);
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            data.push(rng.normal() * 4.0);
            data.push(rng.normal() * 4.0);
        }
        MapArtifact::from_run(
            Matrix::from_vec(n, 2, data),
            Some((0..n as u32).map(|i| i % 6).collect()),
            Provenance { dataset: "http-test".into(), ..Default::default() },
        )
        .unwrap()
    }

    fn test_server(n: usize, cache_entries: usize) -> ServerHandle {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 4,
            backlog: 32,
            cache_entries,
            tile: TileConfig { tile_px: 32, ..Default::default() },
        };
        start(demo_artifact(n), &cfg).expect("server starts")
    }

    const PNG_MAGIC: &[u8] = &[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n'];

    #[test]
    fn serves_tiles_queries_and_stats() {
        let h = test_server(400, 256);
        let addr = h.addr.to_string();

        let (st, body) = http_get(&addr, "/tiles/0/0/0.png").unwrap();
        assert_eq!(st, 200);
        assert_eq!(&body[..8], PNG_MAGIC);

        let (st, body) = http_get(&addr, "/query?x=0&y=0&k=5").unwrap();
        assert_eq!(st, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let results = v.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 5);
        // d2 ascending, and the ids match the quadtree oracle exactly
        let art = demo_artifact(400);
        let want = quadtree::knn_naive(&art.positions, 0.0, 0.0, 5);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.get("id").as_usize().unwrap() as u32, want[i].0);
            assert!(r.get("label").as_f64().is_some());
        }

        let (st, body) = http_get(&addr, "/stats").unwrap();
        assert_eq!(st, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("tiles_served").as_i64(), Some(1));
        assert_eq!(v.get("queries_served").as_i64(), Some(1));

        let (st, _) = http_get(&addr, "/nope").unwrap();
        assert_eq!(st, 404);
        let (st, _) = http_get(&addr, "/tiles/abc/0/0.png").unwrap();
        assert_eq!(st, 404);
        let (st, _) = http_get(&addr, "/tiles/0/9/9.png").unwrap();
        assert_eq!(st, 404);
        let (st, _) = http_get(&addr, "/query?x=abc&y=0").unwrap();
        assert_eq!(st, 400);

        h.stop();
    }

    #[test]
    fn concurrent_clients_get_bitwise_identical_tiles() {
        let h = test_server(800, 64);
        let addr = h.addr.to_string();
        let reference = http_get(&addr, "/tiles/1/0/1.png").unwrap().1;
        assert_eq!(&reference[..8], PNG_MAGIC);

        std::thread::scope(|sc| {
            for _ in 0..6 {
                let addr = addr.clone();
                let reference = reference.clone();
                sc.spawn(move || {
                    for _ in 0..8 {
                        let (st, body) = http_get(&addr, "/tiles/1/0/1.png").unwrap();
                        assert_eq!(st, 200);
                        assert_eq!(body, reference, "tile bytes must be identical");
                        let (st, _) = http_get(&addr, "/query?x=1&y=-1&k=3").unwrap();
                        assert_eq!(st, 200);
                    }
                });
            }
        });

        // cache must have produced hits for the repeated tile
        let v = h.state().stats_json();
        assert!(v.get("cache").get("hits").as_i64().unwrap() > 0);
        assert!(v.get("tiles_served").as_i64().unwrap() >= 49);
        h.stop();
    }

    #[test]
    fn cache_disabled_still_serves_identical_tiles() {
        let h = test_server(300, 0);
        let addr = h.addr.to_string();
        let a = http_get(&addr, "/tiles/2/1/1.png").unwrap();
        let b = http_get(&addr, "/tiles/2/1/1.png").unwrap();
        assert_eq!(a.0, 200);
        assert_eq!(a.1, b.1);
        let v = h.state().stats_json();
        assert_eq!(v.get("cache").get("hits").as_i64(), Some(0));
        h.stop();
    }

    #[test]
    fn watch_hot_swaps_to_newest_checkpoint() {
        use crate::checkpoint::{CheckpointState, RunStore, SaveOpts};
        use crate::distributed::MeanEntry;
        use crate::util::json::Json as J;

        let dir = std::env::temp_dir().join("nomad_watch_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut store = RunStore::create(&dir, 7, J::Null).unwrap();
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            backlog: 16,
            cache_entries: 64,
            tile: TileConfig { tile_px: 32, ..Default::default() },
        };

        // no checkpoint with an artifact yet: watching must refuse cleanly
        assert!(start_watching(&dir, &cfg, Duration::from_millis(25)).is_err());

        // two checkpoint states with visibly different point layouts (a
        // uniform scale would refit to the same view and identical tiles)
        let state_at = |epochs_done: usize, rows_wide: bool| {
            let n = 60usize;
            let mut pos = Vec::with_capacity(n * 2);
            for i in 0..n {
                if rows_wide {
                    pos.push(i as f32);
                    pos.push((i % 7) as f32);
                } else {
                    pos.push((i % 5) as f32);
                    pos.push(i as f32);
                }
            }
            CheckpointState {
                epochs_done,
                positions: Matrix::from_vec(n, 2, pos),
                means: vec![MeanEntry { cluster_id: 0, mean: [0.0, 0.0], weight: 1.0 }],
                loss_history: vec![0.5; epochs_done],
                fingerprint: 7,
            }
        };
        let opts =
            SaveOpts { artifact: true, dataset: "watch-test", seed: 1, ..Default::default() };
        store.save(&state_at(2, true), &opts).unwrap();

        let h = start_watching(&dir, &cfg, Duration::from_millis(20)).unwrap();
        let addr = h.addr.to_string();
        assert_eq!(h.state().generation(), 2);
        let (st, tile_a) = http_get(&addr, "/tiles/0/0/0.png").unwrap();
        assert_eq!(st, 200);
        assert_eq!(&tile_a[..8], PNG_MAGIC);

        // the run writes a newer checkpoint; the watcher must swap to it
        store.save(&state_at(4, false), &opts).unwrap();
        for _ in 0..500 {
            if h.state().generation() >= 4 {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(h.state().generation(), 4, "watcher must hot-swap without restart");
        let (st, tile_b) = http_get(&addr, "/tiles/0/0/0.png").unwrap();
        assert_eq!(st, 200);
        assert_ne!(tile_a, tile_b, "tile must be rendered from the new artifact");
        let v = h.state().stats_json();
        assert_eq!(v.get("generation").as_i64(), Some(4));
        assert!(v.get("swaps").as_i64().unwrap() >= 1);
        h.stop();
    }

    #[test]
    fn stats_field_names_are_backward_compatible() {
        // the /stats JSON shape is a consumer contract: moving the
        // counters onto obs must not rename or drop a field
        let h = test_server(200, 64);
        let addr = h.addr.to_string();
        let _ = http_get(&addr, "/tiles/0/0/0.png").unwrap();
        let (st, body) = http_get(&addr, "/stats").unwrap();
        assert_eq!(st, 200);
        let v = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        for key in ["generation", "swaps", "requests", "tiles_served", "queries_served", "errors"]
        {
            assert!(v.get(key).as_f64().is_some(), "missing top-level field {key}");
        }
        for key in ["hits", "misses", "evictions", "entries", "capacity"] {
            assert!(v.get("cache").get(key).as_f64().is_some(), "missing cache field {key}");
        }
        for key in ["count", "p50_ms", "p99_ms", "max_ms"] {
            assert!(v.get("latency").get(key).as_f64().is_some(), "missing latency field {key}");
        }
        assert!(v.get("requests").as_i64().unwrap() >= 2);
        h.stop();
    }

    #[test]
    fn metrics_route_serves_prometheus_exposition() {
        let h = test_server(200, 64);
        let addr = h.addr.to_string();
        let _ = http_get(&addr, "/tiles/0/0/0.png").unwrap();
        let (st, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(st, 200);
        let text = std::str::from_utf8(&body).unwrap();
        assert!(text.contains("# TYPE nomad_serve_requests_total counter"), "{text}");
        assert!(text.contains("# TYPE nomad_serve_request_seconds histogram"), "{text}");
        assert!(
            text.contains("nomad_serve_request_seconds_bucket{route=\"/tiles\",le=\"+Inf\"} 1"),
            "{text}"
        );
        // well-formedness: every non-comment line is `name{labels} value`
        // with a parseable value
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, value) = line.rsplit_once(' ').expect("series line has a value");
            assert!(
                value == "+Inf" || value.parse::<f64>().is_ok(),
                "unparsable sample value in {line:?}"
            );
        }
        h.stop();
    }

    #[test]
    fn parse_helpers() {
        assert_eq!(parse_tile_path("3/1/2.png"), Some((3, 1, 2)));
        assert_eq!(parse_tile_path("3/1/2"), None);
        assert_eq!(parse_tile_path("3/1/2.png/x"), None);
        assert_eq!(parse_tile_path("a/1/2.png"), None);
        assert_eq!(query_param("x=1&y=2", "y"), Some("2"));
        assert_eq!(query_param("x=1&y=2", "z"), None);
        assert_eq!(parse_request_line(b"GET /a HTTP/1.1\r\n\r\n"), Some(("GET", "/a")));
        assert_eq!(parse_request_line(b"garbage"), None);
    }
}
