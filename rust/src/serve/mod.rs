//! The map serving subsystem — the production read path from a finished
//! run to concurrent viewers (DESIGN.md §10).
//!
//! The paper's headline artifact is the *map*, and maps are consumed
//! interactively: viewport pans, zooms, and point lookups from many
//! simultaneous clients.  This module turns a [`artifact::MapArtifact`]
//! (positions + labels + bounds + provenance, persisted by `nomad embed`)
//! into a served surface, entirely pure-std:
//!
//! * [`quadtree`] — static packed quadtree (Morton leaf layout) for
//!   viewport range queries and embedding-space k-nearest lookups;
//! * [`tiles`] — slippy-style `z/x/y` LOD tile pyramid with
//!   deterministic, seed-addressed thinning (tiles are bitwise
//!   reproducible);
//! * [`cache`] — sharded LRU over encoded tiles, keyed by
//!   `(artifact generation, tile)`, with hit/miss/eviction counters;
//! * [`http`] — threaded HTTP/1.1 server (fixed worker pool, bounded
//!   accept queue) answering tile, query, and stats requests.  In
//!   `--watch` mode ([`http::start_watching`]) a poller hot-swaps the
//!   served artifact to a training run's newest checkpoint (DESIGN.md
//!   §11), turning the server into a live training monitor.
//!
//! `benches/serve_load.rs` drives a zoom/pan mix over loopback and emits
//! p50/p99 latency and tiles/sec to `BENCH_serve_load.json`.

pub mod artifact;
pub mod cache;
pub mod http;
pub mod quadtree;
pub mod tiles;

pub use artifact::{MapArtifact, Provenance};
pub use cache::TileCache;
pub use http::{ServeConfig, ServerHandle};
pub use quadtree::Quadtree;
pub use tiles::{TileConfig, TileRenderer};
