//! The only sanctioned wall-clock access for determinism-critical modules.
//!
//! The invariant linter (DESIGN.md §14) bans `Instant::now` / `SystemTime`
//! inside `embed/`, `linalg/`, `ann/`, `coordinator/`, `checkpoint/` and the
//! wire/shard codecs: a raw clock read next to numerics is how wall time
//! leaks into results.  This module is the funnel instead — it offers only
//! *telemetry* (elapsed seconds for reports) and *deadlines* (timeout
//! instants for I/O waits), shapes that cannot feed gradient math.  Clock
//! values read here must never influence floats that end up in positions,
//! means, or losses.

use std::time::{Duration, Instant};

/// An elapsed-time probe for telemetry fields (`index_secs`,
/// `measured_secs_total`, snapshot `wall_secs`).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(Instant::now())
    }

    /// Seconds since `start()`.
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Resolve an optional timeout into an absolute receive deadline
/// (`None` waits forever).
pub fn deadline_in(timeout: Option<Duration>) -> Option<Instant> {
    timeout.map(|dl| Instant::now() + dl)
}

/// Time remaining until an absolute deadline (zero once passed) — the
/// sanctioned way to turn a deadline back into a socket timeout.
pub fn remaining_until(by: Instant) -> Duration {
    by.saturating_duration_since(Instant::now())
}

/// Has `by` passed?  The deadline-polling counterpart of [`deadline_in`].
pub fn expired(by: Instant) -> bool {
    Instant::now() >= by
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_is_monotone() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(a >= 0.0 && b >= a);
    }

    #[test]
    fn deadline_none_passes_through() {
        assert!(deadline_in(None).is_none());
        let by = deadline_in(Some(Duration::from_secs(5))).expect("deadline");
        assert!(by > Instant::now());
    }
}
