//! Foundational substrates built from scratch for the offline environment:
//! PRNG, JSON, npy interchange, thread-pool parallelism, summary statistics.
pub mod json;
pub mod npy;
pub mod parallel;
pub mod rng;
pub mod stats;
