//! Foundational substrates built from scratch for the offline environment:
//! PRNG, JSON, npy interchange, data parallelism, error handling, summary
//! statistics.
pub mod clock;
pub mod error;
pub mod json;
pub mod mmap;
pub mod npy;
pub mod parallel;
pub mod rng;
pub mod stats;
