//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so NOMAD ships its own generator:
//! a PCG64-DXSM-style 128-bit state PCG (the same family rand uses for
//! `StdRng` seeding paths) plus SplitMix64 for stream derivation.  Every
//! stochastic component of the system (data generators, LSH, K-Means
//! seeding, negative sampling, SGD shuffling) takes an explicit `Rng` so
//! whole experiments replay bit-identically from a single `u64` seed.

/// SplitMix64 — used to expand user seeds into well-mixed state words.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A small, fast, statistically solid PRNG (PCG-DXSM flavored, 128-bit state).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
}

impl Rng {
    /// Create a generator from a seed; different seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let a = splitmix64(&mut sm);
        let b = splitmix64(&mut sm);
        let c = splitmix64(&mut sm);
        let d = splitmix64(&mut sm);
        let mut rng = Rng {
            state: ((a as u128) << 64) | b as u128,
            inc: (((c as u128) << 64) | d as u128) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive the `i`-th independent child stream (device shards, workers).
    pub fn fork(&self, i: u64) -> Self {
        let mut sm = self.inc as u64 ^ i.wrapping_mul(0x9E3779B97F4A7C15);
        let s = splitmix64(&mut sm) ^ (self.state as u64);
        Rng::new(s ^ (i << 1 | 1))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // PCG-DXSM output function over a 128-bit LCG.
        const MUL: u128 = 0x2360ED051FC65DA44385DF649FCCF645;
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xDA942042E4DD58B5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; the force math dominates runtime, not sampling).
    #[inline]
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f64().max(1e-300)) as f64;
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Normal with given mean / stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates for
    /// small k, rejection for tiny k relative to n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        let mut t = self.f64() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(7);
        let mut c0 = root.fork(0);
        let mut c1 = root.fork(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.below(7)] += 1;
        }
        let expect = n / 7;
        for &c in &counts {
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(17);
        for (n, k) in [(100, 5), (10, 10), (1000, 999), (50, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&v| v < n));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(19);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}
