//! Read-only memory-mapped files, zero dependencies.
//!
//! The shard reader ([`crate::data::shard`]) serves per-cluster records out
//! of one large data file; a worker process must be able to page in only
//! the clusters it was assigned instead of reading the whole file.  On unix
//! the std runtime already links libc, so `mmap(2)` is declared directly
//! via `extern "C"` — no crate needed.  On non-unix targets [`Mmap::open`]
//! degrades to reading the file into memory (same API, weaker paging).
//!
//! Under Miri the fallback path is used on unix too: Miri cannot model
//! foreign `mmap` memory, and an owned `Vec` gives the soundness gate a
//! fully tracked allocation while exercising the same `ptr`/`len` slice
//! reconstruction that the real mapping uses.

use crate::util::error::{Context, Result};
use std::path::Path;

/// A read-only mapping (or, off unix / under Miri, an owned copy) of a
/// file's bytes.
pub struct Mmap {
    ptr: *mut u8,
    len: usize,
    /// fallback storage; on the real unix mmap path stays `None`
    fallback: Option<Vec<u8>>,
}

// SAFETY: `Mmap` is `Send`/`Sync` despite holding a raw pointer because the
// memory behind `ptr` is immutable shared state whose validity does not
// depend on which thread touches it: either a PROT_READ, MAP_PRIVATE
// mapping that stays mapped until `Drop` runs (with `&mut self`, i.e.
// exclusive access), or bytes owned by the `fallback` Vec, which is never
// mutated after `open` returns.  No `&self` method writes through `ptr`,
// so concurrent `bytes()` calls are concurrent reads of immutable memory.
unsafe impl Send for Mmap {}
// SAFETY: as above — shared references only ever read the mapping.
unsafe impl Sync for Mmap {}

#[cfg(all(unix, not(miri)))]
mod sys {
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

impl Mmap {
    /// Map `path` read-only.  Empty files map to an empty slice.
    #[cfg(all(unix, not(miri)))]
    pub fn open(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let len = f.metadata()?.len();
        let len = usize::try_from(len).context("file too large to map")?;
        if len == 0 {
            return Ok(Mmap { ptr: std::ptr::null_mut(), len: 0, fallback: None });
        }
        // SAFETY: plain FFI call with a valid open fd, a length measured
        // from that fd, and no requested address; the kernel either maps
        // `len` readable bytes or returns MAP_FAILED, checked below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1
        if ptr.is_null() || ptr as isize == -1 {
            crate::bail!("mmap of {} ({len} bytes) failed", path.display());
        }
        Ok(Mmap { ptr, len, fallback: None })
    }

    /// Non-unix / Miri fallback: same API, backed by an in-memory copy.
    #[cfg(any(not(unix), miri))]
    pub fn open(path: &Path) -> Result<Mmap> {
        let mut data = std::fs::read(path)
            .with_context(|| format!("read {}", path.display()))?;
        let ptr = data.as_mut_ptr();
        let len = data.len();
        Ok(Mmap { ptr, len, fallback: Some(data) })
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: `ptr` covers `len` readable, initialized bytes for the
        // life of `self` — the mapping is unmapped only in Drop, and the
        // fallback Vec is owned by `self` and never reallocated after
        // `open`.  The returned slice borrows `self`, so it cannot outlive
        // either backing store.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, not(miri)))]
        if self.fallback.is_none() && self.len > 0 {
            // SAFETY: on this path `ptr`/`len` are exactly the address and
            // length returned by the successful mmap in `open`, unmapped
            // exactly once (Drop runs once, with exclusive access).
            unsafe {
                sys::munmap(self.ptr, self.len);
            }
        }
        // fallback: the Vec frees itself
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("nomad_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn maps_file_contents() {
        let p = tmp("a.bin");
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&p, &data).unwrap();
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.bytes(), &data[..]);
    }

    #[test]
    fn empty_file_is_empty_slice() {
        let p = tmp("empty.bin");
        std::fs::write(&p, b"").unwrap();
        let m = Mmap::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Mmap::open(&tmp("definitely_missing.bin")).is_err());
    }

    #[test]
    fn mapping_outlives_reads_across_threads() {
        let p = tmp("threads.bin");
        std::fs::write(&p, vec![7u8; 4096]).unwrap();
        let m = std::sync::Arc::new(Mmap::open(&p).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = std::sync::Arc::clone(&m);
                std::thread::spawn(move || m.bytes().iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
    }
}
