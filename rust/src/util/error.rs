//! Minimal error handling for the pure-std offline build.
//!
//! The offline environment has no `anyhow`, so NOMAD ships the small subset
//! it actually uses: a string-backed [`Error`], a [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the
//! [`bail!`](crate::bail)/[`ensure!`](crate::ensure) macros.  Context is
//! folded into the message eagerly (`"outer: inner"`), which is exactly how
//! these errors are consumed — printed once at a CLI or test boundary.

use std::fmt;

/// A human-readable error message (with any context prefixes folded in).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::string::FromUtf8Error> for Error {
    fn from(e: std::string::FromUtf8Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<super::json::JsonError> for Error {
    fn from(e: super::json::JsonError) -> Error {
        Error::msg(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(e)
    }
}

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow::Context`-style message attachment for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built message.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`](crate::util::error::Error).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Early-return with a formatted error unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        let n: u32 = "nope".parse().context("parse count")?;
        Ok(n)
    }

    fn bails(flag: bool) -> Result<()> {
        crate::ensure!(flag, "flag was {flag}");
        crate::bail!("always fails with {}", 42)
    }

    #[test]
    fn context_folds_messages() {
        let e = fails().unwrap_err();
        assert!(e.to_string().starts_with("parse count: "), "{e}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn bail_and_ensure() {
        assert_eq!(bails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(bails(true).unwrap_err().to_string(), "always fails with 42");
    }

    #[test]
    fn io_error_converts() {
        fn open() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(open().is_err());
    }
}
