//! Minimal NumPy `.npy` reader/writer (v1.0), f32/f64 little-endian.
//!
//! Used for tensor interchange between the python compile path and the rust
//! runtime (e.g. exporting embeddings for external inspection, importing
//! real vector datasets) and for the checkpoint run store's state files
//! (DESIGN.md §11).  Only C-contiguous little-endian arrays are
//! supported — exactly what `numpy.save` emits by default.
//!
//! The reader is hardened against corrupt input: a claimed shape whose
//! element count (or byte size) overflows, or whose payload does not match
//! the file's remaining length **exactly**, is an `Err` — never a panic and
//! never a pathological allocation.  (Truncated files fail the length
//! check; bit-flips inside a structurally valid payload are the checkpoint
//! layer's job, which crc32-guards every state file.)

use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{Read, Seek, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// A dense f32 tensor with shape metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct NpyF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyF32 { shape, data }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        write_header(&mut f, "<f4", &self.shape)?;
        let bytes: Vec<u8> = self.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let (descr, shape) = read_header(&mut f)?;
        if descr != "<f4" {
            bail!("expected <f4 dtype, got {descr}");
        }
        let buf = read_payload(&mut f, &shape, 4)
            .with_context(|| format!("read {}", path.display()))?;
        let data = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(NpyF32 { shape, data })
    }
}

/// A dense f64 tensor with shape metadata (loss histories and other state
/// whose bitwise round-trip matters; `numpy.save` of a float64 array).
#[derive(Clone, Debug, PartialEq)]
pub struct NpyF64 {
    pub shape: Vec<usize>,
    pub data: Vec<f64>,
}

impl NpyF64 {
    pub fn new(shape: Vec<usize>, data: Vec<f64>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyF64 { shape, data }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        write_header(&mut f, "<f8", &self.shape)?;
        let bytes: Vec<u8> = self.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let (descr, shape) = read_header(&mut f)?;
        if descr != "<f8" {
            bail!("expected <f8 dtype, got {descr}");
        }
        let buf = read_payload(&mut f, &shape, 8)
            .with_context(|| format!("read {}", path.display()))?;
        let data = buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect();
        Ok(NpyF64 { shape, data })
    }
}

/// Element count of a claimed shape, refusing overflow (a corrupt header
/// can claim `(usize::MAX,)` — that must be an error, not a wrap or a
/// pathological allocation).
fn checked_count(shape: &[usize]) -> Result<usize> {
    let mut count: usize = 1;
    for &d in shape {
        count = count.checked_mul(d).context("npy shape element count overflows")?;
    }
    Ok(count)
}

/// Read the payload after the header, validating that the file's remaining
/// bytes match the claimed `shape` **exactly** before allocating.
fn read_payload(f: &mut std::fs::File, shape: &[usize], esize: usize) -> Result<Vec<u8>> {
    let count = checked_count(shape)?;
    let need = count.checked_mul(esize).context("npy payload byte size overflows")?;
    let pos = f.stream_position()?;
    let len = f.metadata()?.len();
    let avail = len.saturating_sub(pos);
    if avail != need as u64 {
        bail!("npy payload is {avail} bytes, expected {need} (truncated or trailing data)");
    }
    let mut buf = vec![0u8; need];
    f.read_exact(&mut buf)?;
    Ok(buf)
}

fn write_header(w: &mut impl Write, descr: &str, shape: &[usize]) -> Result<()> {
    let shape_s = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_s}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    w.write_all(MAGIC)?;
    w.write_all(&[1, 0])?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    Ok(())
}

fn read_header(r: &mut impl Read) -> Result<(String, Vec<usize>)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        bail!("not an npy file");
    }
    let mut len = [0u8; 2];
    r.read_exact(&mut len)?;
    let hlen = u16::from_le_bytes(len) as usize;
    let mut header = vec![0u8; hlen];
    r.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header utf8")?;

    let descr = extract(&header, "'descr':")
        .context("descr missing")?
        .trim()
        .trim_matches(|c| c == '\'' || c == '"')
        .to_string();
    if header.contains("'fortran_order': True") {
        bail!("fortran order unsupported");
    }
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .context("shape missing")?
        .split('(')
        .nth(1)
        .context("shape paren")?
        .split(')')
        .next()
        .context("shape close")?;
    let shape: Vec<usize> = shape_part
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("shape int"))
        .collect::<Result<_>>()?;
    Ok((descr, shape))
}

fn extract<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    header.split(key).nth(1)?.split(',').next()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("nomad_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_2d() {
        let t = NpyF32::new(vec![3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        let p = tmp_dir().join("a.npy");
        t.save(&p).unwrap();
        let t2 = NpyF32::load(&p).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn roundtrip_1d() {
        let t = NpyF32::new(vec![4], vec![-1.0, 0.0, 1.0, 2.0]);
        let p = tmp_dir().join("b.npy");
        t.save(&p).unwrap();
        assert_eq!(NpyF32::load(&p).unwrap(), t);
    }

    #[test]
    fn roundtrip_f64_bitwise() {
        // loss histories must round-trip with full f64 precision, including
        // values that would be lossy through f32
        let vals = vec![
            0.1f64,
            -1.0 / 3.0,
            f64::MIN_POSITIVE,
            1.0 + f64::EPSILON,
            -0.0,
            12345.678901234567,
        ];
        let t = NpyF64::new(vec![vals.len()], vals.clone());
        let p = tmp_dir().join("c.npy");
        t.save(&p).unwrap();
        let back = NpyF64::load(&p).unwrap();
        assert_eq!(back.shape, vec![vals.len()]);
        for (a, b) in back.data.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits(), "f64 must round-trip bitwise");
        }
    }

    #[test]
    fn rejects_non_npy() {
        let p = tmp_dir().join("d.npy");
        std::fs::write(&p, b"not an npy").unwrap();
        assert!(NpyF32::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let t = NpyF32::new(vec![8, 2], vec![1.0; 16]);
        let p = tmp_dir().join("trunc.npy");
        t.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // cut the payload short by one element
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let e = NpyF32::load(&p);
        assert!(e.is_err(), "truncated payload must be an error");
        // and mid-header truncation too
        std::fs::write(&p, &bytes[..6]).unwrap();
        assert!(NpyF32::load(&p).is_err());
    }

    #[test]
    fn rejects_trailing_garbage() {
        let t = NpyF32::new(vec![2], vec![1.0, 2.0]);
        let p = tmp_dir().join("trail.npy");
        t.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&p, &bytes).unwrap();
        assert!(NpyF32::load(&p).is_err());
    }

    #[test]
    fn rejects_absurd_claimed_shapes_without_allocating() {
        // hand-craft headers whose claimed shapes overflow the element
        // count or the byte size; the loader must Err before allocating
        for shape_s in [
            "(18446744073709551615,)",         // usize::MAX elements
            "(4611686018427387904,)",          // 2^62: count ok, bytes overflow
            "(4294967296, 4294967296)",        // product overflows
            "(1000000,)",                      // plausible but way past EOF
        ] {
            let header = format!(
                "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_s}, }}\n"
            );
            let mut v = Vec::new();
            v.extend_from_slice(MAGIC);
            v.extend_from_slice(&[1, 0]);
            v.extend_from_slice(&(header.len() as u16).to_le_bytes());
            v.extend_from_slice(header.as_bytes());
            v.extend_from_slice(&[0u8; 8]); // token payload, far too short
            let p = tmp_dir().join("absurd.npy");
            std::fs::write(&p, &v).unwrap();
            let r = NpyF32::load(&p);
            assert!(r.is_err(), "shape {shape_s} must be rejected");
        }
    }

    #[test]
    fn rejects_wrong_dtype_cross_loads() {
        let p = tmp_dir().join("dtype.npy");
        NpyF64::new(vec![2], vec![1.0, 2.0]).save(&p).unwrap();
        assert!(NpyF32::load(&p).is_err(), "f32 loader must reject <f8");
        NpyF32::new(vec![2], vec![1.0, 2.0]).save(&p).unwrap();
        assert!(NpyF64::load(&p).is_err(), "f64 loader must reject <f4");
    }
}
