//! Minimal NumPy `.npy` reader/writer (v1.0), f32/i32 little-endian.
//!
//! Used for tensor interchange between the python compile path and the rust
//! runtime (e.g. exporting embeddings for external inspection, importing
//! real vector datasets).  Only C-contiguous little-endian arrays are
//! supported — exactly what `numpy.save` emits by default.

use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// A dense f32 tensor with shape metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct NpyF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl NpyF32 {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        NpyF32 { shape, data }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        write_header(&mut f, "<f4", &self.shape)?;
        let bytes: Vec<u8> = self.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let (descr, shape) = read_header(&mut f)?;
        if descr != "<f4" {
            bail!("expected <f4 dtype, got {descr}");
        }
        let count: usize = shape.iter().product();
        let mut buf = vec![0u8; count * 4];
        f.read_exact(&mut buf)?;
        let data = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(NpyF32 { shape, data })
    }
}

fn write_header(w: &mut impl Write, descr: &str, shape: &[usize]) -> Result<()> {
    let shape_s = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_s}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = 10 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    w.write_all(MAGIC)?;
    w.write_all(&[1, 0])?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    Ok(())
}

fn read_header(r: &mut impl Read) -> Result<(String, Vec<usize>)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic[..6] != MAGIC {
        bail!("not an npy file");
    }
    let mut len = [0u8; 2];
    r.read_exact(&mut len)?;
    let hlen = u16::from_le_bytes(len) as usize;
    let mut header = vec![0u8; hlen];
    r.read_exact(&mut header)?;
    let header = String::from_utf8(header).context("npy header utf8")?;

    let descr = extract(&header, "'descr':")
        .context("descr missing")?
        .trim()
        .trim_matches(|c| c == '\'' || c == '"')
        .to_string();
    if header.contains("'fortran_order': True") {
        bail!("fortran order unsupported");
    }
    let shape_part = header
        .split("'shape':")
        .nth(1)
        .context("shape missing")?
        .split('(')
        .nth(1)
        .context("shape paren")?
        .split(')')
        .next()
        .context("shape close")?;
    let shape: Vec<usize> = shape_part
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().context("shape int"))
        .collect::<Result<_>>()?;
    Ok((descr, shape))
}

fn extract<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    header.split(key).nth(1)?.split(',').next()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_2d() {
        let t = NpyF32::new(vec![3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.5]);
        let dir = std::env::temp_dir().join("nomad_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("a.npy");
        t.save(&p).unwrap();
        let t2 = NpyF32::load(&p).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn roundtrip_1d() {
        let t = NpyF32::new(vec![4], vec![-1.0, 0.0, 1.0, 2.0]);
        let dir = std::env::temp_dir().join("nomad_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("b.npy");
        t.save(&p).unwrap();
        assert_eq!(NpyF32::load(&p).unwrap(), t);
    }

    #[test]
    fn rejects_non_npy() {
        let dir = std::env::temp_dir().join("nomad_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.npy");
        std::fs::write(&p, b"not an npy").unwrap();
        assert!(NpyF32::load(&p).is_err());
    }
}
