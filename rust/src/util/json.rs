//! A small, strict JSON parser and writer.
//!
//! The offline build has no `serde`, so NOMAD ships its own: enough JSON to
//! read the AOT `artifacts/manifest.json`, run-config files, and to write
//! structured benchmark/experiment results.  Numbers are kept as `f64`
//! (plus a lossless `as_i64` view), strings support the standard escapes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.2e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Index into an array; Null on any miss.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.2e18 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building result JSON.
pub fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: only BMP needed for our files.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"o":{"k":3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo é"));
    }

    #[test]
    fn integers_preserved() {
        let v = Json::parse("[2048, -7, 0]").unwrap();
        assert_eq!(v.at(0).as_usize(), Some(2048));
        assert_eq!(v.at(1).as_i64(), Some(-7));
        assert_eq!(v.at(2).as_usize(), Some(0));
    }

    #[test]
    fn builders() {
        let v = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a")]))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":["a"]}"#);
    }
}
