//! Summary statistics for the benchmark harness (no `criterion` offline).

/// Running summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: q(0.50),
            p90: q(0.90),
            p99: q(0.99),
        }
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n <= 1 {
            0.0
        } else {
            self.std / (self.n as f64).sqrt()
        }
    }
}

/// Pearson correlation (used by metric sanity tests).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let xa = a[i] - ma;
        let xb = b[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    num / (da.sqrt() * db.sqrt()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn summary_nan_does_not_panic() {
        // regression: the old partial_cmp().unwrap() comparator panicked on
        // NaN samples; total_cmp sorts NaN after every finite value.
        let s = Summary::of(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan()); // NaN sorts last under total order
        let t = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(t.n, 2);
        assert!(t.max.is_nan());
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
