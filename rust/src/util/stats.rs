//! Summary statistics for the benchmark harness (no `criterion` offline).

/// Running summary of a sample set.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        // nearest-rank percentile: the smallest sample with at least p·n
        // samples ≤ it. The old `round(p·(n-1))` interpolation index
        // under-reported the tail — at n = 67 it mapped p99 to sorted[65]
        // instead of sorted[66], dropping the worst latency sample from
        // the bench tables.
        let q = |p: f64| -> f64 {
            let idx = ((p * n as f64).ceil() as usize).max(1) - 1;
            sorted[idx.min(n - 1)]
        };
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: q(0.50),
            p90: q(0.90),
            p99: q(0.99),
        }
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n <= 1 {
            0.0
        } else {
            self.std / (self.n as f64).sqrt()
        }
    }
}

/// Pearson correlation (used by metric sanity tests).
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for i in 0..a.len() {
        let xa = a[i] - ma;
        let xb = b[i] - mb;
        num += xa * xb;
        da += xa * xa;
        db += xb * xb;
    }
    num / (da.sqrt() * db.sqrt()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn summary_nan_does_not_panic() {
        // regression: the old partial_cmp().unwrap() comparator panicked on
        // NaN samples; total_cmp sorts NaN after every finite value.
        let s = Summary::of(&[2.0, f64::NAN, 1.0, 3.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan()); // NaN sorts last under total order
        let t = Summary::of(&[f64::NAN, f64::NAN]);
        assert_eq!(t.n, 2);
        assert!(t.max.is_nan());
    }

    /// Nearest-rank regression sweep: for every n in 1..=100 and each
    /// reported percentile, the result must equal the brute-force
    /// nearest-rank oracle ceil(p·n) on a distinct-valued sample. The old
    /// round(p·(n-1)) index failed this at, e.g., n = 67 / p = 0.99
    /// (round(65.34) = 65 instead of rank ceil(66.33) = 66 -> index 65
    /// vs 66 — it never reported the worst sample).
    #[test]
    fn percentiles_match_nearest_rank_oracle() {
        for n in 1..=100usize {
            // distinct, shuffled-ish values so a wrong index is visible
            // (37 is coprime to the prime 101 > n, so no collisions)
            let samples: Vec<f64> = (0..n).map(|i| ((i * 37) % 101) as f64).collect();
            let s = Summary::of(&samples);
            let mut sorted = samples.clone();
            sorted.sort_by(|a, b| a.total_cmp(b));
            let oracle = |p: f64| {
                let rank = (p * n as f64).ceil() as usize; // 1-based
                sorted[rank.max(1) - 1]
            };
            assert_eq!(s.p50, oracle(0.50), "n={n} p50");
            assert_eq!(s.p90, oracle(0.90), "n={n} p90");
            assert_eq!(s.p99, oracle(0.99), "n={n} p99");
        }
        // the motivating case, spelled out: with 67 samples the p99 must
        // be the maximum (ceil(0.99 * 67) = 67, the last rank); the old
        // index reported sorted[65] and the worst sample never surfaced.
        let s = Summary::of(&(0..67).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(s.p99, 66.0);
        assert_eq!(s.p99, s.max);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 4.0, 6.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);
        let c = [3.0, 2.0, 1.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-12);
    }
}
