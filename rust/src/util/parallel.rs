//! From-scratch data parallelism (no `rayon` offline).
//!
//! Four primitives cover every hot loop in NOMAD, all using **dynamic
//! chunking** over an atomic cursor — our workloads are ragged (clusters
//! and blocks vary in size), so workers grab the next chunk as they finish
//! rather than receiving a fixed pre-split:
//!  * [`par_for_chunks`] — run `f(start, end)` over chunks of an index range;
//!  * [`par_map`] — map a function over indices, collecting results in order;
//!  * [`par_map_mut`] — like `par_map`, but each index also gets exclusive
//!    `&mut` access to its slice element (the per-block epoch loop);
//!  * [`par_rows_mut`] — mutate disjoint row chunks of a flat matrix.
//!
//! All use `std::thread::scope`, so borrows of the caller's data work
//! without `Arc`.  Thread count defaults to the machine's parallelism and
//! is overridable via the `NOMAD_THREADS` env var or the CLI's `--threads`
//! flag (useful for the scaling benchmarks where the device simulator owns
//! the cores).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Debug-build shadow checker for the dynamic-chunking dispatch.
///
/// Every unsafe block below is sound only because the atomic cursor hands
/// each work unit to exactly one worker.  That argument lives in SAFETY
/// comments; this struct re-checks it at runtime when debug assertions are
/// on (tests, Miri): each unit must be claimed exactly once, and every
/// unit must be claimed by the time the primitive returns.  Release builds
/// compile it to nothing.
struct ShadowClaims {
    #[cfg(debug_assertions)]
    claimed: Vec<std::sync::atomic::AtomicU8>,
}

impl ShadowClaims {
    fn new(n: usize) -> ShadowClaims {
        #[cfg(not(debug_assertions))]
        let _ = n;
        ShadowClaims {
            #[cfg(debug_assertions)]
            claimed: (0..n).map(|_| std::sync::atomic::AtomicU8::new(0)).collect(),
        }
    }

    /// Record that work unit `i` was handed to a worker.
    fn claim(&self, i: usize) {
        #[cfg(not(debug_assertions))]
        let _ = i;
        #[cfg(debug_assertions)]
        {
            let prev = self.claimed[i].fetch_add(1, Ordering::Relaxed);
            assert_eq!(prev, 0, "parallel dispatch claimed unit {i} twice");
        }
    }

    fn claim_range(&self, a: usize, b: usize) {
        for i in a..b {
            self.claim(i);
        }
    }

    /// Assert every unit was dispatched (called after the scope joins).
    fn finish(&self) {
        #[cfg(debug_assertions)]
        for (i, c) in self.claimed.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "parallel dispatch never ran unit {i}"
            );
        }
    }
}

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("NOMAD_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(start, end)` over disjoint chunks of `0..n` on `threads` workers.
/// Work is distributed dynamically in blocks of `chunk` to balance ragged
/// workloads (e.g. variable-size clusters).
pub fn par_for_chunks<F>(n: usize, chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    let shadow = ShadowClaims::new(n);
    if threads <= 1 || n <= chunk {
        shadow.claim_range(0, n);
        f(0, n);
        shadow.finish();
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                shadow.claim_range(start, end);
                f(start, end);
            });
        }
    });
    shadow.finish();
}

/// Parallel map over `0..n`, returning results in index order.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = out.as_mut_ptr() as usize;
    let shadow = ShadowClaims::new(n);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                shadow.claim(i);
                let v = f(i);
                // SAFETY: each index i is claimed exactly once via the atomic
                // cursor, so no two threads write the same slot; the vector
                // outlives the scope.
                unsafe {
                    let p = (slots as *mut Option<T>).add(i);
                    std::ptr::write(p, Some(v));
                }
            });
        }
    });
    shadow.finish();
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

/// Parallel map over the elements of a mutable slice: `f(i, &mut items[i])`
/// runs exactly once per index (claimed dynamically via an atomic cursor),
/// and the results are returned in index order.  This is the primitive
/// behind the intra-device parallel block step: each cluster block is
/// mutated by exactly one worker per epoch.
pub fn par_map_mut<T, R, F>(items: &mut [T], threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots = out.as_mut_ptr() as usize;
    let base = items.as_mut_ptr() as usize;
    let shadow = ShadowClaims::new(n);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                shadow.claim(i);
                // SAFETY: each index i is claimed exactly once via the
                // atomic cursor, so no two threads alias items[i] or the
                // result slot; both vectors outlive the scope.
                let item = unsafe { &mut *(base as *mut T).add(i) };
                let v = f(i, item);
                // SAFETY: as above — slot i is owned by this claim.
                unsafe {
                    std::ptr::write((slots as *mut Option<R>).add(i), Some(v));
                }
            });
        }
    });
    shadow.finish();
    out.into_iter().map(|v| v.expect("slot filled")).collect()
}

/// Parallel-for over mutable disjoint row chunks of a flat f32 matrix
/// (`rows x cols`, row-major).  Each worker gets exclusive chunks of rows.
pub fn par_rows_mut<F>(data: &mut [f32], cols: usize, chunk_rows: usize, threads: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let rows = if cols == 0 { 0 } else { data.len() / cols };
    let threads = threads.max(1);
    if threads <= 1 || rows <= chunk_rows {
        for (r0, chunk) in data.chunks_mut(chunk_rows * cols).enumerate() {
            f(r0 * chunk_rows, chunk);
        }
        return;
    }
    let base = data.as_mut_ptr() as usize;
    let shadow = ShadowClaims::new(rows);
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let r0 = cursor.fetch_add(chunk_rows, Ordering::Relaxed);
                if r0 >= rows {
                    break;
                }
                let r1 = (r0 + chunk_rows).min(rows);
                shadow.claim_range(r0, r1);
                // SAFETY: row ranges [r0, r1) are disjoint across workers
                // (claimed via the atomic cursor) and in-bounds.
                let slice = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut f32).add(r0 * cols),
                        (r1 - r0) * cols,
                    )
                };
                f(r0, slice);
            });
        }
    });
    shadow.finish();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for_chunks(n, 64, 8, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(1000, 8, |i| i * 3);
        assert_eq!(out, (0..1000).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn par_rows_mut_disjoint() {
        let cols = 4;
        let mut m = vec![0f32; 100 * cols];
        par_rows_mut(&mut m, cols, 7, 8, |r0, chunk| {
            for (dr, row) in chunk.chunks_mut(cols).enumerate() {
                for v in row.iter_mut() {
                    *v = (r0 + dr) as f32;
                }
            }
        });
        for r in 0..100 {
            for c in 0..cols {
                assert_eq!(m[r * cols + c], r as f32);
            }
        }
    }

    #[test]
    fn par_map_mut_mutates_and_orders() {
        let mut items: Vec<u64> = (0..500).collect();
        let out = par_map_mut(&mut items, 8, |i, v| {
            *v += 1;
            (i as u64) * 2
        });
        assert_eq!(items, (1..=500).collect::<Vec<_>>());
        assert_eq!(out, (0..500).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_mut_single_thread() {
        let mut items = vec![1u32, 2, 3];
        let out = par_map_mut(&mut items, 1, |i, v| {
            *v *= 10;
            i
        });
        assert_eq!(items, vec![10, 20, 30]);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn single_thread_fallback() {
        let out = par_map(5, 1, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        par_for_chunks(3, 10, 4, |a, b| assert_eq!((a, b), (0, 3)));
    }
}
