//! The positive-edge distribution p(j|i) over the ANN graph.
//!
//! NOMAD models p(j|i) explicitly with the **inverse-rank model**
//! (paper Eq 6):
//!
//! ```text
//! p(j|i) = exp(1/rank_j(i)) / C   if rank_j(i) <= k, else 0
//! C      = sum_{r=1..k} exp(1/r)
//! ```
//!
//! where `rank_j(i)` is the (1-based) position of **i in j's** distance-
//! sorted neighbor list — a *reverse* rank, as written in the paper.  We
//! also provide the forward-rank and uniform models as ablations
//! (`WeightModel`), benchmarked in `benches/ablations.rs`.

use super::{ClusterIndex, NO_NEIGHBOR};

/// How edge weights p(j|i) are computed from the kNN lists.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightModel {
    /// exp(1 / rank_j(i)) — the paper's Eq 6 (reverse rank).
    InverseRankPaper,
    /// exp(1 / rank_i(j)) — forward rank (i's own list), ablation.
    InverseRankForward,
    /// 1/k on every kNN edge, ablation (InfoNC-t-SNE's implicit model).
    Uniform,
}

/// The per-head positive edge lists with weights, in CSR-like fixed-k
/// layout aligned with `ClusterIndex::nbr_idx`.
#[derive(Clone, Debug)]
pub struct EdgeWeights {
    /// flat n x k weights; 0.0 marks absent/pruned edges
    pub w: Vec<f32>,
    pub k: usize,
}

impl EdgeWeights {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.w[i * self.k..(i + 1) * self.k]
    }
}

/// Compute p(j|i) for every kNN edge of the index.
pub fn edge_weights(index: &ClusterIndex, model: WeightModel) -> EdgeWeights {
    let n = index.n();
    let k = index.k;
    let norm: f32 = (1..=k).map(|r| (1.0f32 / r as f32).exp()).sum();
    let mut w = vec![0.0f32; n * k];

    match model {
        WeightModel::Uniform => {
            for i in 0..n {
                for s in 0..k {
                    if index.nbr_idx[i * k + s] != NO_NEIGHBOR {
                        w[i * k + s] = 1.0 / k as f32;
                    }
                }
            }
        }
        WeightModel::InverseRankForward => {
            for i in 0..n {
                for s in 0..k {
                    if index.nbr_idx[i * k + s] != NO_NEIGHBOR {
                        w[i * k + s] = ((1.0 / (s + 1) as f32).exp()) / norm;
                    }
                }
            }
        }
        WeightModel::InverseRankPaper => {
            // rank_j(i): position of i in j's sorted list. Build a reverse
            // lookup: for each directed edge j -> i at slot s, set the weight
            // of the edge i -> j (if present) to exp(1/(s+1))/C.
            // First index the slots: slot_of[i][j] for j in i's list.
            for i in 0..n {
                for s in 0..k {
                    let j = index.nbr_idx[i * k + s];
                    if j == NO_NEIGHBOR {
                        continue;
                    }
                    // find i in j's neighbor list
                    let j = j as usize;
                    let mut rank_ji = None;
                    for t in 0..k {
                        if index.nbr_idx[j * k + t] == i as u32 {
                            rank_ji = Some(t + 1);
                            break;
                        }
                    }
                    if let Some(r) = rank_ji {
                        w[i * k + s] = ((1.0 / r as f32).exp()) / norm;
                    }
                    // non-mutual edges keep weight 0 (pruned), per Eq 6.
                }
            }
        }
    }
    EdgeWeights { w, k }
}

/// Fraction of kNN edges that are mutual (diagnostic; the paper's reverse-
/// rank model zeroes non-mutual edges, so low mutuality means a sparser
/// effective graph).
pub fn mutuality(index: &ClusterIndex) -> f64 {
    let n = index.n();
    let k = index.k;
    let mut present = 0usize;
    let mut mutual = 0usize;
    for i in 0..n {
        for s in 0..k {
            let j = index.nbr_idx[i * k + s];
            if j == NO_NEIGHBOR {
                continue;
            }
            present += 1;
            let j = j as usize;
            if (0..k).any(|t| index.nbr_idx[j * k + t] == i as u32) {
                mutual += 1;
            }
        }
    }
    mutual as f64 / present.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::ann::IndexParams;
    use crate::data::gaussian_mixture;
    use crate::util::rng::Rng;

    fn toy_index(n: usize, k: usize) -> ClusterIndex {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(n, 8, 3, 6.0, 0.2, 0.5, &mut rng);
        ClusterIndex::build(
            &ds.x,
            &IndexParams { n_clusters: 3, k, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        )
    }

    #[test]
    fn uniform_weights_sum_to_one() {
        let idx = toy_index(200, 5);
        let ew = edge_weights(&idx, WeightModel::Uniform);
        for i in 0..200 {
            let s: f32 = ew.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn forward_rank_weights_decrease_with_rank() {
        let idx = toy_index(200, 6);
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        for i in 0..200 {
            let r = ew.row(i);
            for s in 1..6 {
                assert!(r[s] <= r[s - 1] + 1e-7);
            }
        }
    }

    #[test]
    fn paper_rank_uses_reverse_rank() {
        // handcrafted: 3 colinear points, distances 0-1:1, 1-2:1, 0-2:4
        use crate::linalg::Matrix;
        let x = Matrix::from_vec(3, 1, vec![0.0, 1.0, 3.0]);
        let be = NativeBackend::default();
        let idx_raw = crate::ann::knn::within_clusters(&x, &[vec![0, 1, 2]], 2, &be);
        let index = ClusterIndex {
            assign: vec![0, 0, 0],
            clusters: vec![vec![0, 1, 2]],
            centroids: Matrix::zeros(1, 1),
            nbr_idx: idx_raw.0,
            nbr_d2: idx_raw.1,
            k: 2,
        };
        let ew = edge_weights(&index, WeightModel::InverseRankPaper);
        let norm: f32 = (1..=2).map(|r| (1.0f32 / r as f32).exp()).sum();
        // point 0's list: [1, 2]; point 1's list: [0, 2]; point 2's list: [1, 0]
        // edge 0->1: rank_1(0) = position of 0 in 1's list = 1 -> e^1/C
        assert!((ew.row(0)[0] - (1.0f32).exp() / norm).abs() < 1e-6);
        // edge 0->2: rank_2(0) = position of 0 in 2's list = 2 -> e^0.5/C
        assert!((ew.row(0)[1] - (0.5f32).exp() / norm).abs() < 1e-6);
        // edge 2->1: rank_1(2) = position of 2 in 1's list = 2 -> e^0.5/C
        assert!((ew.row(2)[0] - (0.5f32).exp() / norm).abs() < 1e-6);
    }

    #[test]
    fn mutuality_in_unit_range() {
        let idx = toy_index(300, 5);
        let m = mutuality(&idx);
        assert!((0.0..=1.0).contains(&m));
        assert!(m > 0.2, "gaussian blobs should have substantial mutuality");
    }
}
