//! LSH-seeded K-Means (paper §3.2): "We initialize our K-Means clustering
//! using a locally sensitive hash, run expectation maximization until
//! convergence, and compute exact nearest neighbors for each point within
//! its cluster."
//!
//! Additions beyond the paper text, needed for a production build:
//!  * empty clusters are re-seeded to the point farthest from its centroid;
//!  * clusters above `max_cluster_size` are recursively 2-means split so
//!    shard buckets stay bounded (the AOT step artifacts have fixed shapes).
//!
//! The E-step assignment runs on the backend's distance engine — natively,
//! the tiled norm-trick kernels of `crate::linalg::distance` (DESIGN.md §8).

use super::backend::AnnBackend;
use super::IndexParams;
use crate::linalg::{lsh::lsh_seed_centroids, Matrix};
use crate::util::rng::Rng;

/// K-Means result: assignment plus per-cluster member lists.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub assign: Vec<u32>,
    pub clusters: Vec<Vec<u32>>,
    pub centroids: Matrix,
    pub iters_run: usize,
}

/// Run LSH-seeded EM, then enforce the max-cluster-size bound.
pub fn run(
    x: &Matrix,
    params: &IndexParams,
    backend: &dyn AnnBackend,
    rng: &mut Rng,
) -> KmeansResult {
    let k = params.n_clusters.min(x.rows).max(1);
    let mut centroids = lsh_seed_centroids(x, k, rng);
    let mut assign = vec![0u32; x.rows];
    let mut iters_run = 0;

    for it in 0..params.max_iters {
        let pairs = backend.assign(x, &centroids);
        let mut changed = 0usize;
        for (i, (a, _)) in pairs.iter().enumerate() {
            if assign[i] != *a {
                changed += 1;
            }
            assign[i] = *a;
        }
        iters_run = it + 1;

        // M step
        let c = centroids.rows;
        let d = x.cols;
        let mut sums = vec![0.0f64; c * d];
        let mut counts = vec![0usize; c];
        for i in 0..x.rows {
            let a = assign[i] as usize;
            counts[a] += 1;
            let row = x.row(i);
            for j in 0..d {
                sums[a * d + j] += row[j] as f64;
            }
        }
        for a in 0..c {
            if counts[a] == 0 {
                // re-seed to the point farthest from its current centroid
                let far = (0..x.rows)
                    .max_by(|&p, &q| {
                        let dp = crate::linalg::d2(x.row(p), centroids.row(assign[p] as usize));
                        let dq = crate::linalg::d2(x.row(q), centroids.row(assign[q] as usize));
                        dp.total_cmp(&dq)
                    })
                    .unwrap();
                centroids.row_mut(a).copy_from_slice(x.row(far));
            } else {
                let inv = 1.0 / counts[a] as f64;
                let cr = centroids.row_mut(a);
                for j in 0..d {
                    cr[j] = (sums[a * d + j] * inv) as f32;
                }
            }
        }

        if it > 0 && (changed as f64) < params.tol_frac * x.rows as f64 {
            break;
        }
    }

    // final assignment against converged centroids
    let pairs = backend.assign(x, &centroids);
    for (i, (a, _)) in pairs.iter().enumerate() {
        assign[i] = *a;
    }

    let mut result = KmeansResult {
        clusters: members_of(&assign, centroids.rows),
        assign,
        centroids,
        iters_run,
    };
    enforce_max_size(x, &mut result, params.max_cluster_size, backend, rng);
    result
}

fn members_of(assign: &[u32], c: usize) -> Vec<Vec<u32>> {
    let mut m = vec![Vec::new(); c];
    for (i, &a) in assign.iter().enumerate() {
        m[a as usize].push(i as u32);
    }
    m
}

/// Split any cluster above `max_size` with 2-means until all fit.
fn enforce_max_size(
    x: &Matrix,
    km: &mut KmeansResult,
    max_size: usize,
    backend: &dyn AnnBackend,
    rng: &mut Rng,
) {
    let mut queue: Vec<usize> = (0..km.clusters.len())
        .filter(|&c| km.clusters[c].len() > max_size)
        .collect();
    while let Some(c) = queue.pop() {
        let members = std::mem::take(&mut km.clusters[c]);
        let sub = x.gather(&members.iter().map(|&m| m as usize).collect::<Vec<_>>());
        // 2-means on the oversize cluster
        let mut c2 = Matrix::zeros(2, x.cols);
        let a = rng.below(sub.rows);
        let b = (0..sub.rows)
            .max_by(|&p, &q| {
                let dp = crate::linalg::d2(sub.row(p), sub.row(a));
                let dq = crate::linalg::d2(sub.row(q), sub.row(a));
                dp.total_cmp(&dq)
            })
            .unwrap();
        c2.row_mut(0).copy_from_slice(sub.row(a));
        c2.row_mut(1).copy_from_slice(sub.row(b));
        let mut sub_assign = vec![0u32; sub.rows];
        for _ in 0..8 {
            let pairs = backend.assign(&sub, &c2);
            for (i, (aa, _)) in pairs.iter().enumerate() {
                sub_assign[i] = *aa;
            }
            for half in 0..2 {
                let mut cnt = 0usize;
                let mut acc = vec![0.0f64; x.cols];
                for i in 0..sub.rows {
                    if sub_assign[i] as usize == half {
                        cnt += 1;
                        for (j, v) in sub.row(i).iter().enumerate() {
                            acc[j] += *v as f64;
                        }
                    }
                }
                if cnt > 0 {
                    let row = c2.row_mut(half);
                    for j in 0..x.cols {
                        row[j] = (acc[j] / cnt as f64) as f32;
                    }
                }
            }
        }
        // degenerate split (all points identical): force a balanced halving
        if sub_assign.iter().all(|&a| a == 0) || sub_assign.iter().all(|&a| a == 1) {
            for (i, sa) in sub_assign.iter_mut().enumerate() {
                *sa = (i % 2) as u32;
            }
        }

        let new_c = km.clusters.len();
        km.clusters.push(Vec::new());
        // grow the centroid matrix by one row
        let mut grown = Matrix::zeros(new_c + 1, x.cols);
        grown.data[..km.centroids.data.len()].copy_from_slice(&km.centroids.data);
        grown.row_mut(c).copy_from_slice(c2.row(0));
        grown.row_mut(new_c).copy_from_slice(c2.row(1));
        km.centroids = grown;

        let mut keep = Vec::new();
        for (local, &global) in members.iter().enumerate() {
            if sub_assign[local] == 0 {
                keep.push(global);
            } else {
                km.assign[global as usize] = new_c as u32;
                km.clusters[new_c].push(global);
            }
        }
        km.clusters[c] = keep;
        for cc in [c, new_c] {
            if km.clusters[cc].len() > max_size {
                queue.push(cc);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::data::gaussian_mixture;

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(400, 8, 4, 25.0, 0.0, 0.0, &mut rng);
        let params = IndexParams { n_clusters: 4, k: 5, ..Default::default() };
        let km = run(&ds.x, &params, &NativeBackend::default(), &mut rng);
        // purity: each kmeans cluster dominated by one true label
        for members in &km.clusters {
            if members.is_empty() {
                continue;
            }
            let mut counts = std::collections::HashMap::new();
            for &m in members {
                *counts.entry(ds.labels[0][m as usize]).or_insert(0usize) += 1;
            }
            let max = counts.values().max().unwrap();
            assert!(
                *max as f64 / members.len() as f64 > 0.95,
                "cluster purity too low"
            );
        }
    }

    #[test]
    fn every_point_assigned_and_listed_once() {
        let mut rng = Rng::new(1);
        let ds = gaussian_mixture(257, 8, 5, 4.0, 0.4, 0.8, &mut rng);
        let params = IndexParams { n_clusters: 5, k: 5, ..Default::default() };
        let km = run(&ds.x, &params, &NativeBackend::default(), &mut rng);
        let mut seen = vec![0usize; 257];
        for (c, members) in km.clusters.iter().enumerate() {
            for &m in members {
                seen[m as usize] += 1;
                assert_eq!(km.assign[m as usize] as usize, c);
            }
        }
        assert!(seen.iter().all(|&s| s == 1));
    }

    #[test]
    fn split_bounds_cluster_size() {
        let mut rng = Rng::new(2);
        let ds = gaussian_mixture(1000, 4, 1, 1.0, 0.0, 0.0, &mut rng);
        let params = IndexParams {
            n_clusters: 1,
            k: 3,
            max_cluster_size: 130,
            ..Default::default()
        };
        let km = run(&ds.x, &params, &NativeBackend::default(), &mut rng);
        assert!(km.clusters.iter().all(|c| c.len() <= 130));
        assert_eq!(km.clusters.iter().map(|c| c.len()).sum::<usize>(), 1000);
        assert_eq!(km.centroids.rows, km.clusters.len());
    }
}
