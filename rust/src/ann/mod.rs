//! The NOMAD ANN index (paper §3.2).
//!
//! NOMAD Projection deliberately avoids FAISS/PyNNDescent-style indexes
//! because their kNN graphs do not shard cleanly.  Instead:
//!
//! 1. K-Means clustering, **initialized with a locality-sensitive hash**,
//!    run to convergence with EM ([`kmeans`]);
//! 2. **exact** kNN computed *within* each cluster ([`knn`]);
//! 3. the resulting graph is a disjoint union of per-cluster components
//!    ([`graph`]), so clusters shard across devices with zero inter-device
//!    communication during positive (attractive) force computation.
//!
//! The high-dimensional distance work (assignment, within-cluster kNN) is
//! behind the [`backend::AnnBackend`] trait: the native implementation
//! runs on the tiled norm-trick distance engine (`crate::linalg::distance`,
//! see DESIGN.md §8 for the tile layout and tie-breaking contract); the
//! AOT/XLA implementation lives in `crate::runtime` and is cross-checked
//! against this one in the integration tests.

pub mod backend;
pub mod graph;
pub mod kmeans;
pub mod knn;

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// The built index: cluster structure plus the within-cluster kNN graph.
#[derive(Clone, Debug)]
pub struct ClusterIndex {
    /// cluster id of every point
    pub assign: Vec<u32>,
    /// members of each cluster (global point ids)
    pub clusters: Vec<Vec<u32>>,
    /// centroids in the *ambient* space (c x d)
    pub centroids: Matrix,
    /// kNN edges: `nbr_idx[i*k..(i+1)*k]` = global ids of i's neighbors,
    /// sorted ascending by distance; `u32::MAX` marks a missing slot
    /// (cluster smaller than k+1).
    pub nbr_idx: Vec<u32>,
    /// squared distances matching `nbr_idx` (f32::INFINITY for missing)
    pub nbr_d2: Vec<f32>,
    pub k: usize,
}

/// Marker for an absent neighbor slot.
pub const NO_NEIGHBOR: u32 = u32::MAX;

/// Index build parameters.
#[derive(Clone, Debug)]
pub struct IndexParams {
    /// number of K-Means clusters (devices shard these)
    pub n_clusters: usize,
    /// neighbors per point
    pub k: usize,
    /// max EM iterations
    pub max_iters: usize,
    /// EM stops when fewer than `tol_frac` of points change cluster
    pub tol_frac: f64,
    /// clusters larger than this are split (keeps shard buckets bounded)
    pub max_cluster_size: usize,
}

impl Default for IndexParams {
    fn default() -> Self {
        IndexParams {
            n_clusters: 32,
            k: 15,
            max_iters: 25,
            tol_frac: 0.005,
            max_cluster_size: 8192,
        }
    }
}

impl ClusterIndex {
    /// Build the index over `x` using the given distance backend.
    pub fn build(
        x: &Matrix,
        params: &IndexParams,
        backend: &dyn backend::AnnBackend,
        rng: &mut Rng,
    ) -> ClusterIndex {
        let km = kmeans::run(x, params, backend, rng);
        let (nbr_idx, nbr_d2) = knn::within_clusters(x, &km.clusters, params.k, backend);
        ClusterIndex {
            assign: km.assign,
            clusters: km.clusters,
            centroids: km.centroids,
            nbr_idx,
            nbr_d2,
            k: params.k,
        }
    }

    pub fn n(&self) -> usize {
        self.assign.len()
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    /// neighbors of point i (global ids, NO_NEIGHBOR-padded)
    pub fn neighbors(&self, i: usize) -> &[u32] {
        &self.nbr_idx[i * self.k..(i + 1) * self.k]
    }

    pub fn neighbor_d2(&self, i: usize) -> &[f32] {
        &self.nbr_d2[i * self.k..(i + 1) * self.k]
    }

    /// Verify the defining invariant: every kNN edge stays inside one
    /// cluster (no cross-device positive forces).  Used by tests and debug
    /// assertions.
    pub fn edges_respect_clusters(&self) -> bool {
        for i in 0..self.n() {
            for &j in self.neighbors(i) {
                if j != NO_NEIGHBOR && self.assign[j as usize] != self.assign[i] {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_mixture;

    #[test]
    fn build_produces_consistent_index() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(600, 16, 6, 8.0, 0.3, 0.5, &mut rng);
        let params = IndexParams { n_clusters: 6, k: 5, ..Default::default() };
        let be = backend::NativeBackend::default();
        let idx = ClusterIndex::build(&ds.x, &params, &be, &mut rng);

        assert_eq!(idx.n(), 600);
        assert!(idx.n_clusters() >= 6);
        // members lists match assign
        for (c, members) in idx.clusters.iter().enumerate() {
            for &m in members {
                assert_eq!(idx.assign[m as usize] as usize, c);
            }
        }
        let total: usize = idx.clusters.iter().map(|c| c.len()).sum();
        assert_eq!(total, 600);
        assert!(idx.edges_respect_clusters());
    }

    #[test]
    fn knn_edges_are_sorted_and_self_free() {
        let mut rng = Rng::new(1);
        let ds = gaussian_mixture(300, 8, 3, 10.0, 0.0, 0.0, &mut rng);
        let params = IndexParams { n_clusters: 3, k: 7, ..Default::default() };
        let be = backend::NativeBackend::default();
        let idx = ClusterIndex::build(&ds.x, &params, &be, &mut rng);
        for i in 0..idx.n() {
            let ds_ = idx.neighbor_d2(i);
            for w in ds_.windows(2) {
                assert!(w[0] <= w[1], "distances sorted");
            }
            for &j in idx.neighbors(i) {
                assert_ne!(j, i as u32, "no self edges");
            }
        }
    }

    #[test]
    fn oversize_clusters_are_split() {
        let mut rng = Rng::new(2);
        // single blob forces everything into one cluster unless split
        let ds = gaussian_mixture(500, 8, 1, 1.0, 0.0, 0.0, &mut rng);
        let params = IndexParams {
            n_clusters: 2,
            k: 3,
            max_cluster_size: 200,
            ..Default::default()
        };
        let be = backend::NativeBackend::default();
        let idx = ClusterIndex::build(&ds.x, &params, &be, &mut rng);
        assert!(idx.clusters.iter().all(|c| c.len() <= 200));
        assert!(idx.edges_respect_clusters());
    }
}
