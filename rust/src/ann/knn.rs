//! Within-cluster exact kNN (paper §3.2) plus the brute-force global kNN
//! used as metric ground truth.

use super::backend::AnnBackend;
use super::NO_NEIGHBOR;
use crate::linalg::{d2, Matrix};
use crate::util::parallel::{num_threads, par_map};

/// Exact kNN inside each cluster, results in *global* point ids.
/// Returns flat `(idx, d2)` arrays of shape n x k.
pub fn within_clusters(
    x: &Matrix,
    clusters: &[Vec<u32>],
    k: usize,
    backend: &dyn AnnBackend,
) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let mut nbr_idx = vec![NO_NEIGHBOR; n * k];
    let mut nbr_d2 = vec![f32::INFINITY; n * k];

    // process clusters serially; the backend parallelizes internally (the
    // distributed coordinator overlaps clusters across devices instead)
    for members in clusters {
        if members.len() <= 1 {
            continue;
        }
        let ids: Vec<usize> = members.iter().map(|&m| m as usize).collect();
        let sub = x.gather(&ids);
        let (l_idx, l_d2) = backend.knn(&sub, k);
        for (local, &global) in members.iter().enumerate() {
            let g = global as usize;
            for s in 0..k {
                let li = l_idx[local * k + s];
                if li != NO_NEIGHBOR {
                    nbr_idx[g * k + s] = members[li as usize];
                    nbr_d2[g * k + s] = l_d2[local * k + s];
                }
            }
        }
    }
    (nbr_idx, nbr_d2)
}

/// Brute-force exact global kNN — O(n²d), used only for metric ground truth
/// and small-scale validation.  Parallel over query points.
pub fn exact_global(x: &Matrix, k: usize) -> Vec<u32> {
    let n = x.rows;
    let threads = num_threads();
    let rows = par_map(n, threads, |i| {
        let mut best: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        let xi = x.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let dist = d2(xi, x.row(j));
            if best.len() < k {
                best.push((dist, j as u32));
                if best.len() == k {
                    best.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                }
            } else if dist < best[0].0 {
                best[0] = (dist, j as u32);
                let mut p = 0;
                while p + 1 < k && best[p].0 < best[p + 1].0 {
                    best.swap(p, p + 1);
                    p += 1;
                }
            }
        }
        best.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut out = vec![NO_NEIGHBOR; k];
        for (s, (_, j)) in best.into_iter().enumerate() {
            out[s] = j;
        }
        out
    });
    rows.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn within_cluster_ids_are_global_and_in_cluster() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 60, 4);
        let clusters = vec![
            (0..30u32).collect::<Vec<_>>(),
            (30..60u32).collect::<Vec<_>>(),
        ];
        let (idx, dd) = within_clusters(&x, &clusters, 5, &NativeBackend::default());
        for i in 0..60 {
            let my_cluster = (i >= 30) as usize;
            for s in 0..5 {
                let j = idx[i * 5 + s];
                assert_ne!(j, NO_NEIGHBOR);
                assert_eq!((j >= 30) as usize, my_cluster, "edge stays in cluster");
                assert!(dd[i * 5 + s].is_finite());
            }
        }
    }

    #[test]
    fn tiny_cluster_padded() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 4, 3);
        let clusters = vec![vec![0u32, 1], vec![2], vec![3]];
        let (idx, _) = within_clusters(&x, &clusters, 3, &NativeBackend::default());
        assert_eq!(idx[0 * 3], 1);
        assert_eq!(idx[0 * 3 + 1], NO_NEIGHBOR);
        assert_eq!(idx[2 * 3], NO_NEIGHBOR); // singleton has no neighbors
    }

    #[test]
    fn exact_global_matches_naive() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 50, 5);
        let k = 4;
        let got = exact_global(&x, k);
        for i in 0..50 {
            let mut all: Vec<(f32, u32)> = (0..50)
                .filter(|&j| j != i)
                .map(|j| (d2(x.row(i), x.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            assert_eq!(got[i * k], all[0].1, "nearest neighbor row {i}");
        }
    }
}
