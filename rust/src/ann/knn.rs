//! Within-cluster exact kNN (paper §3.2) plus the brute-force global kNN
//! used as metric ground truth.  Distance work runs on the tiled
//! norm-trick engine (`crate::linalg::distance`, DESIGN.md §8); the
//! `*_naive` functions keep the pointwise scans as exact-match oracles.

use super::backend::{knn_naive, AnnBackend};
use super::NO_NEIGHBOR;
use crate::linalg::{distance, Matrix};
use crate::util::parallel::{num_threads, par_for_chunks};

/// Exact kNN inside each cluster, results in *global* point ids.
/// Returns flat `(idx, d2)` arrays of shape n x k.
///
/// Clusters must be disjoint subsets of `0..x.rows` (checked); points not
/// listed in any cluster keep the `NO_NEIGHBOR`/∞ padding.
pub fn within_clusters(
    x: &Matrix,
    clusters: &[Vec<u32>],
    k: usize,
    backend: &dyn AnnBackend,
) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let mut nbr_idx = vec![NO_NEIGHBOR; n * k];
    let mut nbr_d2 = vec![f32::INFINITY; n * k];

    // The raw-pointer scatter below is sound only if cluster member lists
    // are in-range and pairwise disjoint — validate up front (O(n), free
    // next to the O(n_c²·d) kNN work) instead of risking racing writes.
    let mut seen = vec![false; n];
    for members in clusters {
        for &m in members {
            let m = m as usize;
            assert!(
                m < n && !seen[m],
                "clusters must be disjoint subsets of 0..{n} (bad id {m})"
            );
            seen[m] = true;
        }
    }

    // Clusters are dispatched to workers largest-first over par_for_chunks'
    // dynamic cursor; each worker gathers its cluster, runs the backend's
    // kNN with a share of the thread pool, and scatters results straight
    // into the per-cluster slices of the global neighbor arrays.  Member
    // lists are disjoint (checked above), so those row ranges are written
    // by exactly one worker; results are position-addressed, so the output
    // is independent of scheduling.  (The distributed coordinator overlaps
    // clusters across devices on top of this.)
    let mut order: Vec<usize> =
        (0..clusters.len()).filter(|&c| clusters[c].len() > 1).collect();
    order.sort_by_key(|&c| std::cmp::Reverse(clusters[c].len()));
    if order.is_empty() {
        return (nbr_idx, nbr_d2);
    }
    let threads = num_threads().max(1);
    // Split the pool between cluster-level and intra-cluster parallelism by
    // the work profile: per-cluster kNN is O(n_c²·d), so cap the number of
    // concurrently running clusters at total_work / max_work — when one
    // giant cluster dominates, outer collapses toward 1 and the giant gets
    // the whole pool via `knn_with_budget` instead of serializing on a
    // single thread while the other workers idle.
    let work: Vec<u64> = order.iter().map(|&c| (clusters[c].len() as u64).pow(2)).collect();
    let total_work: u64 = work.iter().sum();
    let max_work = work[0].max(1); // order is largest-first
    let par_limit = (total_work / max_work).max(1) as usize;
    let outer = threads.min(order.len()).min(par_limit);
    let inner = (threads / outer).max(1);
    // distribute the remainder: the first `rem` (largest) clusters get one
    // extra worker so no thread idles when threads % outer != 0
    let rem = threads % outer;
    let idx_base = nbr_idx.as_mut_ptr() as usize;
    let d2_base = nbr_d2.as_mut_ptr() as usize;
    par_for_chunks(order.len(), 1, outer, |t0, t1| {
        for t in t0..t1 {
            let members = &clusters[order[t]];
            let ids: Vec<usize> = members.iter().map(|&m| m as usize).collect();
            let sub = x.gather(&ids);
            let budget = inner + usize::from(t < rem);
            let (l_idx, l_d2) = backend.knn_with_budget(&sub, k, budget);
            for (local, &global) in members.iter().enumerate() {
                let g = global as usize;
                // SAFETY: member lists are pairwise disjoint and in-range
                // (validated above), so rows [g*k, (g+1)*k) are written by
                // exactly one worker; both vectors outlive the call.
                let oi = unsafe {
                    std::slice::from_raw_parts_mut((idx_base as *mut u32).add(g * k), k)
                };
                // SAFETY: as above — the same rows of the d² vector.
                let od = unsafe {
                    std::slice::from_raw_parts_mut((d2_base as *mut f32).add(g * k), k)
                };
                for slot in 0..k {
                    let li = l_idx[local * k + slot];
                    if li != NO_NEIGHBOR {
                        oi[slot] = members[li as usize];
                        od[slot] = l_d2[local * k + slot];
                    }
                }
            }
        }
    });
    (nbr_idx, nbr_d2)
}

/// The pre-engine serial build: clusters walked one after another through
/// the [`knn_naive`] oracle.  Kept for the exact-match property tests and
/// the naive side of `bench/index_build`.
pub fn within_clusters_naive(x: &Matrix, clusters: &[Vec<u32>], k: usize) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let mut nbr_idx = vec![NO_NEIGHBOR; n * k];
    let mut nbr_d2 = vec![f32::INFINITY; n * k];
    for members in clusters {
        if members.len() <= 1 {
            continue;
        }
        let ids: Vec<usize> = members.iter().map(|&m| m as usize).collect();
        let sub = x.gather(&ids);
        let (l_idx, l_d2) = knn_naive(&sub, k);
        for (local, &global) in members.iter().enumerate() {
            let g = global as usize;
            for slot in 0..k {
                let li = l_idx[local * k + slot];
                if li != NO_NEIGHBOR {
                    nbr_idx[g * k + slot] = members[li as usize];
                    nbr_d2[g * k + slot] = l_d2[local * k + slot];
                }
            }
        }
    }
    (nbr_idx, nbr_d2)
}

/// Brute-force exact global kNN — O(n²d), used only for metric ground
/// truth and small-scale validation.  Runs on the tiled engine.
pub fn exact_global(x: &Matrix, k: usize) -> Vec<u32> {
    let (idx, _) = distance::self_knn_tiled(x, k, num_threads());
    idx
}

/// Sort-everything oracle for [`exact_global`] (same `(d², index)`
/// ordering contract), single-threaded.
pub fn exact_global_naive(x: &Matrix, k: usize) -> Vec<u32> {
    let (idx, _) = knn_naive(x, k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::linalg::d2;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn within_cluster_ids_are_global_and_in_cluster() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 60, 4);
        let clusters = vec![
            (0..30u32).collect::<Vec<_>>(),
            (30..60u32).collect::<Vec<_>>(),
        ];
        let (idx, dd) = within_clusters(&x, &clusters, 5, &NativeBackend::default());
        for i in 0..60 {
            let my_cluster = (i >= 30) as usize;
            for s in 0..5 {
                let j = idx[i * 5 + s];
                assert_ne!(j, NO_NEIGHBOR);
                assert_eq!((j >= 30) as usize, my_cluster, "edge stays in cluster");
                assert!(dd[i * 5 + s].is_finite());
            }
        }
    }

    #[test]
    fn tiny_cluster_padded() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 4, 3);
        let clusters = vec![vec![0u32, 1], vec![2], vec![3]];
        let (idx, _) = within_clusters(&x, &clusters, 3, &NativeBackend::default());
        assert_eq!(idx[0 * 3], 1);
        assert_eq!(idx[0 * 3 + 1], NO_NEIGHBOR);
        assert_eq!(idx[2 * 3], NO_NEIGHBOR); // singleton has no neighbors
    }

    #[test]
    fn exact_global_matches_naive() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 50, 5);
        let k = 4;
        let got = exact_global(&x, k);
        for i in 0..50 {
            let mut all: Vec<(f32, u32)> = (0..50)
                .filter(|&j| j != i)
                .map(|j| (d2(x.row(i), x.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            assert_eq!(got[i * k], all[0].1, "nearest neighbor row {i}");
        }
    }

    #[test]
    fn parallel_dispatch_matches_serial_oracle_on_many_clusters() {
        // more clusters than threads, ragged sizes — the dynamic dispatch
        // must land every cluster's rows exactly once
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 157, 6);
        let mut clusters: Vec<Vec<u32>> = vec![Vec::new(); 12];
        for i in 0..157u32 {
            clusters[rng.below(12)].push(i);
        }
        let (idx, _) = within_clusters(&x, &clusters, 4, &NativeBackend::default());
        // structural check against the membership map (distances are
        // engine-vs-naive checked exactly in tests/distance_engine.rs)
        let mut owner = vec![u32::MAX; 157];
        for (c, members) in clusters.iter().enumerate() {
            for &m in members {
                owner[m as usize] = c as u32;
            }
        }
        for i in 0..157 {
            for s in 0..4 {
                let j = idx[i * 4 + s];
                if j != NO_NEIGHBOR {
                    assert_eq!(owner[j as usize], owner[i], "edge stays in cluster");
                    assert_ne!(j as usize, i);
                }
            }
        }
    }
}
