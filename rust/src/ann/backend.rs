//! Distance-computation backend abstraction.
//!
//! The heavy O(N·C·D) assignment and O(n_c²·D) within-cluster kNN work can
//! run either natively (the tiled norm-trick engine,
//! `crate::linalg::distance`, DESIGN.md §8) or through the AOT-compiled
//! XLA artifacts (`crate::runtime::XlaAnnBackend`).  Both implement
//! [`AnnBackend`] and must agree numerically — the integration tests
//! cross-check them.
//!
//! The pre-engine pointwise scans are kept here as [`assign_naive`] and
//! [`knn_naive`]: slow, obviously-correct oracles implementing the same
//! `(d², index)` ordering contract as the engine, which the property
//! tests in `tests/distance_engine.rs` compare against exactly.

use crate::linalg::{d2, distance, quant, Matrix};
use crate::util::parallel::num_threads;

/// Pluggable distance engine for the ANN index build.
///
/// `Sync` is a supertrait: the within-cluster kNN build dispatches whole
/// clusters across worker threads, each calling into the backend
/// concurrently.
pub trait AnnBackend: Sync {
    /// For each row of `x`, the nearest centroid and its squared distance.
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)>;

    /// Exact kNN among the rows of `x` (one cluster), excluding self.
    /// Returns `(idx, d2)` of shape n x k (row-major), local indices,
    /// `u32::MAX` / `INFINITY` padding when n <= k.
    fn knn(&self, x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>);

    /// Like [`AnnBackend::knn`], but with an explicit worker budget: the
    /// within-cluster build runs whole clusters on separate threads and
    /// hands each call its share of the pool.  Backends that do their own
    /// scheduling (e.g. a device queue) may ignore the hint — the default
    /// does.
    fn knn_with_budget(&self, x: &Matrix, k: usize, threads: usize) -> (Vec<u32>, Vec<f32>) {
        let _ = threads;
        self.knn(x, k)
    }
}

/// Tiled, multithreaded pure-Rust backend over the norm-trick distance
/// engine (`crate::linalg::distance`).
///
/// With `quantize` set (the `--quantize-build` flag), the within-cluster
/// kNN scan runs through the int8 screen-and-rerank path
/// (`crate::linalg::quant`, DESIGN.md §16); the exact f32 rerank makes the
/// output bitwise equal to the unquantized engine, so the flag is purely a
/// throughput knob. Assignment is unaffected.
#[derive(Default)]
pub struct NativeBackend {
    pub quantize: bool,
}

impl NativeBackend {
    /// Backend with the int8-screened kNN build enabled or not.
    pub fn quantized(quantize: bool) -> NativeBackend {
        NativeBackend { quantize }
    }
}

impl AnnBackend for NativeBackend {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)> {
        distance::assign_tiled(x, centroids, num_threads())
    }

    fn knn(&self, x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>) {
        self.knn_with_budget(x, k, num_threads())
    }

    fn knn_with_budget(&self, x: &Matrix, k: usize, threads: usize) -> (Vec<u32>, Vec<f32>) {
        if self.quantize {
            quant::self_knn_quantized(x, k, threads)
        } else {
            distance::self_knn_tiled(x, k, threads)
        }
    }
}

/// Pointwise assignment oracle: for each row, scan every centroid with the
/// engine's ordering contract (strictly-smaller distance wins, so the
/// smallest index wins ties; `total_cmp`, so NaN distances are skipped
/// instead of panicking).
pub fn assign_naive(x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)> {
    (0..x.rows)
        .map(|i| {
            let row = x.row(i);
            let mut best = (0u32, f32::INFINITY);
            for c in 0..centroids.rows {
                let dist = d2(row, centroids.row(c));
                if dist.total_cmp(&best.1) == std::cmp::Ordering::Less {
                    best = (c as u32, dist);
                }
            }
            best
        })
        .collect()
}

/// Pointwise kNN oracle: the pre-engine per-row scan with a bounded
/// sorted buffer (O(n·(d+k)) per row, no full sort, serial), updated to
/// the engine's `(d², index)` ordering contract so ties break identically.
/// Used by tests and as the naive side of `bench/index_build`.
pub fn knn_naive(x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let mut idx = vec![u32::MAX; n * k];
    let mut dd = vec![f32::INFINITY; n * k];
    if k == 0 {
        return (idx, dd);
    }
    let lex = |a: (f32, u32), b: (f32, u32)| match a.0.total_cmp(&b.0) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => a.1 < b.1,
    };
    for i in 0..n {
        // ascending (d², index) buffer of the k best so far
        let mut best: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
        let xi = x.row(i);
        for j in 0..n {
            if j == i {
                continue;
            }
            let cand = (d2(xi, x.row(j)), j as u32);
            if best.len() == k {
                if !lex(cand, *best.last().unwrap()) {
                    continue;
                }
                best.pop();
            }
            let pos = best.iter().position(|&b| lex(cand, b)).unwrap_or(best.len());
            best.insert(pos, cand);
        }
        for (slot, (dist, j)) in best.into_iter().enumerate() {
            idx[i * k + slot] = j;
            dd[i * k + slot] = dist;
        }
    }
    (idx, dd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn assign_picks_nearest() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 200, 8);
        let c = randm(&mut rng, 10, 8);
        let be = NativeBackend::default();
        for (i, (a, dist)) in be.assign(&x, &c).into_iter().enumerate() {
            let naive: Vec<f32> = (0..10).map(|j| d2(x.row(i), c.row(j))).collect();
            let best = naive
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap();
            // the tiled engine's norm-trick distances differ from the
            // pointwise ones by rounding, so a different winner is legal
            // only at a (near-)tie
            if a as usize != best.0 {
                assert!(
                    (naive[a as usize] - best.1).abs() < 1e-4,
                    "row {i}: picked {a} at {} but argmin {} at {}",
                    naive[a as usize],
                    best.0,
                    best.1
                );
            }
            assert!((dist - naive[a as usize]).abs() < 1e-4);
        }
    }

    #[test]
    fn knn_matches_bruteforce_sort() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 80, 6);
        let be = NativeBackend::default();
        let k = 9;
        let (idx, dd) = be.knn(&x, k);
        for i in 0..80 {
            let mut all: Vec<(f32, u32)> = (0..80)
                .filter(|&j| j != i)
                .map(|j| (d2(x.row(i), x.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.total_cmp(&b.0));
            for s in 0..k {
                assert!((dd[i * k + s] - all[s].0).abs() < 1e-4, "row {i} slot {s}");
            }
            // index set matches (ties may reorder)
            let got: std::collections::HashSet<u32> =
                idx[i * k..i * k + k].iter().copied().collect();
            let want: std::collections::HashSet<u32> =
                all[..k].iter().map(|p| p.1).collect();
            // allow differences only at equal distances
            for j in want.difference(&got) {
                let dj = all.iter().find(|p| p.1 == *j).unwrap().0;
                assert!(got.iter().any(|g| {
                    (dd[i * k..i * k + k][idx[i * k..i * k + k]
                        .iter()
                        .position(|v| v == g)
                        .unwrap()]
                        - dj)
                        .abs()
                        < 1e-5
                }));
            }
        }
    }

    #[test]
    fn knn_pads_small_clusters() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 3, 4);
        let be = NativeBackend::default();
        let (idx, dd) = be.knn(&x, 5);
        for i in 0..3 {
            assert_eq!(idx[i * 5 + 2], u32::MAX);
            assert!(dd[i * 5 + 2].is_infinite());
            assert_ne!(idx[i * 5], u32::MAX);
        }
    }

    /// The int8 screen is containment-guaranteed and the rerank is the
    /// exact f32 kernel, so the quantized backend must reproduce the
    /// default backend bit for bit (the `--quantize-build` contract).
    #[test]
    fn quantized_backend_is_bitwise_equal() {
        let mut rng = Rng::new(4);
        let x = randm(&mut rng, 150, 12);
        let exact = NativeBackend::default();
        let quant = NativeBackend::quantized(true);
        for k in [1, 7, 16] {
            let (ia, da) = exact.knn(&x, k);
            let (ib, db) = quant.knn(&x, k);
            assert_eq!(ia, ib, "k={k}: index mismatch");
            assert_eq!(
                da.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                db.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "k={k}: distance bits mismatch"
            );
        }
    }

    #[test]
    fn budgeted_knn_is_bitwise_equal() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 70, 5);
        let be = NativeBackend::default();
        let a = be.knn(&x, 6);
        let b = be.knn_with_budget(&x, 6, 1);
        assert_eq!(a, b);
    }
}
