//! Distance-computation backend abstraction.
//!
//! The heavy O(N·C·D) assignment and O(n_c²·D) within-cluster kNN work can
//! run either natively (tiled Rust loops, this file) or through the
//! AOT-compiled XLA artifacts (`crate::runtime::XlaAnnBackend`).  Both
//! implement [`AnnBackend`] and must agree numerically — the integration
//! tests cross-check them.

use crate::linalg::{d2, Matrix};
use crate::util::parallel::{num_threads, par_map};

/// Pluggable distance engine for the ANN index build.
pub trait AnnBackend {
    /// For each row of `x`, the nearest centroid and its squared distance.
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)>;

    /// Exact kNN among the rows of `x` (one cluster), excluding self.
    /// Returns `(idx, d2)` of shape n x k (row-major), local indices,
    /// `u32::MAX` / `INFINITY` padding when n <= k.
    fn knn(&self, x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>);
}

/// Tiled, multithreaded pure-Rust backend.
#[derive(Default)]
pub struct NativeBackend {}

impl AnnBackend for NativeBackend {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)> {
        let threads = num_threads();
        par_map(x.rows, threads, |i| {
            let row = x.row(i);
            let mut best = (0u32, f32::INFINITY);
            for c in 0..centroids.rows {
                let dist = d2(row, centroids.row(c));
                if dist < best.1 {
                    best = (c as u32, dist);
                }
            }
            best
        })
    }

    fn knn(&self, x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>) {
        let n = x.rows;
        let threads = num_threads();
        let rows: Vec<(Vec<u32>, Vec<f32>)> = par_map(n, threads, |i| {
            // bounded max-heap of the k closest
            let mut heap: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
            let xi = x.row(i);
            for j in 0..n {
                if j == i {
                    continue;
                }
                let dist = d2(xi, x.row(j));
                if heap.len() < k {
                    heap.push((dist, j as u32));
                    if heap.len() == k {
                        heap.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                    }
                } else if dist < heap[0].0 {
                    // replace current max, restore descending order
                    heap[0] = (dist, j as u32);
                    let mut p = 0;
                    while p + 1 < k && heap[p].0 < heap[p + 1].0 {
                        heap.swap(p, p + 1);
                        p += 1;
                    }
                }
            }
            heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut idx = vec![u32::MAX; k];
            let mut dd = vec![f32::INFINITY; k];
            for (slot, (dist, j)) in heap.into_iter().enumerate() {
                idx[slot] = j;
                dd[slot] = dist;
            }
            (idx, dd)
        });
        let mut idx = Vec::with_capacity(n * k);
        let mut dd = Vec::with_capacity(n * k);
        for (i, d_) in rows {
            idx.extend(i);
            dd.extend(d_);
        }
        (idx, dd)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn assign_picks_nearest() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 200, 8);
        let c = randm(&mut rng, 10, 8);
        let be = NativeBackend::default();
        for (i, (a, dist)) in be.assign(&x, &c).into_iter().enumerate() {
            let naive: Vec<f32> = (0..10).map(|j| d2(x.row(i), c.row(j))).collect();
            let best = naive
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            assert_eq!(a as usize, best.0);
            assert!((dist - naive[a as usize]).abs() < 1e-4);
        }
    }

    #[test]
    fn knn_matches_bruteforce_sort() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 80, 6);
        let be = NativeBackend::default();
        let k = 9;
        let (idx, dd) = be.knn(&x, k);
        for i in 0..80 {
            let mut all: Vec<(f32, u32)> = (0..80)
                .filter(|&j| j != i)
                .map(|j| (d2(x.row(i), x.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for s in 0..k {
                assert!((dd[i * k + s] - all[s].0).abs() < 1e-4, "row {i} slot {s}");
            }
            // index set matches (ties may reorder)
            let got: std::collections::HashSet<u32> =
                idx[i * k..i * k + k].iter().copied().collect();
            let want: std::collections::HashSet<u32> =
                all[..k].iter().map(|p| p.1).collect();
            // allow differences only at equal distances
            for j in want.difference(&got) {
                let dj = all.iter().find(|p| p.1 == *j).unwrap().0;
                assert!(got.iter().any(|g| {
                    (dd[i * k..i * k + k][idx[i * k..i * k + k]
                        .iter()
                        .position(|v| v == g)
                        .unwrap()]
                        - dj)
                        .abs()
                        < 1e-5
                }));
            }
        }
    }

    #[test]
    fn knn_pads_small_clusters() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 3, 4);
        let be = NativeBackend::default();
        let (idx, dd) = be.knn(&x, 5);
        for i in 0..3 {
            assert_eq!(idx[i * 5 + 2], u32::MAX);
            assert!(dd[i * 5 + 2].is_infinite());
            assert_ne!(idx[i * 5], u32::MAX);
        }
    }
}
