//! Runtime-dispatched 8-lane SIMD microkernels (DESIGN.md §16).
//!
//! Every hot f32 kernel in the crate funnels through this module: the
//! canonical [`dot`]/[`d2`] pair (re-wrapped by `linalg`), the distance
//! engine's 1×4 register block ([`dot4`]), and the gather engine's fused
//! mean-field / mean-repulsion passes ([`mean_field`]/[`mean_repulse`]).
//! Two implementations exist per kernel:
//!
//! * an **AVX2 path** built from `std::arch` intrinsics — deliberately
//!   FMA-free (`vmulps`/`vaddps`/`vsubps`/`vdivps` only), because every
//!   per-lane AVX2 op rounds exactly like its scalar f32 counterpart,
//!   while an FMA contraction would not;
//! * an **array-based scalar fallback** that keeps the same eight
//!   accumulators (`[f32; 8]`, lane `l` sums elements `j*8 + l`) and
//!   reduces them with the same fixed tree ([`reduce8`]), followed by
//!   the identical sequential tail.
//!
//! Both paths therefore perform bit-identical IEEE-754 operations in the
//! same association order, so SIMD-on vs SIMD-off is **bitwise equal**
//! on every input shape and the engine's (d², index) tie contract and
//! thread-invariance gates carry over unchanged. (NaN *payload* bits are
//! propagated but not part of the contract — the compiler may commute
//! add/mul operands, which only matters when two distinct NaN payloads
//! meet.)
//!
//! Dispatch is resolved once per process: `NOMAD_SIMD=scalar|off|0`
//! forces the fallback, otherwise AVX2 is used when the CPU reports it
//! ([`simd_active`] tells which path won). The `*_scalar` kernels stay
//! `pub` so tests and benches can compare the dispatched path against
//! the fallback in-process, whatever the host CPU.
//!
//! This is the only module allowed to touch `std::arch` — the xtask
//! `simd_arch` lint rule (DESIGN.md §14) rejects raw intrinsics
//! anywhere else in the tree.

#[cfg(target_arch = "x86_64")]
use std::sync::atomic::{AtomicU8, Ordering};

/// Accumulator lanes per block; one AVX2 `__m256` register of f32.
pub const LANES: usize = 8;

#[cfg(target_arch = "x86_64")]
const MODE_UNRESOLVED: u8 = 0;
#[cfg(target_arch = "x86_64")]
const MODE_SCALAR: u8 = 1;
#[cfg(target_arch = "x86_64")]
const MODE_AVX2: u8 = 2;

/// Process-wide dispatch decision; 0 until first use, then sticky.
#[cfg(target_arch = "x86_64")]
static MODE: AtomicU8 = AtomicU8::new(MODE_UNRESOLVED);

#[cfg(target_arch = "x86_64")]
#[inline(always)]
fn mode() -> u8 {
    let m = MODE.load(Ordering::Relaxed);
    if m == MODE_UNRESOLVED {
        resolve_mode()
    } else {
        m
    }
}

/// One-time dispatch resolution: honour the `NOMAD_SIMD` kill switch,
/// then probe the CPU. Racing threads compute the same value, so the
/// relaxed store is benign.
#[cfg(target_arch = "x86_64")]
#[cold]
fn resolve_mode() -> u8 {
    let forced_scalar = matches!(
        std::env::var("NOMAD_SIMD").map(|v| v.to_ascii_lowercase()).as_deref(),
        Ok("scalar") | Ok("off") | Ok("0")
    );
    let m = if !forced_scalar && std::is_x86_feature_detected!("avx2") {
        MODE_AVX2
    } else {
        MODE_SCALAR
    };
    MODE.store(m, Ordering::Relaxed);
    m
}

/// True when the AVX2 path is active for this process (false on
/// non-x86_64 builds, CPUs without AVX2, or under `NOMAD_SIMD=scalar`).
pub fn simd_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        mode() == MODE_AVX2
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The fixed reduction tree shared by both paths: pairwise within each
/// 128-bit half, then across halves — the order a hardware horizontal
/// reduction would use, spelled out so the scalar fallback matches the
/// AVX2 path bit for bit.
#[inline(always)]
fn reduce8(s: [f32; 8]) -> f32 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

// ---- scalar fallbacks (the semantic reference) ---------------------------

/// Scalar fallback for [`dot`]: eight accumulators, fixed reduction
/// tree, sequential tail.
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let blocks = n - n % LANES;
    let mut s = [0.0f32; LANES];
    let mut j = 0;
    while j < blocks {
        for l in 0..LANES {
            s[l] += a[j + l] * b[j + l];
        }
        j += LANES;
    }
    let mut acc = reduce8(s);
    while j < n {
        acc += a[j] * b[j];
        j += 1;
    }
    acc
}

/// Scalar fallback for [`d2`]: per-lane `(a-b)²` accumulation with the
/// same lane discipline as [`dot_scalar`].
pub fn d2_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let blocks = n - n % LANES;
    let mut s = [0.0f32; LANES];
    let mut j = 0;
    while j < blocks {
        for l in 0..LANES {
            let d = a[j + l] - b[j + l];
            s[l] += d * d;
        }
        j += LANES;
    }
    let mut acc = reduce8(s);
    while j < n {
        let d = a[j] - b[j];
        acc += d * d;
        j += 1;
    }
    acc
}

/// Scalar fallback for [`dot4`]: one shared `a` load against four
/// corpus rows — the distance engine's 1×4 register block, each lane
/// set identical to a standalone [`dot_scalar`] call.
pub fn dot4_scalar(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    let n = a.len();
    let blocks = n - n % LANES;
    let mut s = [[0.0f32; LANES]; 4];
    let mut j = 0;
    while j < blocks {
        for l in 0..LANES {
            let av = a[j + l];
            s[0][l] += av * b0[j + l];
            s[1][l] += av * b1[j + l];
            s[2][l] += av * b2[j + l];
            s[3][l] += av * b3[j + l];
        }
        j += LANES;
    }
    let mut out = [reduce8(s[0]), reduce8(s[1]), reduce8(s[2]), reduce8(s[3])];
    while j < n {
        let av = a[j];
        out[0] += av * b0[j];
        out[1] += av * b1[j];
        out[2] += av * b2[j];
        out[3] += av * b3[j];
        j += 1;
    }
    out
}

/// Scalar fallback for [`mean_field`]: the gather engine's fused
/// attractive mean pass — Cauchy kernel `q = 1/((1 + dx²) + dy²)`
/// against every mean point, caching `q`/`dx`/`dy` for the repulsion
/// pass, returning the weighted sum `Σ w·q`.
pub fn mean_field_scalar(
    px: f32,
    py: f32,
    xs: &[f32],
    ys: &[f32],
    ws: &[f32],
    q: &mut [f32],
    dx: &mut [f32],
    dy: &mut [f32],
) -> f32 {
    let r = ws.len();
    let blocks = r - r % LANES;
    let mut s = [0.0f32; LANES];
    let mut i = 0;
    while i < blocks {
        for l in 0..LANES {
            let dix = px - xs[i + l];
            let diy = py - ys[i + l];
            let qi = 1.0 / ((1.0 + dix * dix) + diy * diy);
            q[i + l] = qi;
            dx[i + l] = dix;
            dy[i + l] = diy;
            s[l] += ws[i + l] * qi;
        }
        i += LANES;
    }
    let mut acc = reduce8(s);
    while i < r {
        let dix = px - xs[i];
        let diy = py - ys[i];
        let qi = 1.0 / ((1.0 + dix * dix) + diy * diy);
        q[i] = qi;
        dx[i] = dix;
        dy[i] = diy;
        acc += ws[i] * qi;
        i += 1;
    }
    acc
}

/// Scalar fallback for [`mean_repulse`]: per-mean repulsive coefficient
/// `c = (w·q)·q` applied to the cached displacement, accumulated into
/// separate x/y lane sets.
pub fn mean_repulse_scalar(ws: &[f32], q: &[f32], dx: &[f32], dy: &[f32]) -> (f32, f32) {
    let r = ws.len();
    let blocks = r - r % LANES;
    let mut gx = [0.0f32; LANES];
    let mut gy = [0.0f32; LANES];
    let mut i = 0;
    while i < blocks {
        for l in 0..LANES {
            let c = (ws[i + l] * q[i + l]) * q[i + l];
            gx[l] += c * dx[i + l];
            gy[l] += c * dy[i + l];
        }
        i += LANES;
    }
    let (mut ax, mut ay) = (reduce8(gx), reduce8(gy));
    while i < r {
        let c = (ws[i] * q[i]) * q[i];
        ax += c * dx[i];
        ay += c * dy[i];
        i += 1;
    }
    (ax, ay)
}

// ---- AVX2 mirrors --------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 mirrors of the scalar fallbacks. Every function performs
    //! exactly the per-lane operation sequence of its fallback (no FMA
    //! contraction, no reciprocal approximations), stores the lane
    //! accumulators and reduces them with the same `reduce8` tree, then
    //! runs the identical sequential tail — so results are bitwise
    //! equal to the fallback on every input shape.

    use super::{reduce8, LANES};
    use std::arch::x86_64::*;

    /// Unaligned 8-lane load of `p[i..i + 8]`.
    ///
    /// # Safety
    /// `i + 8 <= p.len()` (debug-asserted) and the CPU must support
    /// AVX2 — callers are themselves `target_feature(avx2)` functions
    /// reached only through the module's dispatch gate.
    // SAFETY: bounds are the caller's contract (debug-asserted below);
    // the avx2 feature is guaranteed by the resolve_mode dispatch gate.
    #[target_feature(enable = "avx2")]
    unsafe fn load8(p: &[f32], i: usize) -> __m256 {
        debug_assert!(i + LANES <= p.len());
        _mm256_loadu_ps(p.as_ptr().add(i))
    }

    /// Unaligned 8-lane store to `p[i..i + 8]`.
    ///
    /// # Safety
    /// `i + 8 <= p.len()` (debug-asserted) and the CPU must support
    /// AVX2 (same contract as [`load8`]).
    // SAFETY: bounds are the caller's contract (debug-asserted below);
    // the avx2 feature is guaranteed by the resolve_mode dispatch gate.
    #[target_feature(enable = "avx2")]
    unsafe fn store8(p: &mut [f32], i: usize, v: __m256) {
        debug_assert!(i + LANES <= p.len());
        _mm256_storeu_ps(p.as_mut_ptr().add(i), v);
    }

    /// Horizontal reduction through the shared fixed tree: spill the
    /// lanes and reuse the scalar `reduce8` so both paths agree bit for
    /// bit.
    ///
    /// # Safety
    /// CPU must support AVX2 (same contract as [`load8`]).
    // SAFETY: writes 8 lanes into a stack array of exactly 8 f32s; the
    // avx2 feature is guaranteed by the resolve_mode dispatch gate.
    #[target_feature(enable = "avx2")]
    unsafe fn reduce(v: __m256) -> f32 {
        let mut lanes = [0.0f32; LANES];
        _mm256_storeu_ps(lanes.as_mut_ptr(), v);
        reduce8(lanes)
    }

    /// AVX2 mirror of [`super::dot_scalar`].
    ///
    /// # Safety
    /// CPU must support AVX2; `a.len() == b.len()`.
    // SAFETY: all lane loads stay inside a/b (blocks <= len, asserted
    // in load8); avx2 is guaranteed by the resolve_mode dispatch gate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j < blocks {
            acc = _mm256_add_ps(acc, _mm256_mul_ps(load8(a, j), load8(b, j)));
            j += LANES;
        }
        let mut t = reduce(acc);
        while j < n {
            t += a[j] * b[j];
            j += 1;
        }
        t
    }

    /// AVX2 mirror of [`super::d2_scalar`].
    ///
    /// # Safety
    /// CPU must support AVX2; `a.len() == b.len()`.
    // SAFETY: all lane loads stay inside a/b (blocks <= len, asserted
    // in load8); avx2 is guaranteed by the resolve_mode dispatch gate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn d2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let blocks = n - n % LANES;
        let mut acc = _mm256_setzero_ps();
        let mut j = 0;
        while j < blocks {
            let vd = _mm256_sub_ps(load8(a, j), load8(b, j));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(vd, vd));
            j += LANES;
        }
        let mut t = reduce(acc);
        while j < n {
            let d = a[j] - b[j];
            t += d * d;
            j += 1;
        }
        t
    }

    /// AVX2 mirror of [`super::dot4_scalar`].
    ///
    /// # Safety
    /// CPU must support AVX2; all five slices must have equal length.
    // SAFETY: all lane loads stay inside the five equal-length slices
    // (asserted in load8); avx2 is guaranteed by the dispatch gate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4(
        a: &[f32],
        b0: &[f32],
        b1: &[f32],
        b2: &[f32],
        b3: &[f32],
    ) -> [f32; 4] {
        let n = a.len();
        let blocks = n - n % LANES;
        let mut s0 = _mm256_setzero_ps();
        let mut s1 = _mm256_setzero_ps();
        let mut s2 = _mm256_setzero_ps();
        let mut s3 = _mm256_setzero_ps();
        let mut j = 0;
        while j < blocks {
            let va = load8(a, j);
            s0 = _mm256_add_ps(s0, _mm256_mul_ps(va, load8(b0, j)));
            s1 = _mm256_add_ps(s1, _mm256_mul_ps(va, load8(b1, j)));
            s2 = _mm256_add_ps(s2, _mm256_mul_ps(va, load8(b2, j)));
            s3 = _mm256_add_ps(s3, _mm256_mul_ps(va, load8(b3, j)));
            j += LANES;
        }
        let mut out = [reduce(s0), reduce(s1), reduce(s2), reduce(s3)];
        while j < n {
            let av = a[j];
            out[0] += av * b0[j];
            out[1] += av * b1[j];
            out[2] += av * b2[j];
            out[3] += av * b3[j];
            j += 1;
        }
        out
    }

    /// AVX2 mirror of [`super::mean_field_scalar`].
    ///
    /// # Safety
    /// CPU must support AVX2; all six slices must have equal length.
    // SAFETY: lane loads/stores stay inside the equal-length slices
    // (asserted in load8/store8); avx2 is guaranteed by the gate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mean_field(
        px: f32,
        py: f32,
        xs: &[f32],
        ys: &[f32],
        ws: &[f32],
        q: &mut [f32],
        dx: &mut [f32],
        dy: &mut [f32],
    ) -> f32 {
        let r = ws.len();
        let blocks = r - r % LANES;
        let vpx = _mm256_set1_ps(px);
        let vpy = _mm256_set1_ps(py);
        let one = _mm256_set1_ps(1.0);
        let mut acc = _mm256_setzero_ps();
        let mut i = 0;
        while i < blocks {
            let vdx = _mm256_sub_ps(vpx, load8(xs, i));
            let vdy = _mm256_sub_ps(vpy, load8(ys, i));
            let den = _mm256_add_ps(
                _mm256_add_ps(one, _mm256_mul_ps(vdx, vdx)),
                _mm256_mul_ps(vdy, vdy),
            );
            let vq = _mm256_div_ps(one, den);
            store8(q, i, vq);
            store8(dx, i, vdx);
            store8(dy, i, vdy);
            acc = _mm256_add_ps(acc, _mm256_mul_ps(load8(ws, i), vq));
            i += LANES;
        }
        let mut t = reduce(acc);
        while i < r {
            let dix = px - xs[i];
            let diy = py - ys[i];
            let qi = 1.0 / ((1.0 + dix * dix) + diy * diy);
            q[i] = qi;
            dx[i] = dix;
            dy[i] = diy;
            t += ws[i] * qi;
            i += 1;
        }
        t
    }

    /// AVX2 mirror of [`super::mean_repulse_scalar`].
    ///
    /// # Safety
    /// CPU must support AVX2; all four slices must have equal length.
    // SAFETY: all lane loads stay inside the four equal-length slices
    // (asserted in load8); avx2 is guaranteed by the dispatch gate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mean_repulse(ws: &[f32], q: &[f32], dx: &[f32], dy: &[f32]) -> (f32, f32) {
        let r = ws.len();
        let blocks = r - r % LANES;
        let mut gx = _mm256_setzero_ps();
        let mut gy = _mm256_setzero_ps();
        let mut i = 0;
        while i < blocks {
            let c = _mm256_mul_ps(_mm256_mul_ps(load8(ws, i), load8(q, i)), load8(q, i));
            gx = _mm256_add_ps(gx, _mm256_mul_ps(c, load8(dx, i)));
            gy = _mm256_add_ps(gy, _mm256_mul_ps(c, load8(dy, i)));
            i += LANES;
        }
        let (mut ax, mut ay) = (reduce(gx), reduce(gy));
        while i < r {
            let c = (ws[i] * q[i]) * q[i];
            ax += c * dx[i];
            ay += c * dy[i];
            i += 1;
        }
        (ax, ay)
    }
}

// ---- dispatched entry points ---------------------------------------------

/// Canonical 8-lane dot product; runtime-dispatched, bitwise identical
/// across the AVX2 and scalar paths.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot: length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true, so the
            // required target feature is present; lengths match.
            return unsafe { avx2::dot(a, b) };
        }
    }
    dot_scalar(a, b)
}

/// Canonical 8-lane squared Euclidean distance; runtime-dispatched,
/// bitwise identical across the AVX2 and scalar paths.
#[inline]
pub fn d2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "d2: length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true, so the
            // required target feature is present; lengths match.
            return unsafe { avx2::d2(a, b) };
        }
    }
    d2_scalar(a, b)
}

/// 1×4 register block: one query row against four corpus rows. Lane `t`
/// of the result is bitwise equal to `dot(a, b_t)`.
#[inline]
pub fn dot4(a: &[f32], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) -> [f32; 4] {
    debug_assert!(
        a.len() == b0.len() && a.len() == b1.len() && a.len() == b2.len() && a.len() == b3.len(),
        "dot4: length mismatch"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true, so the
            // required target feature is present; lengths match.
            return unsafe { avx2::dot4(a, b0, b1, b2, b3) };
        }
    }
    dot4_scalar(a, b0, b1, b2, b3)
}

/// Fused attractive mean-field pass of the gather engine (DESIGN.md §9):
/// caches `q`/`dx`/`dy` per mean point and returns `Σ w·q`.
/// Runtime-dispatched, bitwise identical across paths.
#[inline]
pub fn mean_field(
    px: f32,
    py: f32,
    xs: &[f32],
    ys: &[f32],
    ws: &[f32],
    q: &mut [f32],
    dx: &mut [f32],
    dy: &mut [f32],
) -> f32 {
    let r = ws.len();
    debug_assert!(
        xs.len() == r && ys.len() == r && q.len() == r && dx.len() == r && dy.len() == r,
        "mean_field: length mismatch"
    );
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true, so the
            // required target feature is present; lengths match.
            return unsafe { avx2::mean_field(px, py, xs, ys, ws, q, dx, dy) };
        }
    }
    mean_field_scalar(px, py, xs, ys, ws, q, dx, dy)
}

/// Repulsive mean accumulation over the buffers cached by
/// [`mean_field`]. Runtime-dispatched, bitwise identical across paths.
#[inline]
pub fn mean_repulse(ws: &[f32], q: &[f32], dx: &[f32], dy: &[f32]) -> (f32, f32) {
    let r = ws.len();
    debug_assert!(q.len() == r && dx.len() == r && dy.len() == r, "mean_repulse: length mismatch");
    #[cfg(target_arch = "x86_64")]
    {
        if mode() == MODE_AVX2 {
            // SAFETY: MODE_AVX2 is only ever stored after
            // `is_x86_feature_detected!("avx2")` returned true, so the
            // required target feature is present; lengths match.
            return unsafe { avx2::mean_repulse(ws, q, dx, dy) };
        }
    }
    mean_repulse_scalar(ws, q, dx, dy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Bitwise equality with NaN-payload tolerance: NaN payload bits are
    /// propagated but not contractual (see module doc).
    fn bits_eq(x: f32, y: f32) -> bool {
        x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan())
    }

    fn assert_bits_eq(x: f32, y: f32, ctx: &str) {
        assert!(bits_eq(x, y), "{ctx}: {x:?} ({:#x}) vs {y:?} ({:#x})", x.to_bits(), y.to_bits());
    }

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Every remainder class mod 8 (d = 0..=17): dispatched kernels are
    /// bitwise equal to the scalar fallbacks, and dot4 lane `t` equals a
    /// standalone dot against row `t`.
    #[test]
    fn tail_sweep_dispatch_matches_scalar() {
        let mut rng = Rng::new(42);
        for d in 0..=17usize {
            let a = randv(d, &mut rng);
            let bs: Vec<Vec<f32>> = (0..4).map(|_| randv(d, &mut rng)).collect();
            let b = &bs[0];
            assert_bits_eq(dot(&a, b), dot_scalar(&a, b), &format!("dot d={d}"));
            assert_bits_eq(d2(&a, b), d2_scalar(&a, b), &format!("d2 d={d}"));
            let v = dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            let w = dot4_scalar(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for t in 0..4 {
                assert_bits_eq(v[t], w[t], &format!("dot4 lane {t} d={d}"));
                assert_bits_eq(v[t], dot(&a, &bs[t]), &format!("dot4 vs dot lane {t} d={d}"));
            }
        }
    }

    /// NaN, ±inf and −0.0 propagate identically through both paths, at
    /// head, lane-interior and tail positions of every alignment class.
    #[test]
    fn specials_propagate_bitwise() {
        let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0f32];
        let mut rng = Rng::new(7);
        for &d in &[1usize, 3, 7, 8, 9, 15, 16, 17] {
            for &sv in &specials {
                for pos in [0, d / 2, d - 1] {
                    let mut a = randv(d, &mut rng);
                    a[pos] = sv;
                    let b = randv(d, &mut rng);
                    let ctx = format!("special {sv:?} at {pos} d={d}");
                    assert_bits_eq(dot(&a, &b), dot_scalar(&a, &b), &ctx);
                    assert_bits_eq(d2(&a, &b), d2_scalar(&a, &b), &ctx);
                    assert_bits_eq(dot(&b, &a), dot_scalar(&b, &a), &ctx);
                }
            }
        }
        // empty input reduces zeroed accumulators to +0.0 on both paths
        assert_eq!(dot(&[], &[]).to_bits(), 0.0f32.to_bits());
        assert_eq!(d2(&[], &[]).to_bits(), 0.0f32.to_bits());
        // −0.0·+0.0 products leave the +0.0 accumulator positive
        let nz = [-0.0f32; 5];
        let pz = [0.0f32; 5];
        assert_eq!(dot(&nz, &pz).to_bits(), 0.0f32.to_bits());
    }

    /// Random ragged shapes: the mean-pass kernels agree bitwise between
    /// the dispatched and fallback paths, including the cached q/dx/dy
    /// side buffers.
    #[test]
    fn mean_kernels_dispatch_invariant_on_ragged_shapes() {
        let mut rng = Rng::new(11);
        for trial in 0..60 {
            let r = rng.below(66);
            let xs = randv(r, &mut rng);
            let ys = randv(r, &mut rng);
            let mut ws = randv(r, &mut rng);
            if r > 0 && trial % 5 == 0 {
                ws[rng.below(r)] = f32::NAN;
            }
            let (mut q1, mut dx1, mut dy1) = (vec![0.0; r], vec![0.0; r], vec![0.0; r]);
            let (mut q2, mut dx2, mut dy2) = (vec![0.0; r], vec![0.0; r], vec![0.0; r]);
            let px = rng.normal();
            let py = rng.normal();
            let f1 = mean_field(px, py, &xs, &ys, &ws, &mut q1, &mut dx1, &mut dy1);
            let f2 = mean_field_scalar(px, py, &xs, &ys, &ws, &mut q2, &mut dx2, &mut dy2);
            assert_bits_eq(f1, f2, &format!("mean_field r={r}"));
            for i in 0..r {
                assert_bits_eq(q1[i], q2[i], &format!("q[{i}] r={r}"));
                assert_bits_eq(dx1[i], dx2[i], &format!("dx[{i}] r={r}"));
                assert_bits_eq(dy1[i], dy2[i], &format!("dy[{i}] r={r}"));
            }
            let (gx1, gy1) = mean_repulse(&ws, &q1, &dx1, &dy1);
            let (gx2, gy2) = mean_repulse_scalar(&ws, &q2, &dx2, &dy2);
            assert_bits_eq(gx1, gx2, &format!("mean_repulse gx r={r}"));
            assert_bits_eq(gy1, gy2, &format!("mean_repulse gy r={r}"));
        }
    }

    /// Random ragged shapes for the dot-family kernels, with occasional
    /// specials mixed in.
    #[test]
    fn dot_kernels_dispatch_invariant_on_ragged_shapes() {
        let mut rng = Rng::new(13);
        for trial in 0..120 {
            let d = rng.below(66);
            let mut a = randv(d, &mut rng);
            let b = randv(d, &mut rng);
            if d > 0 && trial % 7 == 0 {
                a[rng.below(d)] = [f32::NAN, f32::INFINITY, -0.0][trial % 3];
            }
            assert_bits_eq(dot(&a, &b), dot_scalar(&a, &b), &format!("dot d={d}"));
            assert_bits_eq(d2(&a, &b), d2_scalar(&a, &b), &format!("d2 d={d}"));
        }
    }

    /// The 8-lane kernels agree with a sequential f64 reference to
    /// f32-roundoff accuracy (association changes bits, not magnitude).
    #[test]
    fn kernels_match_f64_reference() {
        let mut rng = Rng::new(17);
        for &d in &[16usize, 123, 512] {
            let a = randv(d, &mut rng);
            let b = randv(d, &mut rng);
            let dref: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let scale: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 * y as f64).abs()).sum();
            assert!(
                (dot(&a, &b) as f64 - dref).abs() <= 1e-5 * scale.max(1.0),
                "dot d={d}: {} vs {dref}",
                dot(&a, &b)
            );
            let d2ref: f64 = a.iter().zip(&b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum();
            assert!(
                (d2(&a, &b) as f64 - d2ref).abs() <= 1e-5 * d2ref.max(1.0),
                "d2 d={d}: {} vs {d2ref}",
                d2(&a, &b)
            );
        }
    }
}
