//! Random-hyperplane locality-sensitive hashing.
//!
//! NOMAD's ANN index seeds its K-Means clustering with an LSH (paper §3.2:
//! "We initialize our K-Means clustering using a locally sensitive hash").
//! Points are hashed by the sign pattern of `bits` random projections;
//! K-Means centroids are then initialized as the means of the largest hash
//! buckets, which spreads them across the data without a distance pass.

use crate::linalg::Matrix;
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// A random-hyperplane hasher producing `bits`-bit signatures.
pub struct HyperplaneLsh {
    pub bits: usize,
    planes: Matrix, // bits x d
}

impl HyperplaneLsh {
    pub fn new(dim: usize, bits: usize, rng: &mut Rng) -> Self {
        assert!(bits <= 64, "at most 64 hash bits");
        let mut planes = Matrix::zeros(bits, dim);
        for v in planes.data.iter_mut() {
            *v = rng.normal();
        }
        HyperplaneLsh { bits, planes }
    }

    /// Hash one vector to its sign signature.
    pub fn hash(&self, x: &[f32]) -> u64 {
        let mut h = 0u64;
        for b in 0..self.bits {
            if super::dot(self.planes.row(b), x) >= 0.0 {
                h |= 1 << b;
            }
        }
        h
    }

    /// Hash every row of `x`.
    pub fn hash_all(&self, x: &Matrix) -> Vec<u64> {
        let threads = crate::util::parallel::num_threads();
        crate::util::parallel::par_map(x.rows, threads, |r| self.hash(x.row(r)))
    }
}

/// Seed `k` centroids from LSH buckets: take the `k` most populated buckets'
/// means; if fewer buckets exist, fill the remainder with random points.
pub fn lsh_seed_centroids(x: &Matrix, k: usize, rng: &mut Rng) -> Matrix {
    let bits = (k.max(2) as f32).log2().ceil() as usize + 3;
    let lsh = HyperplaneLsh::new(x.cols, bits.min(24), rng);
    let hashes = lsh.hash_all(x);

    let mut buckets: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, h) in hashes.iter().enumerate() {
        buckets.entry(*h).or_default().push(i);
    }
    let mut by_size: Vec<(u64, Vec<usize>)> = buckets.into_iter().collect();
    by_size.sort_by(|a, b| b.1.len().cmp(&a.1.len()).then(a.0.cmp(&b.0)));

    let mut centroids = Matrix::zeros(k, x.cols);
    let mut filled = 0;
    for (_, members) in by_size.into_iter().take(k) {
        let c = centroids.row_mut(filled);
        for &m in &members {
            for (cv, xv) in c.iter_mut().zip(x.row(m)) {
                *cv += *xv;
            }
        }
        let inv = 1.0 / members.len() as f32;
        for cv in c.iter_mut() {
            *cv *= inv;
        }
        filled += 1;
    }
    // fill any remainder with random data points
    while filled < k {
        let r = rng.below(x.rows);
        centroids.row_mut(filled).copy_from_slice(x.row(r));
        filled += 1;
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn identical_points_share_hash() {
        let mut rng = Rng::new(0);
        let lsh = HyperplaneLsh::new(16, 12, &mut rng);
        let x = [0.3f32; 16];
        assert_eq!(lsh.hash(&x), lsh.hash(&x));
    }

    #[test]
    fn nearby_points_collide_more_than_far_points() {
        let mut rng = Rng::new(1);
        let d = 32;
        let lsh = HyperplaneLsh::new(d, 16, &mut rng);
        let mut same = 0;
        let mut diff = 0;
        for _ in 0..300 {
            let a: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            let near: Vec<f32> = a.iter().map(|v| v + 0.01 * rng.normal()).collect();
            let far: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
            same += (lsh.hash(&a) ^ lsh.hash(&near)).count_ones();
            diff += (lsh.hash(&a) ^ lsh.hash(&far)).count_ones();
        }
        assert!(same < diff / 4, "near bit-diff {same} vs far {diff}");
    }

    #[test]
    fn seed_centroids_shape_and_coverage() {
        let mut rng = Rng::new(2);
        // two well-separated blobs: seeds must land near both
        let n = 400;
        let mut m = toy(&mut rng, n, 8);
        for r in 0..n / 2 {
            m.row_mut(r)[0] += 50.0;
        }
        let c = lsh_seed_centroids(&m, 4, &mut rng);
        assert_eq!(c.rows, 4);
        assert_eq!(c.cols, 8);
        let near_a = (0..4).any(|i| c.row(i)[0] > 25.0);
        let near_b = (0..4).any(|i| c.row(i)[0] < 25.0);
        assert!(near_a && near_b, "seeds must cover both blobs");
    }
}
