//! Int8 row quantization for the ANN build's candidate scan
//! (DESIGN.md §16, `--quantize-build`).
//!
//! Each corpus row is affinely quantized on its own scale:
//! `x̂_t = offset + scale · code_t` with `code_t ∈ [-127, 127]`, chosen
//! so the row's finite range maps onto the full code range. The
//! quantized candidate scan computes, per pair, a **conservative
//! interval** `[lb, ub]` around the exact engine's clamped norm-trick
//! distance using only int8 dot products (i32 accumulate) and per-row
//! f64 stats:
//!
//! * the reconstructed distance expands to
//!   `d̂² = d·Δo² + 2Δo(s_iΣa − s_jΣb) + s_i²Σa² + s_j²Σb² − 2s_is_jΣab`
//!   where `Σab` is the only per-pair term — one int8 dot;
//! * the triangle inequality bounds the true distance by
//!   `d̂ ± (‖x_i − x̂_i‖ + ‖x_j − x̂_j‖)` (residual norms precomputed
//!   exactly in f64 at quantization time);
//! * an additive slack covers the exact engine's own f32 rounding, so
//!   the interval brackets the *computed* distance, not just the true
//!   one.
//!
//! Per query, candidates whose `lb` exceeds the k-th smallest `ub`
//! cannot reach the top-k and are skipped; every survivor is then
//! **reranked with the exact f32 kernel, reproducing the engine's
//! expression bit for bit in the same ascending-j order**. Survivors
//! provably contain the true top-k (any candidate beaten by k upper
//! bounds loses to k real distances), so the final kNN output is
//! **bitwise equal** to [`self_knn_tiled`] — quantization changes build
//! speed, never results. All bound comparisons keep candidates on NaN,
//! degrading NaN-poisoned rows to a full exact scan rather than risking
//! a divergent prune.

use super::distance::{clamp0, row_sq_norms, self_knn_tiled, TopK, TILE_Q};
use super::{dot, Matrix};
use crate::util::parallel::par_for_chunks;

/// A row-quantized corpus: int8 codes plus the per-row f64 stats the
/// bound computation needs (scale, offset, residual norm, Σcode,
/// Σcode²).
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    codes: Vec<i8>,
    scale: Vec<f64>,
    offset: Vec<f64>,
    /// Exact reconstruction residual ‖x − x̂‖ per row (NaN when the row
    /// holds non-finite values — such rows are never pruned).
    err: Vec<f64>,
    sum: Vec<i64>,
    sum_sq: Vec<i64>,
}

impl QuantizedMatrix {
    /// Quantize every row of `x` at its own scale/offset. Degenerate
    /// rows (empty, constant, all-NaN) keep code 0 everywhere and
    /// reconstruct to the constant `offset`; non-finite values poison
    /// the row's residual to NaN, which the scan treats as "never
    /// prune".
    pub fn quantize(x: &Matrix) -> QuantizedMatrix {
        let (rows, cols) = (x.rows, x.cols);
        assert!(cols <= 100_000, "quantized scan: i32 code dot caps dims at 100k");
        let mut codes = vec![0i8; rows * cols];
        let mut scale = vec![1.0f64; rows];
        let mut offset = vec![0.0f64; rows];
        let mut err = vec![0.0f64; rows];
        let mut sum = vec![0i64; rows];
        let mut sum_sq = vec![0i64; rows];
        for r in 0..rows {
            let row = x.row(r);
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for &v in row {
                if v.is_nan() {
                    continue;
                }
                let v = v as f64;
                if v < lo {
                    lo = v;
                }
                if v > hi {
                    hi = v;
                }
            }
            let (s, o) = if lo > hi {
                (1.0, 0.0) // empty or all-NaN row
            } else if hi == lo {
                (1.0, lo) // constant row: codes stay 0, exact reconstruction
            } else {
                ((hi - lo) / 254.0, (lo + hi) / 2.0)
            };
            scale[r] = s;
            offset[r] = o;
            let cr = &mut codes[r * cols..(r + 1) * cols];
            let mut e2 = 0.0f64;
            let (mut cs, mut cs2) = (0i64, 0i64);
            for (t, &v) in row.iter().enumerate() {
                // NaN propagates through clamp and saturates to 0 in the
                // cast; ±inf saturates to ±127 — either way the residual
                // goes NaN and disables pruning for this row
                let c = ((v as f64 - o) / s).round().clamp(-127.0, 127.0) as i8;
                cr[t] = c;
                cs += c as i64;
                cs2 += (c as i64) * (c as i64);
                let resid = v as f64 - (o + s * c as f64);
                e2 += resid * resid;
            }
            sum[r] = cs;
            sum_sq[r] = cs2;
            err[r] = e2.sqrt();
        }
        QuantizedMatrix { rows, cols, codes, scale, offset, err, sum, sum_sq }
    }

    /// Conservative f64 interval around the exact engine's clamped
    /// norm-trick d²(i, j), from the codes alone (one int8 dot). The
    /// slack term covers the engine's f32 rounding — `O(d·ε·(nᵢ+nⱼ))`,
    /// overshot by >20× — so widening only costs rerank work, never
    /// correctness. NaN stats yield NaN bounds, which the scan keeps.
    fn bound_pair(&self, i: usize, j: usize, norms: &[f32]) -> (f64, f64) {
        let d = self.cols as f64;
        let ni = norms[i] as f64;
        let nj = norms[j] as f64;
        let slack = (ni + nj) * (1e-4 + 1e-6 * d) + 1e-6;
        let si = self.scale[i];
        let sj = self.scale[j];
        let doff = self.offset[i] - self.offset[j];
        let a = &self.codes[i * self.cols..(i + 1) * self.cols];
        let b = &self.codes[j * self.cols..(j + 1) * self.cols];
        let mut cd = 0i32;
        for (ca, cb) in a.iter().zip(b) {
            cd += (*ca as i32) * (*cb as i32);
        }
        let dhat2 = d * doff * doff
            + 2.0 * doff * (si * self.sum[i] as f64 - sj * self.sum[j] as f64)
            + si * si * self.sum_sq[i] as f64
            + sj * sj * self.sum_sq[j] as f64
            - 2.0 * si * sj * cd as f64;
        // not f64::max — that would absorb a NaN d̂² into 0.0
        let dhat = if dhat2 > 0.0 { dhat2.sqrt() } else { 0.0 };
        let e = self.err[i] + self.err[j];
        let ub = (dhat + e) * (dhat + e) * (1.0 + 1e-9) + slack;
        let lo = dhat - e;
        let lb = if lo > 0.0 { lo * lo * (1.0 - 1e-9) - slack } else { f64::NEG_INFINITY };
        (lb, ub)
    }
}

/// k-th smallest (1-based) upper bound under `total_cmp`; +∞ when there
/// are at most k candidates (nothing can be pruned) or when the cut
/// lands on NaN (NaN bounds must never prune anyone).
fn kth_smallest(scratch: &mut [f64], k: usize) -> f64 {
    if scratch.len() <= k {
        return f64::INFINITY;
    }
    let (_, kth, _) = scratch.select_nth_unstable_by(k - 1, |a, b| a.total_cmp(b));
    if kth.is_nan() {
        f64::INFINITY
    } else {
        *kth
    }
}

/// Exact kNN among the rows of `x` via the int8-screened candidate scan:
/// same signature, same padding, and **bitwise-equal output** to
/// [`self_knn_tiled`] — only the amount of f32 work per query changes.
/// Thread-invariant for the same reason as the exact engine: each query
/// row is screened and reranked whole by exactly one worker, in
/// globally ascending j order.
pub fn self_knn_quantized(x: &Matrix, k: usize, threads: usize) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let mut idx = vec![u32::MAX; n * k];
    let mut dd = vec![f32::INFINITY; n * k];
    if k == 0 || n == 0 {
        return (idx, dd);
    }
    let qm = QuantizedMatrix::quantize(x);
    let norms = row_sq_norms(x);
    let idx_base = idx.as_mut_ptr() as usize;
    let d2_base = dd.as_mut_ptr() as usize;
    par_for_chunks(n, TILE_Q, threads, |i0, i1| {
        let mut lb = vec![0.0f64; n];
        let mut scratch: Vec<f64> = Vec::with_capacity(n);
        for i in i0..i1 {
            let qi = x.row(i);
            let nqi = norms[i];
            scratch.clear();
            for j in 0..n {
                if j == i {
                    continue;
                }
                let (l, u) = qm.bound_pair(i, j, &norms);
                lb[j] = l;
                scratch.push(u);
            }
            let u = kth_smallest(&mut scratch, k);
            let mut top = TopK::new(k);
            for j in 0..n {
                // keep-on-NaN: `!(lb > u)` keeps NaN bounds in the scan
                if j == i || lb[j] > u {
                    continue;
                }
                // the exact engine's expression, bit for bit, in the
                // same ascending-j candidate order
                let dist = clamp0(nqi + norms[j] - 2.0 * dot(qi, x.row(j)));
                top.push(dist, j as u32);
            }
            let off = i * k;
            // SAFETY: par_for_chunks hands out disjoint [i0, i1) ranges,
            // so row i's k output slots are written by exactly one
            // worker and both vectors outlive the parallel scope.
            let oi = unsafe { std::slice::from_raw_parts_mut((idx_base as *mut u32).add(off), k) };
            // SAFETY: as above — the same row of the d² vector.
            let od = unsafe { std::slice::from_raw_parts_mut((d2_base as *mut f32).add(off), k) };
            top.write_into(oi, od);
        }
    });
    (idx, dd)
}

/// Exhaustive check that the quantized scan is bitwise equal to the
/// exact engine on `x` — the acceptance gauge wired into
/// `benches/index_build.rs` (exit-nonzero CI gate) and the tests below.
pub fn quantized_matches_exact(x: &Matrix, k: usize, threads: usize) -> bool {
    let (qi, qd) = self_knn_quantized(x, k, threads);
    let (ei, ed) = self_knn_tiled(x, k, threads);
    let bits = |a: &f32, b: &f32| a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan());
    qi == ei && qd.len() == ed.len() && qd.iter().zip(&ed).all(|(a, b)| bits(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn assert_knn_eq(x: &Matrix, k: usize, threads: usize, ctx: &str) {
        let (qi, qd) = self_knn_quantized(x, k, threads);
        let (ei, ed) = self_knn_tiled(x, k, threads);
        assert_eq!(qi, ei, "{ctx}: indices diverge");
        assert_eq!(qd.len(), ed.len(), "{ctx}: d² shape");
        for (s, (a, b)) in qd.iter().zip(&ed).enumerate() {
            assert!(
                a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan()),
                "{ctx}: d²[{s}] {a} vs {b}"
            );
        }
    }

    #[test]
    fn quantized_knn_bitwise_equal_on_gaussian_data() {
        let mut rng = Rng::new(21);
        for &(n, d) in &[(257usize, 8usize), (120, 33), (300, 16)] {
            let x = randm(&mut rng, n, d);
            for &k in &[1usize, 5, 17] {
                assert_knn_eq(&x, k, 3, &format!("gaussian n={n} d={d} k={k}"));
            }
        }
    }

    #[test]
    fn quantized_knn_bitwise_equal_with_ties_and_duplicates() {
        // integer grid data: masses of exactly tied distances, where any
        // deviation from the (d², index) contract shows up immediately
        let mut rng = Rng::new(22);
        let mut x = Matrix::zeros(200, 12);
        for v in x.data.iter_mut() {
            *v = rng.below(4) as f32;
        }
        for r in 0..20 {
            let dup = x.row(r).to_vec();
            x.row_mut(199 - r).copy_from_slice(&dup);
        }
        assert_knn_eq(&x, 5, 4, "tied integer grid");
    }

    #[test]
    fn quantized_knn_bitwise_equal_with_nan_rows() {
        let mut rng = Rng::new(23);
        let mut x = randm(&mut rng, 90, 9);
        for v in x.row_mut(17) {
            *v = f32::NAN; // fully poisoned row
        }
        x.data[5] = f32::NAN; // scattered single NaN
        x.data[300] = f32::INFINITY;
        assert_knn_eq(&x, 4, 2, "NaN rows");
    }

    #[test]
    fn quantized_knn_bitwise_equal_on_degenerate_shapes() {
        let mut rng = Rng::new(24);
        let x = randm(&mut rng, 7, 5);
        assert_knn_eq(&x, 0, 2, "k=0");
        assert_knn_eq(&x, 7, 2, "k=n");
        assert_knn_eq(&x, 20, 2, "k>n");
        assert_knn_eq(&randm(&mut rng, 1, 5), 3, 2, "single row");
        assert_knn_eq(&randm(&mut rng, 2, 5), 1, 2, "two rows");
        assert_knn_eq(&Matrix::zeros(0, 5), 3, 2, "empty matrix");
        assert_knn_eq(&Matrix::zeros(40, 6), 3, 2, "constant zero matrix");
        let mut wide = randm(&mut rng, 30, 8);
        wide.data[10] = 1.0e30;
        wide.data[50] = -1.0e30;
        assert_knn_eq(&wide, 3, 2, "huge-range rows");
    }

    #[test]
    fn quantized_knn_thread_invariant() {
        let mut rng = Rng::new(25);
        let x = randm(&mut rng, 150, 14);
        let base = self_knn_quantized(&x, 6, 1);
        for threads in [2usize, 4, 8] {
            assert_eq!(self_knn_quantized(&x, 6, threads), base, "threads={threads}");
        }
    }

    #[test]
    fn quantized_matches_exact_gauge() {
        let mut rng = Rng::new(26);
        let x = randm(&mut rng, 128, 32);
        assert!(quantized_matches_exact(&x, 15, 4));
    }

    #[test]
    fn quantize_reconstruction_is_tight_and_consistent() {
        let mut rng = Rng::new(27);
        let x = randm(&mut rng, 40, 23);
        let qm = QuantizedMatrix::quantize(&x);
        for r in 0..x.rows {
            let row = x.row(r);
            let s = qm.scale[r];
            let o = qm.offset[r];
            let mut e2 = 0.0f64;
            for (t, &v) in row.iter().enumerate() {
                let c = qm.codes[r * qm.cols + t] as f64;
                let resid = (v as f64 - (o + s * c)).abs();
                assert!(resid <= s * 0.5 + 1e-12, "row {r} col {t}: resid {resid} > s/2 {s}");
                e2 += resid * resid;
            }
            let err = qm.err[r];
            assert!((err - e2.sqrt()).abs() <= 1e-12 * e2.sqrt().max(1.0), "row {r} err");
        }
    }
}
