//! Tiled norm-trick distance engine (DESIGN.md §8).
//!
//! Every distance consumer in the ANN build pipeline — K-Means assignment,
//! within-cluster kNN, the brute-force global kNN used as metric ground
//! truth — reduces to "for each query row, the (arg)min-k squared
//! distances to a corpus of rows".  Following t-SNE-CUDA (Chan et al.,
//! 2018), this module casts that as blocked matrix work via the norm
//! trick:
//!
//! ```text
//! d²(x, y) = ‖x‖² + ‖y‖² − 2⟨x, y⟩
//! ```
//!
//! Row squared-norms are precomputed once; the inner loop is a
//! cache-blocked x·yᵀ microkernel ([`TILE_Q`] query rows × [`TILE_C`]
//! corpus rows per tile, one corpus tile stays L1-resident while every
//! query row of the chunk streams over it) built on the runtime-
//! dispatched 1×4 register block [`simd::dot4`] (8-lane accumulators,
//! bitwise identical SIMD-on and SIMD-off — DESIGN.md §16), with a
//! **fused** top-k selection pass ([`TopK`]) consuming each d² tile as
//! it is produced — the full n×m distance matrix is never materialized.
//!
//! **Determinism contract** (mirrors the step path, DESIGN.md §7): tile
//! sizes are fixed constants, each query row is processed start-to-finish
//! by exactly one worker, and the corpus is always walked in ascending
//! index order — so results are bitwise independent of the thread count.
//! Candidates are ordered by the lexicographic `(d², index)` contract
//! (ties go to the smaller corpus index, `total_cmp` so NaN never
//! panics); the naive oracles in `crate::ann` implement the identical
//! contract, and the property tests in `tests/distance_engine.rs` check
//! exact agreement.

use super::{dot, simd, Matrix};
use crate::util::parallel::par_for_chunks;

/// Query rows per worker chunk (i-tile).  Each chunk is claimed by one
/// worker and processed whole — the unit of the determinism argument.
pub const TILE_Q: usize = 32;

/// Corpus rows per j-tile.  A 64-row × 64-dim f32 tile is 16 KiB, so it
/// stays L1-resident while all [`TILE_Q`] query rows stream over it.
pub const TILE_C: usize = 64;

/// k at or below which [`TopK`] uses the insertion array instead of the
/// binary heap (replace cost is O(k) either way at this size, but the
/// insertion array is branch-light and stays in registers/L1).
const INSERTION_MAX_K: usize = 16;

/// Missing-slot marker (same value as `crate::ann::NO_NEIGHBOR`).
const NO_IDX: u32 = u32::MAX;

/// The engine's total order on candidates: ascending squared distance,
/// ties broken toward the smaller corpus index.  `total_cmp` keeps NaN
/// from panicking (NaN sorts above +∞, so it never wins a slot).
/// `pub(crate)` so the quantized candidate scan (`linalg::quant`) can
/// implement the identical contract.
#[inline]
pub(crate) fn lex_less(da: f32, ia: u32, db: f32, ib: u32) -> bool {
    match da.total_cmp(&db) {
        std::cmp::Ordering::Less => true,
        std::cmp::Ordering::Greater => false,
        std::cmp::Ordering::Equal => ia < ib,
    }
}

/// Clamp the norm-trick cancellation to zero **without absorbing NaN**:
/// `f32::max(NaN, 0.0)` returns 0.0, which would let a NaN row win every
/// top-k/argmin slot with a perfect distance — the opposite of the
/// documented contract.  `NaN < 0.0` is false, so NaN passes through and
/// `total_cmp` sorts it above +∞ where it never wins.  `pub(crate)` for
/// the exact rerank in `linalg::quant`, which must reproduce this
/// expression bit for bit.
#[inline]
pub(crate) fn clamp0(d: f32) -> f32 {
    if d < 0.0 {
        0.0
    } else {
        d
    }
}

/// Per-row squared norms ‖x_i‖², accumulated with the same association
/// order as [`dot`] — so a corpus row that is bitwise equal to a query row
/// yields an exact-zero self distance under the norm trick.
pub fn row_sq_norms(m: &Matrix) -> Vec<f32> {
    (0..m.rows)
        .map(|r| {
            let row = m.row(r);
            dot(row, row)
        })
        .collect()
}

/// Bounded best-k accumulator under the `(d², index)` order: an
/// insertion-sorted array for small k, a binary max-heap above
/// [`INSERTION_MAX_K`].  Both variants keep the current *worst* kept
/// candidate at slot 0 and accept/reject identically, so the hybrid is
/// invisible in the results.
pub struct TopK {
    k: usize,
    heap: bool,
    items: Vec<(f32, u32)>,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        TopK { k, heap: k > INSERTION_MAX_K, items: Vec::with_capacity(k) }
    }

    /// Offer a candidate; keeps the k least under the `(d², index)` order.
    #[inline]
    pub fn push(&mut self, d: f32, j: u32) {
        if self.items.len() < self.k {
            self.items.push((d, j));
            let p = self.items.len() - 1;
            if self.heap {
                self.sift_up(p);
            } else {
                // keep worst-first (descending) order
                let mut p = p;
                while p > 0 && self.less(p - 1, p) {
                    self.items.swap(p - 1, p);
                    p -= 1;
                }
            }
        } else if self.k > 0 && lex_less(d, j, self.items[0].0, self.items[0].1) {
            self.items[0] = (d, j);
            if self.heap {
                self.sift_down(0);
            } else {
                let mut p = 0;
                while p + 1 < self.k && self.less(p, p + 1) {
                    self.items.swap(p, p + 1);
                    p += 1;
                }
            }
        }
    }

    #[inline]
    fn less(&self, a: usize, b: usize) -> bool {
        lex_less(self.items[a].0, self.items[a].1, self.items[b].0, self.items[b].1)
    }

    fn sift_up(&mut self, mut p: usize) {
        while p > 0 {
            let parent = (p - 1) / 2;
            if self.less(parent, p) {
                self.items.swap(p, parent);
                p = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut p: usize) {
        let n = self.items.len();
        loop {
            let l = 2 * p + 1;
            if l >= n {
                break;
            }
            let r = l + 1;
            let mut big = l;
            if r < n && self.less(l, r) {
                big = r;
            }
            if self.less(p, big) {
                self.items.swap(p, big);
                p = big;
            } else {
                break;
            }
        }
    }

    /// Drain into one output row, ascending by `(d², index)`; slots beyond
    /// the number of candidates seen keep the caller's padding.
    pub fn write_into(mut self, out_idx: &mut [u32], out_d2: &mut [f32]) {
        self.items.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (slot, (d, j)) in self.items.iter().enumerate() {
            out_idx[slot] = *j;
            out_d2[slot] = *d;
        }
    }
}

/// For each row of `q`, the k nearest rows of `corpus` under the clamped
/// norm-trick squared distance, excluding corpus row `exclude[i]` for
/// query i when given (`u32::MAX` excludes nothing).  Results land in
/// `out_idx`/`out_d2` (shape `q.rows × k`, prefilled here with
/// `u32::MAX`/∞ padding), each row sorted ascending under the
/// `(d², index)` contract.
pub fn topk_tiled_into(
    q: &Matrix,
    exclude: Option<&[u32]>,
    corpus: &Matrix,
    corpus_norms: &[f32],
    k: usize,
    threads: usize,
    out_idx: &mut [u32],
    out_d2: &mut [f32],
) {
    assert_eq!(q.cols, corpus.cols, "dimension mismatch");
    assert_eq!(corpus_norms.len(), corpus.rows, "corpus norms mismatch");
    assert_eq!(out_idx.len(), q.rows * k, "out_idx shape");
    assert_eq!(out_d2.len(), q.rows * k, "out_d2 shape");
    if let Some(ex) = exclude {
        assert_eq!(ex.len(), q.rows, "exclusion list shape");
    }
    out_idx.fill(NO_IDX);
    out_d2.fill(f32::INFINITY);
    if k == 0 || q.rows == 0 || corpus.rows == 0 {
        return;
    }
    let m = corpus.rows;
    let idx_base = out_idx.as_mut_ptr() as usize;
    let d2_base = out_d2.as_mut_ptr() as usize;
    par_for_chunks(q.rows, TILE_Q, threads, |i0, i1| {
        let q_norms: Vec<f32> = (i0..i1)
            .map(|i| {
                let r = q.row(i);
                dot(r, r)
            })
            .collect();
        let mut sel: Vec<TopK> = (i0..i1).map(|_| TopK::new(k)).collect();
        // j-tile outer, query inner: the corpus tile stays hot in L1 while
        // every query row of this chunk consumes it.  Per query row the j
        // order is globally ascending, which fixes both the accumulation
        // order and the tie outcomes.
        let mut j0 = 0usize;
        while j0 < m {
            let j1 = (j0 + TILE_C).min(m);
            for (bi, i) in (i0..i1).enumerate() {
                let qi = q.row(i);
                let nqi = q_norms[bi];
                let ex = exclude.map(|e| e[i]).unwrap_or(NO_IDX);
                let top = &mut sel[bi];
                let mut j = j0;
                while j + 4 <= j1 {
                    let ds = simd::dot4(
                        qi,
                        corpus.row(j),
                        corpus.row(j + 1),
                        corpus.row(j + 2),
                        corpus.row(j + 3),
                    );
                    for (t, &dv) in ds.iter().enumerate() {
                        let jj = (j + t) as u32;
                        if jj != ex {
                            let dist = clamp0(nqi + corpus_norms[j + t] - 2.0 * dv);
                            top.push(dist, jj);
                        }
                    }
                    j += 4;
                }
                while j < j1 {
                    let jj = j as u32;
                    if jj != ex {
                        let dist = clamp0(nqi + corpus_norms[j] - 2.0 * dot(qi, corpus.row(j)));
                        top.push(dist, jj);
                    }
                    j += 1;
                }
            }
            j0 = j1;
        }
        // SAFETY: par_for_chunks hands out disjoint [i0, i1) ranges, so
        // output rows [i0*k, i1*k) are written by exactly one worker and
        // both vectors outlive the scope.
        let oi = unsafe {
            std::slice::from_raw_parts_mut((idx_base as *mut u32).add(i0 * k), (i1 - i0) * k)
        };
        // SAFETY: as above — the same rows of the d² vector.
        let od = unsafe {
            std::slice::from_raw_parts_mut((d2_base as *mut f32).add(i0 * k), (i1 - i0) * k)
        };
        for (bi, top) in sel.into_iter().enumerate() {
            top.write_into(&mut oi[bi * k..(bi + 1) * k], &mut od[bi * k..(bi + 1) * k]);
        }
    });
}

/// Exact kNN among the rows of `x`, excluding self: `(idx, d²)` of shape
/// n×k with `u32::MAX`/∞ padding when n ≤ k.  Tiled replacement for the
/// old per-row scan; `crate::ann::backend::knn_naive` is the oracle.
pub fn self_knn_tiled(x: &Matrix, k: usize, threads: usize) -> (Vec<u32>, Vec<f32>) {
    let mut idx = vec![NO_IDX; x.rows * k];
    let mut dd = vec![f32::INFINITY; x.rows * k];
    let norms = row_sq_norms(x);
    let ids: Vec<u32> = (0..x.rows as u32).collect();
    topk_tiled_into(x, Some(&ids), x, &norms, k, threads, &mut idx, &mut dd);
    (idx, dd)
}

/// k nearest corpus rows for a gathered set of query rows, excluding each
/// query's own corpus id; indices only (the metric ground-truth shape).
pub fn knn_for_queries(
    q: &Matrix,
    q_ids: &[u32],
    corpus: &Matrix,
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let norms = row_sq_norms(corpus);
    let mut idx = vec![NO_IDX; q.rows * k];
    let mut dd = vec![f32::INFINITY; q.rows * k];
    topk_tiled_into(q, Some(q_ids), corpus, &norms, k, threads, &mut idx, &mut dd);
    idx
}

/// For each row of `q`, the nearest row of `corpus` and its clamped
/// squared distance — argmin under the `(d², index)` contract, i.e. the
/// k = 1 special case with the selection structure collapsed to one
/// register pair.  `crate::ann::backend::assign_naive` is the oracle.
pub fn assign_tiled(q: &Matrix, corpus: &Matrix, threads: usize) -> Vec<(u32, f32)> {
    assert_eq!(q.cols, corpus.cols, "dimension mismatch");
    let m = corpus.rows;
    let mut out = vec![(0u32, f32::INFINITY); q.rows];
    if q.rows == 0 || m == 0 {
        return out;
    }
    let corpus_norms = row_sq_norms(corpus);
    let base = out.as_mut_ptr() as usize;
    par_for_chunks(q.rows, TILE_Q, threads, |i0, i1| {
        let q_norms: Vec<f32> = (i0..i1)
            .map(|i| {
                let r = q.row(i);
                dot(r, r)
            })
            .collect();
        let mut best: Vec<(f32, u32)> = vec![(f32::INFINITY, NO_IDX); i1 - i0];
        let mut j0 = 0usize;
        while j0 < m {
            let j1 = (j0 + TILE_C).min(m);
            for (bi, i) in (i0..i1).enumerate() {
                let qi = q.row(i);
                let nqi = q_norms[bi];
                let (mut bd, mut bj) = best[bi];
                let mut j = j0;
                while j + 4 <= j1 {
                    let ds = simd::dot4(
                        qi,
                        corpus.row(j),
                        corpus.row(j + 1),
                        corpus.row(j + 2),
                        corpus.row(j + 3),
                    );
                    for (t, &dv) in ds.iter().enumerate() {
                        let jj = (j + t) as u32;
                        let dist = clamp0(nqi + corpus_norms[j + t] - 2.0 * dv);
                        if lex_less(dist, jj, bd, bj) {
                            bd = dist;
                            bj = jj;
                        }
                    }
                    j += 4;
                }
                while j < j1 {
                    let jj = j as u32;
                    let dist = clamp0(nqi + corpus_norms[j] - 2.0 * dot(qi, corpus.row(j)));
                    if lex_less(dist, jj, bd, bj) {
                        bd = dist;
                        bj = jj;
                    }
                    j += 1;
                }
                best[bi] = (bd, bj);
            }
            j0 = j1;
        }
        // SAFETY: par_for_chunks chunks are disjoint, so out[i0..i1] is
        // written by exactly one worker; the vector outlives the scope.
        let o = unsafe {
            std::slice::from_raw_parts_mut((base as *mut (u32, f32)).add(i0), i1 - i0)
        };
        for (bi, &(d, j)) in best.iter().enumerate() {
            // no candidate won (all-NaN query row): mirror the naive
            // oracle's initial (0, ∞) answer
            o[bi] = if j == NO_IDX { (0, f32::INFINITY) } else { (j, d) };
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::d2;
    use crate::util::rng::Rng;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn dot4_is_bitwise_equal_to_dot() {
        let mut rng = Rng::new(0);
        for len in [1usize, 3, 4, 7, 16, 33, 64, 67] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
            let bs: Vec<Vec<f32>> =
                (0..4).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
            let got = simd::dot4(&a, &bs[0], &bs[1], &bs[2], &bs[3]);
            for t in 0..4 {
                assert_eq!(
                    got[t].to_bits(),
                    dot(&a, &bs[t]).to_bits(),
                    "len {len} lane {t}"
                );
            }
        }
    }

    #[test]
    fn row_sq_norms_match_dot() {
        let mut rng = Rng::new(1);
        let m = randm(&mut rng, 9, 13);
        let norms = row_sq_norms(&m);
        for r in 0..9 {
            assert_eq!(norms[r].to_bits(), dot(m.row(r), m.row(r)).to_bits());
        }
    }

    #[test]
    fn duplicate_rows_have_exact_zero_distance() {
        let mut rng = Rng::new(2);
        let mut m = randm(&mut rng, 50, 17);
        let dup = m.row(7).to_vec();
        m.row_mut(23).copy_from_slice(&dup);
        let (idx, dd) = self_knn_tiled(&m, 3, 4);
        assert_eq!(idx[7 * 3], 23, "row 7's nearest is its duplicate");
        assert_eq!(dd[7 * 3], 0.0, "exact zero under the norm trick");
        assert_eq!(idx[23 * 3], 7);
        assert_eq!(dd[23 * 3], 0.0);
    }

    #[test]
    fn topk_tie_contract_prefers_smaller_index() {
        // same distance streamed in ascending index order, more candidates
        // than slots: the k smallest indices must survive, ascending.
        for k in [2usize, 5, 20] {
            let mut top = TopK::new(k);
            for j in 0..40u32 {
                top.push(1.0, j);
            }
            let mut idx = vec![NO_IDX; k];
            let mut dd = vec![f32::INFINITY; k];
            top.write_into(&mut idx, &mut dd);
            let want: Vec<u32> = (0..k as u32).collect();
            assert_eq!(idx, want, "k {k}");
            assert!(dd.iter().all(|&d| d == 1.0));
        }
    }

    #[test]
    fn topk_pads_when_underfull() {
        let mut top = TopK::new(4);
        top.push(2.0, 9);
        top.push(1.0, 3);
        let mut idx = vec![NO_IDX; 4];
        let mut dd = vec![f32::INFINITY; 4];
        top.write_into(&mut idx, &mut dd);
        assert_eq!(idx, vec![3, 9, NO_IDX, NO_IDX]);
        assert_eq!(dd[0], 1.0);
        assert!(dd[2].is_infinite() && dd[3].is_infinite());
    }

    #[test]
    fn topk_zero_k_is_inert() {
        let mut top = TopK::new(0);
        top.push(1.0, 1);
        top.write_into(&mut [], &mut []);
    }

    #[test]
    fn heap_and_insertion_variants_agree() {
        // force both variants onto the same stream by straddling the
        // crossover: k=16 (insertion) vs k=17 (heap) prefixes must agree.
        let mut rng = Rng::new(3);
        let stream: Vec<(f32, u32)> =
            (0..300u32).map(|j| ((rng.below(40) as f32) * 0.5, j)).collect();
        let (mut a, mut b) = (TopK::new(16), TopK::new(17));
        for &(d, j) in &stream {
            a.push(d, j);
            b.push(d, j);
        }
        let (mut ia, mut da) = (vec![NO_IDX; 16], vec![f32::INFINITY; 16]);
        let (mut ib, mut db) = (vec![NO_IDX; 17], vec![f32::INFINITY; 17]);
        a.write_into(&mut ia, &mut da);
        b.write_into(&mut ib, &mut db);
        assert_eq!(&ia[..], &ib[..16], "first 16 slots agree across variants");
        assert_eq!(&da[..], &db[..16]);
    }

    #[test]
    fn tiled_distances_track_naive_d2_on_gaussian_data() {
        let mut rng = Rng::new(4);
        // sizes straddle both tile constants
        let x = randm(&mut rng, TILE_Q * 2 + 5, 19);
        let (idx, dd) = self_knn_tiled(&x, 4, 3);
        for i in 0..x.rows {
            for s in 0..4 {
                let j = idx[i * 4 + s] as usize;
                let err = (dd[i * 4 + s] - d2(x.row(i), x.row(j))).abs();
                assert!(err < 1e-3, "row {i} slot {s}: err {err}");
            }
        }
    }

    #[test]
    fn assign_tiled_empty_corpus() {
        let mut rng = Rng::new(5);
        let x = randm(&mut rng, 4, 3);
        let c = Matrix::zeros(0, 3);
        let out = assign_tiled(&x, &c, 2);
        assert_eq!(out.len(), 4);
        assert!(out.iter().all(|&(j, d)| j == 0 && d.is_infinite()));
    }

    #[test]
    fn nan_inputs_do_not_panic() {
        let mut rng = Rng::new(6);
        let mut x = randm(&mut rng, 40, 6);
        x.data[13] = f32::NAN;
        x.data[77] = f32::NAN;
        let c = randm(&mut rng, 5, 6);
        let a = assign_tiled(&x, &c, 2);
        assert_eq!(a.len(), 40);
        let (idx, dd) = self_knn_tiled(&x, 3, 2);
        assert_eq!(idx.len(), 120);
        assert_eq!(dd.len(), 120);
    }

    #[test]
    fn nan_rows_never_win_a_slot() {
        // clamp0 must not absorb NaN into 0.0 — a NaN centroid would
        // otherwise beat every real centroid with a perfect distance
        let mut rng = Rng::new(7);
        let x = randm(&mut rng, 60, 8);
        let mut c = randm(&mut rng, 6, 8);
        c.row_mut(2)[4] = f32::NAN;
        for (i, (a, d)) in assign_tiled(&x, &c, 2).into_iter().enumerate() {
            assert_ne!(a, 2, "row {i} assigned to the NaN centroid");
            assert!(d.is_finite());
        }
        // and in kNN a NaN row must come last, not first
        let mut y = randm(&mut rng, 20, 8);
        let nan_row = 5usize;
        for v in y.row_mut(nan_row) {
            *v = f32::NAN;
        }
        let (idx, _) = self_knn_tiled(&y, 3, 2);
        for i in 0..20 {
            if i == nan_row {
                continue;
            }
            for s in 0..3 {
                assert_ne!(idx[i * 3 + s], nan_row as u32, "row {i} picked the NaN row");
            }
        }
    }
}
