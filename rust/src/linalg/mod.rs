//! Dense linear algebra substrate: row-major f32 matrices, the operations
//! NOMAD needs (norms, distances, matmul-free PCA via power iteration),
//! the LSH used to seed the K-Means ANN index, the tiled norm-trick
//! distance engine behind the ANN build pipeline ([`distance`],
//! DESIGN.md §8), the runtime-dispatched SIMD kernel layer every hot
//! f32 loop funnels through ([`simd`], DESIGN.md §16), and the int8
//! row quantizer for the `--quantize-build` candidate scan ([`quant`]).

pub mod distance;
pub mod lsh;
pub mod pca;
pub mod quant;
pub mod simd;

/// A dense row-major f32 matrix (`rows x cols`).
///
/// This is deliberately minimal: NOMAD's heavy lifting happens either in the
/// AOT-compiled XLA artifacts or in hand-tiled loops in `embed/`; `Matrix`
/// is the container they share.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy the given rows into a new matrix (gather).
    pub fn gather(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Column means. The mean of zero rows is undefined — an empty
    /// matrix is rejected loudly rather than silently yielding an
    /// all-zero mean (which once masked bugs upstream; the K-Means
    /// reseed path guards its counts and can never reach this, and PCA
    /// runs on non-empty datasets by construction).
    pub fn col_means(&self) -> Vec<f32> {
        assert!(self.rows > 0, "col_means: empty matrix has no mean");
        let mut m = vec![0.0f64; self.cols];
        for r in 0..self.rows {
            for (c, v) in self.row(r).iter().enumerate() {
                m[c] += *v as f64;
            }
        }
        m.iter().map(|v| (*v / self.rows as f64) as f32).collect()
    }

    /// Subtract a row vector from every row, in place.
    pub fn sub_row(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.cols);
        for r in 0..self.rows {
            for (x, m) in self.row_mut(r).iter_mut().zip(v) {
                *x -= m;
            }
        }
    }
}

/// Squared euclidean distance of two equal-length slices — the
/// canonical 8-lane kernel ([`simd::d2`]), runtime-dispatched between
/// AVX2 and a bitwise-identical scalar fallback. This is the innermost
/// loop of the native K-Means / kNN path.
#[inline]
pub fn d2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::d2(a, b)
}

/// Dot product — the canonical 8-lane kernel ([`simd::dot`]),
/// runtime-dispatched between AVX2 and a bitwise-identical scalar
/// fallback.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Normalize a vector in place; returns the original norm.
pub fn normalize(a: &mut [f32]) -> f32 {
    let n = norm(a);
    if n > 1e-30 {
        for v in a.iter_mut() {
            *v /= n;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_rows() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn gather_rows() {
        let m = Matrix::from_vec(3, 2, vec![0., 0., 1., 1., 2., 2.]);
        let g = m.gather(&[2, 0]);
        assert_eq!(g.data, vec![2., 2., 0., 0.]);
    }

    #[test]
    fn col_means_and_center() {
        let mut m = Matrix::from_vec(2, 2, vec![1., 10., 3., 30.]);
        let mu = m.col_means();
        assert_eq!(mu, vec![2., 20.]);
        m.sub_row(&mu);
        assert_eq!(m.data, vec![-1., -10., 1., 10.]);
    }

    /// An empty matrix has no mean; the old code silently returned an
    /// all-zero vector, which upstream consumers can't tell apart from
    /// a legitimate centered dataset.
    #[test]
    #[should_panic(expected = "col_means: empty matrix")]
    fn col_means_rejects_empty_matrix() {
        let m = Matrix::zeros(0, 3);
        let _ = m.col_means();
    }

    #[test]
    fn d2_matches_naive() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((d2(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dot_and_norm() {
        let a = [3.0f32, 4.0];
        assert_eq!(norm(&a), 5.0);
        assert_eq!(dot(&a, &a), 25.0);
        let mut v = [3.0f32, 4.0];
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm(&v) - 1.0).abs() < 1e-6);
    }
}
