//! PCA initialization (paper §3.4: "We initialize our projection with PCA,
//! as it has been found to improve global structure").
//!
//! We compute the top-`k` principal components with randomized subspace
//! power iteration on the centered data — no full covariance matrix is ever
//! materialized (the datasets are n x d with n in the millions), only
//! `X^T (X v)` products, which stream over rows and parallelize.

use super::Matrix;
use crate::util::parallel::par_map;
use crate::util::rng::Rng;

/// Project `x` (n x d) onto its top-`k` principal components.
/// Returns an n x k matrix of scores, scaled to unit average std per
/// component (the t-SNE convention: tiny init, handled by the caller).
pub fn pca_project(x: &Matrix, k: usize, iters: usize, rng: &mut Rng) -> Matrix {
    let (n, d) = (x.rows, x.cols);
    assert!(k <= d, "k {k} > dim {d}");
    let mean = x.col_means();

    // subspace of k random directions
    let mut basis: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..d).map(|_| rng.normal()).collect())
        .collect();
    orthonormalize(&mut basis);

    let threads = crate::util::parallel::num_threads();
    for _ in 0..iters {
        // y_j = X^T (X b_j), accumulated in chunks over rows
        let new_basis: Vec<Vec<f32>> = basis
            .iter()
            .map(|b| {
                let partials = par_map(threads, threads, |t| {
                    let lo = n * t / threads;
                    let hi = n * (t + 1) / threads;
                    let mut acc = vec![0.0f64; d];
                    for r in lo..hi {
                        let row = x.row(r);
                        let mut s = 0.0f32;
                        for c in 0..d {
                            s += (row[c] - mean[c]) * b[c];
                        }
                        for c in 0..d {
                            acc[c] += (s * (row[c] - mean[c])) as f64;
                        }
                    }
                    acc
                });
                let mut y = vec![0.0f32; d];
                for p in partials {
                    for c in 0..d {
                        y[c] += p[c] as f32;
                    }
                }
                y
            })
            .collect();
        basis = new_basis;
        orthonormalize(&mut basis);
    }

    // scores
    let mut out = Matrix::zeros(n, k);
    let scores: Vec<Vec<f32>> = par_map(n, threads, |r| {
        let row = x.row(r);
        basis
            .iter()
            .map(|b| {
                let mut s = 0.0f32;
                for c in 0..d {
                    s += (row[c] - mean[c]) * b[c];
                }
                s
            })
            .collect()
    });
    for (r, sc) in scores.iter().enumerate() {
        out.row_mut(r).copy_from_slice(sc);
    }
    out
}

/// Standard t-SNE-style initialization: PCA scores rescaled so the first
/// component has std `target_std` (1e-4 x n-scale conventions live in the
/// optimizer; here we use 1.0 and let the caller scale).
pub fn pca_init(x: &Matrix, dim: usize, rng: &mut Rng, target_std: f32) -> Matrix {
    let mut p = pca_project(x, dim, 12, rng);
    // scale by the std of the first component
    let n = p.rows;
    let mut mean0 = 0.0f64;
    for r in 0..n {
        mean0 += p.row(r)[0] as f64;
    }
    mean0 /= n as f64;
    let mut var0 = 0.0f64;
    for r in 0..n {
        let v = p.row(r)[0] as f64 - mean0;
        var0 += v * v;
    }
    let std0 = (var0 / n.max(1) as f64).sqrt().max(1e-12) as f32;
    let scale = target_std / std0;
    for v in p.data.iter_mut() {
        *v *= scale;
    }
    p
}

fn orthonormalize(basis: &mut [Vec<f32>]) {
    for i in 0..basis.len() {
        for j in 0..i {
            let proj = super::dot(&basis[i], &basis[j]);
            let bj = basis[j].clone();
            for (v, w) in basis[i].iter_mut().zip(&bj) {
                *v -= proj * w;
            }
        }
        super::normalize(&mut basis[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_dominant_direction() {
        // data stretched along (1, 1)/sqrt2 in 2-d
        let mut rng = Rng::new(0);
        let n = 2000;
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let t = rng.normal() * 10.0;
            let e = rng.normal() * 0.5;
            data.push(t + e);
            data.push(t - e);
        }
        let x = Matrix::from_vec(n, 2, data);
        let p = pca_project(&x, 1, 10, &mut rng);
        // the first PC must capture nearly all the variance: correlation of
        // score with (x0 + x1) should be ~±1
        let mut a = Vec::new();
        let mut b = Vec::new();
        for r in 0..n {
            a.push(p.row(r)[0] as f64);
            b.push((x.row(r)[0] + x.row(r)[1]) as f64);
        }
        let c = crate::util::stats::pearson(&a, &b).abs();
        assert!(c > 0.99, "pearson {c}");
    }

    #[test]
    fn components_are_decorrelated() {
        let mut rng = Rng::new(1);
        let n = 1500;
        let d = 8;
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            let a = rng.normal() * 5.0;
            let b = rng.normal() * 2.0;
            for c in 0..d {
                data.push(a * (c as f32 + 1.0) / d as f32 + b * ((d - c) as f32) / d as f32 + rng.normal() * 0.1);
            }
        }
        let x = Matrix::from_vec(n, d, data);
        let p = pca_project(&x, 2, 15, &mut rng);
        let c0: Vec<f64> = (0..n).map(|r| p.row(r)[0] as f64).collect();
        let c1: Vec<f64> = (0..n).map(|r| p.row(r)[1] as f64).collect();
        let corr = crate::util::stats::pearson(&c0, &c1).abs();
        assert!(corr < 0.1, "pc0/pc1 correlation {corr}");
    }

    #[test]
    fn init_scales_first_component() {
        let mut rng = Rng::new(2);
        let n = 500;
        let mut data = Vec::with_capacity(n * 3);
        for _ in 0..n {
            for _ in 0..3 {
                data.push(rng.normal() * 4.0);
            }
        }
        let x = Matrix::from_vec(n, 3, data);
        let p = pca_init(&x, 2, &mut rng, 1.0);
        let mean: f64 = (0..n).map(|r| p.row(r)[0] as f64).sum::<f64>() / n as f64;
        let var: f64 = (0..n).map(|r| (p.row(r)[0] as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((var.sqrt() - 1.0).abs() < 0.05, "std {}", var.sqrt());
    }
}
