//! Baseline data-mapping algorithms the paper compares against (§4).
//!
//! We cannot run the authors' exact comparators offline, so each baseline
//! is reimplemented from its defining paper, exercising the same ANN/metric
//! substrates (DESIGN.md §3 documents the mapping):
//!
//! * [`bh_tsne`] — Barnes–Hut t-SNE (van der Maaten 2013) with sparse
//!   perplexity-calibrated P.  With early exaggeration + PCA init it stands
//!   in for **OpenTSNE** (Table 1); with both disabled it matches the
//!   paper's characterization of **t-SNE-CUDA** (Fig 3: "does not take
//!   advantage of techniques for improving global coherence").
//! * [`umap_like`] — negative-sampling UMAP (McInnes et al.), the
//!   **RapidsUMAP** stand-in.
//! * exact InfoNC-t-SNE — NOMAD with `ApproxMode::None` (the surrogate's
//!   exact counterpart); no separate module needed.

pub mod bh_tsne;
pub mod umap_like;
