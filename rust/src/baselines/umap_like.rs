//! UMAP-style baseline: per-edge SGD with negative sampling
//! (McInnes, Healy & Melville 2020; a=b=1 kernel, the RAPIDS default family).
//!
//! Uses the same kNN graph machinery as NOMAD so comparisons isolate the
//! *optimizer/loss* difference, not index quality.

use crate::ann::{ClusterIndex, NO_NEIGHBOR};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// UMAP baseline hyperparameters.
#[derive(Clone, Debug)]
pub struct UmapParams {
    pub epochs: usize,
    pub neg_per_edge: usize,
    pub lr_initial: f32,
    pub seed: u64,
    /// gradient clip (UMAP clips to ±4)
    pub clip: f32,
}

impl Default for UmapParams {
    fn default() -> Self {
        UmapParams { epochs: 200, neg_per_edge: 5, lr_initial: 1.0, seed: 42, clip: 4.0 }
    }
}

/// Run UMAP-ish SGD from `init` (n x 2) over the index's kNN edges.
pub fn run(index: &ClusterIndex, init: &Matrix, p: &UmapParams) -> Matrix {
    let n = index.n();
    let k = index.k;
    let mut pos = init.data.clone();
    let mut rng = Rng::new(p.seed);

    // edge list (directed)
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * k);
    for i in 0..n {
        for s in 0..k {
            let j = index.nbr_idx[i * k + s];
            if j != NO_NEIGHBOR {
                edges.push((i as u32, j));
            }
        }
    }

    let clip = p.clip;
    for epoch in 0..p.epochs {
        let lr = p.lr_initial * (1.0 - epoch as f32 / p.epochs as f32);
        rng.shuffle(&mut edges);
        for &(i, j) in &edges {
            let (i, j) = (i as usize, j as usize);
            let dx = pos[i * 2] - pos[j * 2];
            let dy = pos[i * 2 + 1] - pos[j * 2 + 1];
            let d2 = dx * dx + dy * dy;
            // attractive gradient of log(1/(1+d^2)): -2/(1+d^2) * delta
            let g = (-2.0 / (1.0 + d2)).clamp(-clip, clip);
            let (gx, gy) = ((g * dx).clamp(-clip, clip), (g * dy).clamp(-clip, clip));
            pos[i * 2] += lr * gx;
            pos[i * 2 + 1] += lr * gy;
            pos[j * 2] -= lr * gx;
            pos[j * 2 + 1] -= lr * gy;

            for _ in 0..p.neg_per_edge {
                let m = rng.below(n);
                if m == i {
                    continue;
                }
                let dx = pos[i * 2] - pos[m * 2];
                let dy = pos[i * 2 + 1] - pos[m * 2 + 1];
                let d2 = dx * dx + dy * dy;
                // repulsive gradient of log(1 - 1/(1+d^2)):
                // 2 / (d^2 (1+d^2)) * delta  (eps-guarded)
                let g = (2.0 / ((0.001 + d2) * (1.0 + d2))).clamp(-clip, clip);
                let (gx, gy) = ((g * dx).clamp(-clip, clip), (g * dy).clamp(-clip, clip));
                pos[i * 2] += lr * gx;
                pos[i * 2 + 1] += lr * gy;
            }
        }
    }
    Matrix::from_vec(n, 2, pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::ann::IndexParams;
    use crate::data::gaussian_mixture;

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(200, 8, 2, 30.0, 0.0, 0.0, &mut rng);
        let idx = ClusterIndex::build(
            &ds.x,
            &IndexParams { n_clusters: 2, k: 8, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        );
        let mut init = Matrix::zeros(200, 2);
        for v in init.data.iter_mut() {
            *v = rng.normal() * 0.01;
        }
        let y = run(&idx, &init, &UmapParams { epochs: 80, ..Default::default() });
        // within-label distances must be far below between-label distances
        let mut within = 0.0f64;
        let mut between = 0.0f64;
        let (mut wn, mut bn) = (0, 0);
        for i in (0..200).step_by(3) {
            for j in (1..200).step_by(7) {
                let d = crate::linalg::d2(y.row(i), y.row(j)) as f64;
                if ds.labels[0][i] == ds.labels[0][j] {
                    within += d;
                    wn += 1;
                } else {
                    between += d;
                    bn += 1;
                }
            }
        }
        assert!(between / bn as f64 > 2.0 * within / wn as f64);
    }

    #[test]
    fn positions_stay_finite() {
        let mut rng = Rng::new(1);
        let ds = gaussian_mixture(150, 8, 3, 5.0, 0.5, 0.7, &mut rng);
        let idx = ClusterIndex::build(
            &ds.x,
            &IndexParams { n_clusters: 3, k: 5, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        );
        let mut init = Matrix::zeros(150, 2);
        for v in init.data.iter_mut() {
            *v = rng.normal();
        }
        let y = run(&idx, &init, &UmapParams { epochs: 30, ..Default::default() });
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
