//! Barnes–Hut t-SNE (van der Maaten 2013): sparse perplexity-calibrated P
//! over a kNN graph, quadtree-approximated repulsion, momentum + gains.
//!
//! Configured two ways for the paper's comparisons:
//!  * `exaggeration > 1` + PCA init  -> the **OpenTSNE** stand-in (Table 1);
//!  * `exaggeration = 1` + random init -> the **t-SNE-CUDA** stand-in
//!    (the paper notes t-SNE-CUDA lacks early exaggeration / spectral init
//!    and attributes its weak triplet accuracy to that).

use crate::linalg::Matrix;
use crate::util::parallel::{num_threads, par_map};

/// BH t-SNE hyperparameters.
#[derive(Clone, Debug)]
pub struct TsneParams {
    pub perplexity: f64,
    pub theta: f32,
    pub epochs: usize,
    pub exaggeration: f32,
    pub exaggeration_epochs: usize,
    pub lr: Option<f64>,
    pub momentum_start: f32,
    pub momentum_final: f32,
    pub seed: u64,
}

impl Default for TsneParams {
    fn default() -> Self {
        TsneParams {
            perplexity: 30.0,
            theta: 0.5,
            epochs: 300,
            exaggeration: 12.0,
            exaggeration_epochs: 75,
            lr: None,
            momentum_start: 0.5,
            momentum_final: 0.8,
            seed: 42,
        }
    }
}

/// Sparse symmetric affinities.
pub struct SparseP {
    /// CSR: for row i, entries [indptr[i], indptr[i+1])
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// Perplexity-calibrated conditional affinities over a kNN list, then
/// symmetrized: p_ij = (p_{j|i} + p_{i|j}) / 2n.
pub fn calibrate_affinities(
    nbr_idx: &[u32],
    nbr_d2: &[f32],
    n: usize,
    k: usize,
    perplexity: f64,
) -> SparseP {
    let log_perp = perplexity.ln();
    let threads = num_threads();
    // binary search beta_i per point
    let rows: Vec<Vec<(u32, f32)>> = par_map(n, threads, |i| {
        let ds = &nbr_d2[i * k..(i + 1) * k];
        let js = &nbr_idx[i * k..(i + 1) * k];
        let valid: Vec<(u32, f64)> = js
            .iter()
            .zip(ds)
            .filter(|(j, d)| **j != u32::MAX && d.is_finite())
            .map(|(j, d)| (*j, *d as f64))
            .collect();
        if valid.is_empty() {
            return Vec::new();
        }
        let mut beta = 1.0f64;
        let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
        let mut p: Vec<f64> = vec![0.0; valid.len()];
        for _ in 0..60 {
            let mut sum = 0.0;
            for (t, (_, d)) in valid.iter().enumerate() {
                p[t] = (-beta * d).exp();
                sum += p[t];
            }
            if sum <= 1e-300 {
                beta /= 2.0;
                hi = beta * 2.0;
                continue;
            }
            // entropy H = log(sum) + beta * <d>
            let mut h = 0.0;
            for (t, (_, d)) in valid.iter().enumerate() {
                h += beta * d * p[t];
            }
            let h = h / sum + sum.ln();
            let diff = h - log_perp;
            if diff.abs() < 1e-5 {
                break;
            }
            if diff > 0.0 {
                lo = beta;
                beta = if hi.is_finite() { (beta + hi) / 2.0 } else { beta * 2.0 };
            } else {
                hi = beta;
                beta = (beta + lo) / 2.0;
            }
        }
        let sum: f64 = p.iter().sum::<f64>().max(1e-300);
        valid
            .iter()
            .zip(&p)
            .map(|((j, _), pv)| (*j, (pv / sum) as f32))
            .collect()
    });

    // symmetrize into a hash map per row
    let mut maps: Vec<std::collections::HashMap<u32, f32>> =
        (0..n).map(|_| std::collections::HashMap::new()).collect();
    for (i, row) in rows.iter().enumerate() {
        for &(j, p) in row {
            let half = p / (2.0 * n as f32);
            *maps[i].entry(j).or_insert(0.0) += half;
            *maps[j as usize].entry(i as u32).or_insert(0.0) += half;
        }
    }
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(0);
    for m in maps {
        let mut row: Vec<(u32, f32)> = m.into_iter().collect();
        row.sort_by_key(|e| e.0);
        for (j, v) in row {
            indices.push(j);
            values.push(v);
        }
        indptr.push(indices.len());
    }
    SparseP { indptr, indices, values }
}

// ---------------------------------------------------------------------------
// Quadtree for Barnes–Hut repulsion
// ---------------------------------------------------------------------------

struct QuadTree {
    nodes: Vec<QtNode>,
}

#[derive(Clone, Copy)]
struct QtNode {
    // center of mass and count
    com: [f32; 2],
    count: f32,
    // square cell
    cx: f32,
    cy: f32,
    half: f32,
    children: [i32; 4], // -1 = none
    leaf_point: i32,    // index of the single point if leaf w/ 1 point
}

impl QuadTree {
    fn build(pos: &[f32], n: usize) -> QuadTree {
        let mut min = [f32::INFINITY; 2];
        let mut max = [f32::NEG_INFINITY; 2];
        for i in 0..n {
            min[0] = min[0].min(pos[i * 2]);
            max[0] = max[0].max(pos[i * 2]);
            min[1] = min[1].min(pos[i * 2 + 1]);
            max[1] = max[1].max(pos[i * 2 + 1]);
        }
        let cx = (min[0] + max[0]) / 2.0;
        let cy = (min[1] + max[1]) / 2.0;
        let half = ((max[0] - min[0]).max(max[1] - min[1]) / 2.0 + 1e-5).max(1e-5);
        let root = QtNode {
            com: [0.0; 2],
            count: 0.0,
            cx,
            cy,
            half,
            children: [-1; 4],
            leaf_point: -1,
        };
        let mut t = QuadTree { nodes: vec![root] };
        for i in 0..n {
            t.insert(0, pos, i, 0);
        }
        t
    }

    fn insert(&mut self, node: usize, pos: &[f32], p: usize, depth: usize) {
        let (px, py) = (pos[p * 2], pos[p * 2 + 1]);
        // update center of mass
        let c = self.nodes[node].count;
        self.nodes[node].com[0] = (self.nodes[node].com[0] * c + px) / (c + 1.0);
        self.nodes[node].com[1] = (self.nodes[node].com[1] * c + py) / (c + 1.0);
        self.nodes[node].count = c + 1.0;

        if self.nodes[node].count == 1.0 {
            self.nodes[node].leaf_point = p as i32;
            return;
        }
        // split: push existing single point down, then insert new
        if depth > 48 {
            return; // coincident points: keep aggregated at this node
        }
        let existing = self.nodes[node].leaf_point;
        self.nodes[node].leaf_point = -1;
        if existing >= 0 {
            let q = existing as usize;
            let qd = self.quadrant(node, pos[q * 2], pos[q * 2 + 1]);
            let ch = self.child(node, qd);
            self.insert_into_child(ch, pos, q, depth);
        }
        let qd = self.quadrant(node, px, py);
        let ch = self.child(node, qd);
        self.insert_into_child(ch, pos, p, depth);
    }

    fn insert_into_child(&mut self, child: usize, pos: &[f32], p: usize, depth: usize) {
        self.insert(child, pos, p, depth + 1);
    }

    fn quadrant(&self, node: usize, x: f32, y: f32) -> usize {
        let n = &self.nodes[node];
        ((x >= n.cx) as usize) | (((y >= n.cy) as usize) << 1)
    }

    fn child(&mut self, node: usize, q: usize) -> usize {
        if self.nodes[node].children[q] >= 0 {
            return self.nodes[node].children[q] as usize;
        }
        let parent = self.nodes[node];
        let h = parent.half / 2.0;
        let cx = parent.cx + if q & 1 == 1 { h } else { -h };
        let cy = parent.cy + if q & 2 == 2 { h } else { -h };
        let idx = self.nodes.len();
        self.nodes.push(QtNode {
            com: [0.0; 2],
            count: 0.0,
            cx,
            cy,
            half: h,
            children: [-1; 4],
            leaf_point: -1,
        });
        self.nodes[node].children[q] = idx as i32;
        idx
    }

    /// Accumulate the BH-approximated repulsive numerator for point p,
    /// returning (fx, fy, z_partial).
    fn repulsion(&self, p: usize, pos: &[f32], theta2: f32) -> (f64, f64, f64) {
        let (px, py) = (pos[p * 2], pos[p * 2 + 1]);
        let mut fx = 0.0f64;
        let mut fy = 0.0f64;
        let mut z = 0.0f64;
        let mut stack = vec![0usize];
        while let Some(node) = stack.pop() {
            let nd = &self.nodes[node];
            if nd.count == 0.0 {
                continue;
            }
            let dx = px - nd.com[0];
            let dy = py - nd.com[1];
            let d2 = dx * dx + dy * dy;
            let is_self_leaf = nd.leaf_point == p as i32 && nd.count == 1.0;
            let cell = 2.0 * nd.half;
            if is_self_leaf {
                continue;
            }
            if nd.leaf_point >= 0 || (cell * cell) < theta2 * d2 {
                // treat as a single body of mass count (excluding self if
                // the aggregated node contains p: the standard BH-tSNE
                // approximation ignores that tiny error)
                let q = 1.0 / (1.0 + d2);
                let mult = nd.count as f64 * (q * q) as f64;
                fx += mult * dx as f64;
                fy += mult * dy as f64;
                z += nd.count as f64 * q as f64;
            } else {
                for &c in &nd.children {
                    if c >= 0 {
                        stack.push(c as usize);
                    }
                }
            }
        }
        (fx, fy, z)
    }
}

/// Run BH t-SNE from `init` over a kNN graph (`nbr_idx/nbr_d2` flat n x k).
pub fn run(
    nbr_idx: &[u32],
    nbr_d2: &[f32],
    n: usize,
    k: usize,
    init: &Matrix,
    p: &TsneParams,
) -> Matrix {
    let sp = calibrate_affinities(nbr_idx, nbr_d2, n, k, p.perplexity);
    run_with_affinities(&sp, n, init, p)
}

/// Run from precomputed affinities (reused across configurations).
pub fn run_with_affinities(sp: &SparseP, n: usize, init: &Matrix, p: &TsneParams) -> Matrix {
    let mut pos = init.data.clone();
    let mut vel = vec![0.0f32; n * 2];
    let mut gains = vec![1.0f32; n * 2];
    let lr = p.lr.unwrap_or(n as f64 / p.exaggeration as f64).max(50.0) as f32;
    let theta2 = p.theta * p.theta;
    let threads = num_threads();

    for epoch in 0..p.epochs {
        let exag = if epoch < p.exaggeration_epochs { p.exaggeration } else { 1.0 };
        let momentum =
            if epoch < p.exaggeration_epochs { p.momentum_start } else { p.momentum_final };

        let tree = QuadTree::build(&pos, n);
        // repulsion (parallel) -> also accumulates Z
        let rep: Vec<(f64, f64, f64)> =
            par_map(n, threads, |i| tree.repulsion(i, &pos, theta2));
        let z: f64 = rep.iter().map(|r| r.2).sum::<f64>().max(1e-12);

        // attraction (sparse, serial is fine: |E| ~ n*k)
        let mut grad = vec![0.0f32; n * 2];
        for i in 0..n {
            let (px, py) = (pos[i * 2], pos[i * 2 + 1]);
            let mut ax = 0.0f32;
            let mut ay = 0.0f32;
            for e in sp.indptr[i]..sp.indptr[i + 1] {
                let j = sp.indices[e] as usize;
                let pij = sp.values[e] * exag;
                let dx = px - pos[j * 2];
                let dy = py - pos[j * 2 + 1];
                let q = 1.0 / (1.0 + dx * dx + dy * dy);
                ax += pij * q * dx;
                ay += pij * q * dy;
            }
            grad[i * 2] = 4.0 * (ax - (rep[i].0 / z) as f32);
            grad[i * 2 + 1] = 4.0 * (ay - (rep[i].1 / z) as f32);
        }

        // momentum + gains update (vdM 2008 conventions)
        for t in 0..n * 2 {
            let same_sign = (grad[t] > 0.0) == (vel[t] > 0.0);
            gains[t] = if same_sign { (gains[t] * 0.8).max(0.01) } else { gains[t] + 0.2 };
            vel[t] = momentum * vel[t] - lr * gains[t] * grad[t];
            pos[t] += vel[t];
        }
    }
    Matrix::from_vec(n, 2, pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::knn::exact_global;
    use crate::util::rng::Rng;
    use crate::data::gaussian_mixture;
    use crate::linalg::d2;

    #[test]
    fn affinities_rows_sum_consistently() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(120, 8, 2, 10.0, 0.0, 0.0, &mut rng);
        let k = 30;
        let idx = exact_global(&ds.x, k);
        let mut dd = vec![0.0f32; 120 * k];
        for i in 0..120 {
            for s in 0..k {
                dd[i * k + s] = d2(ds.x.row(i), ds.x.row(idx[i * k + s] as usize));
            }
        }
        let sp = calibrate_affinities(&idx, &dd, 120, k, 10.0);
        let total: f32 = sp.values.iter().sum();
        assert!((total - 1.0).abs() < 1e-3, "sum p = {total}");
        // symmetry
        for i in 0..120 {
            for e in sp.indptr[i]..sp.indptr[i + 1] {
                let j = sp.indices[e] as usize;
                let back = (sp.indptr[j]..sp.indptr[j + 1])
                    .find(|&f| sp.indices[f] as usize == i)
                    .expect("symmetric entry");
                assert!((sp.values[e] - sp.values[back]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn quadtree_mass_conserved() {
        let mut rng = Rng::new(1);
        let pos: Vec<f32> = (0..200).map(|_| rng.normal()).collect();
        let tree = QuadTree::build(&pos, 100);
        assert_eq!(tree.nodes[0].count, 100.0);
        // com equals mean
        let mx: f32 = (0..100).map(|i| pos[i * 2]).sum::<f32>() / 100.0;
        assert!((tree.nodes[0].com[0] - mx).abs() < 1e-3);
    }

    #[test]
    fn bh_repulsion_close_to_exact() {
        let mut rng = Rng::new(2);
        let n = 300;
        let pos: Vec<f32> = (0..n * 2).map(|_| rng.normal() * 3.0).collect();
        let tree = QuadTree::build(&pos, n);
        for &p in &[0usize, 17, 123] {
            let (bx, by, bz) = tree.repulsion(p, &pos, 0.25);
            // exact
            let (mut ex, mut ey, mut ez) = (0.0f64, 0.0f64, 0.0f64);
            for j in 0..n {
                if j == p {
                    continue;
                }
                let dx = pos[p * 2] - pos[j * 2];
                let dy = pos[p * 2 + 1] - pos[j * 2 + 1];
                let q = 1.0 / (1.0 + dx * dx + dy * dy);
                ex += (q * q * dx) as f64;
                ey += (q * q * dy) as f64;
                ez += q as f64;
            }
            assert!((bx - ex).abs() < 0.05 * (1.0 + ex.abs()), "fx {bx} vs {ex}");
            assert!((by - ey).abs() < 0.05 * (1.0 + ey.abs()), "fy {by} vs {ey}");
            assert!((bz - ez).abs() < 0.05 * (1.0 + ez.abs()), "z {bz} vs {ez}");
        }
    }

    #[test]
    fn tsne_separates_blobs() {
        let mut rng = Rng::new(3);
        let ds = gaussian_mixture(200, 8, 2, 30.0, 0.0, 0.0, &mut rng);
        let k = 20;
        let idx = exact_global(&ds.x, k);
        let mut dd = vec![0.0f32; 200 * k];
        for i in 0..200 {
            for s in 0..k {
                dd[i * k + s] = d2(ds.x.row(i), ds.x.row(idx[i * k + s] as usize));
            }
        }
        let mut init = Matrix::zeros(200, 2);
        for v in init.data.iter_mut() {
            *v = rng.normal() * 0.0001;
        }
        let y = run(
            &idx,
            &dd,
            200,
            k,
            &init,
            &TsneParams { epochs: 120, exaggeration_epochs: 40, ..Default::default() },
        );
        assert!(y.data.iter().all(|v| v.is_finite()));
        let mut within = 0.0f64;
        let mut between = 0.0f64;
        let (mut wn, mut bn) = (0, 0);
        for i in (0..200).step_by(3) {
            for j in (1..200).step_by(7) {
                let d = d2(y.row(i), y.row(j)) as f64;
                if ds.labels[0][i] == ds.labels[0][j] {
                    within += d;
                    wn += 1;
                } else {
                    between += d;
                    bn += 1;
                }
            }
        }
        assert!(
            between / bn as f64 > 3.0 * within / wn as f64,
            "between {between} within {within}"
        );
    }
}
