//! The PJRT runtime: loads the AOT HLO-text artifacts emitted by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange contract (see /opt/xla-example/README.md and DESIGN.md §6):
//! HLO **text**, not serialized protos — the published `xla` crate links
//! xla_extension 0.5.1 which rejects jax>=0.5's 64-bit instruction ids; the
//! text parser reassigns ids.  `artifacts/manifest.json` (parsed with the
//! from-scratch JSON parser) describes every artifact's function, shape
//! bucket and signature; executables are compiled lazily and cached.
//!
//! Every artifact-backed function has a bit-equivalent native fallback, so
//! the system degrades gracefully when a shape has no artifact.

// The manifest parser and bucket selection are pure std and always built
// (the default-feature tests cover their malformed-manifest behavior); only
// the PJRT-backed executor modules need the `xla` crate.
#[cfg(feature = "xla")]
pub mod ann;
pub mod artifact;
#[cfg(feature = "xla")]
pub mod step;

#[cfg(feature = "xla")]
pub use ann::XlaAnnBackend;
pub use artifact::{Artifact, Manifest};
#[cfg(feature = "xla")]
pub use step::XlaStepBackend;

#[cfg(feature = "xla")]
use crate::util::error::Result;

/// Resolve the artifacts directory: `$NOMAD_ARTIFACTS` or `./artifacts`,
/// walking up from the current directory so tests/benches work from any
/// workspace subdirectory.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("NOMAD_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

/// Load + compile one HLO text file on a fresh CPU PJRT client (smoke/test
/// helper; production paths use the cached executables in the backends).
#[cfg(feature = "xla")]
pub fn compile_hlo_text(
    client: &xla::PjRtClient,
    path: &std::path::Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}
