//! XLA-backed NOMAD step: executes the `nomad_step` AOT artifact per block.
//!
//! One `XlaStepBackend` lives per device thread (PJRT clients are not
//! thread-portable and a real deployment is one client per GPU anyway).
//! Executables are compiled lazily, once per shape bucket, and cached.
//! Blocks whose (k, negs) or mean count exceed every artifact fall back to
//! the native implementation — logged once.

use crate::embed::{native, ClusterBlock, StepBackend, StepInputs};
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;
use std::cell::RefCell;
use std::collections::HashMap;

pub struct XlaStepBackend {
    client: xla::PjRtClient,
    manifest: super::Manifest,
    /// bucket size -> compiled executable (+ its r capacity)
    cache: RefCell<HashMap<String, CachedExe>>,
    #[allow(dead_code)]
    native: native::NativeStepBackend,
    warned_fallback: RefCell<bool>,
}

struct CachedExe {
    exe: xla::PjRtLoadedExecutable,
    s: usize,
    r: usize,
}

impl XlaStepBackend {
    /// Build from `$NOMAD_ARTIFACTS` / `./artifacts`.
    pub fn from_env() -> Result<XlaStepBackend> {
        let dir = super::artifacts_dir();
        let manifest = super::Manifest::load(&dir)
            .with_context(|| format!("manifest in {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaStepBackend {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            native: native::NativeStepBackend::default(),
            warned_fallback: RefCell::new(false),
        })
    }

    fn exec_block(
        &self,
        block: &mut ClusterBlock,
        inputs: &StepInputs,
    ) -> Result<Option<f64>> {
        let r_needed = inputs.mean_w.len();
        let art = match self
            .manifest
            .step_for(block.size, block.k, block.negs, r_needed)
        {
            Some(a) => a,
            None => return Ok(None),
        };
        let s_pad = art.param("s").unwrap();
        let r_pad = art.param("r").unwrap();

        let mut cache = self.cache.borrow_mut();
        let entry = match cache.get(&art.name) {
            Some(_) => cache.get(&art.name).unwrap(),
            None => {
                let exe = super::compile_hlo_text(&self.client, &art.file)
                    .with_context(|| format!("compile {}", art.name))?;
                cache.insert(art.name.clone(), CachedExe { exe, s: s_pad, r: r_pad });
                cache.get(&art.name).unwrap()
            }
        };

        // ---- pad host buffers to the artifact bucket ---------------------
        let k = block.k;
        let negs = block.negs;
        let s = block.size;
        debug_assert!(entry.s >= s && entry.r >= r_needed);
        let sp = entry.s;
        let rp = entry.r;

        let mut pos = vec![0.0f32; sp * 2];
        pos[..s * 2].copy_from_slice(&block.pos);
        let mut nbr_idx = vec![0i32; sp * k];
        nbr_idx[..s * k].copy_from_slice(&block.nbr_idx);
        let mut nbr_w = vec![0.0f32; sp * k];
        nbr_w[..s * k].copy_from_slice(&block.nbr_w);
        let mut neg_idx = vec![0i32; sp * negs];
        neg_idx[..s * negs].copy_from_slice(&block.neg_idx);
        let mut valid = vec![0.0f32; sp];
        valid[..s].copy_from_slice(&block.valid);
        // padded rows self-loop so gathers stay in bounds
        for l in s..sp {
            for t in 0..k {
                nbr_idx[l * k + t] = l as i32;
            }
            for t in 0..negs {
                neg_idx[l * negs + t] = l as i32;
            }
        }
        // the step inputs are SoA (gather-engine layout); the artifact
        // signature wants the classic interleaved r x 2 means
        let mut means = vec![0.0f32; rp * 2];
        for rr in 0..r_needed {
            means[rr * 2] = inputs.mean_x[rr];
            means[rr * 2 + 1] = inputs.mean_y[rr];
        }
        let mut mean_w = vec![0.0f32; rp];
        mean_w[..r_needed].copy_from_slice(inputs.mean_w);

        let lits = [
            xla::Literal::vec1(&pos).reshape(&[sp as i64, 2])?,
            xla::Literal::vec1(&nbr_idx).reshape(&[sp as i64, k as i64])?,
            xla::Literal::vec1(&nbr_w).reshape(&[sp as i64, k as i64])?,
            xla::Literal::vec1(&neg_idx).reshape(&[sp as i64, negs as i64])?,
            xla::Literal::vec1(&[block.neg_w]),
            xla::Literal::vec1(&means).reshape(&[rp as i64, 2])?,
            xla::Literal::vec1(&mean_w).reshape(&[rp as i64])?,
            xla::Literal::vec1(&valid).reshape(&[sp as i64])?,
            xla::Literal::scalar(inputs.lr),
        ];
        let result = entry.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let (pos_new, loss) = result.to_tuple2()?;
        let pos_out = pos_new.to_vec::<f32>()?;
        block.pos.copy_from_slice(&pos_out[..s * 2]);
        let loss = loss.to_vec::<f32>()?[0] as f64;
        Ok(Some(loss))
    }
}

impl StepBackend for XlaStepBackend {
    fn step(&self, block: &mut ClusterBlock, inputs: &StepInputs, rng: &mut Rng) -> f64 {
        block.resample_negatives(rng);
        match self.exec_block(block, inputs) {
            Ok(Some(loss)) => loss,
            Ok(None) => {
                if !*self.warned_fallback.borrow() {
                    eprintln!(
                        "[nomad] no step artifact for bucket s={} k={} negs={} r={}; native fallback",
                        block.size, block.k, block.negs, inputs.mean_w.len()
                    );
                    *self.warned_fallback.borrow_mut() = true;
                }
                self.native_step_no_resample(block, inputs)
            }
            Err(e) => {
                eprintln!("[nomad] XLA step failed ({e:#}); native fallback");
                self.native_step_no_resample(block, inputs)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

impl XlaStepBackend {
    /// Native step reusing the already-resampled negatives (so the XLA and
    /// native paths stay comparable within an epoch) — the gather engine on
    /// the block's precomputed transposes, same as [`native::NativeStepBackend`].
    /// Honors the caller's intra-step thread budget instead of grabbing the
    /// machine default — the device worker already divided the cores across
    /// devices.
    fn native_step_no_resample(&self, block: &mut ClusterBlock, inputs: &StepInputs) -> f64 {
        let threads = if inputs.threads == 0 {
            crate::util::parallel::num_threads()
        } else {
            inputs.threads
        };
        let (grad, loss) = native::nomad_grad_gather(
            &block.pos,
            &block.nbr_idx,
            &block.nbr_w,
            &block.nbr_in,
            &block.neg_idx,
            &block.neg_in,
            block.neg_w,
            inputs.mean_x,
            inputs.mean_y,
            inputs.mean_w,
            &block.valid,
            block.k,
            block.negs,
            threads,
        );
        for l in 0..block.n_real {
            block.pos[l * 2] -= inputs.lr * grad[l * 2];
            block.pos[l * 2 + 1] -= inputs.lr * grad[l * 2 + 1];
        }
        loss
    }
}
