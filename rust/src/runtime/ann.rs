//! XLA-backed ANN distance engine: K-Means assignment and within-cluster
//! kNN through the `kmeans_em_step` / `knn_build` artifacts.
//!
//! On TPU these are the MXU-bound kernels (see python/compile/kernels); on
//! the CPU PJRT plugin they exercise the same artifact path end-to-end.
//! Shapes without a matching artifact fall back to the native backend.

use crate::ann::backend::{AnnBackend, NativeBackend};
use crate::linalg::Matrix;
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

pub struct XlaAnnBackend {
    client: xla::PjRtClient,
    manifest: super::Manifest,
    // Arc so callers clone a handle and drop the lock before `execute` —
    // concurrent per-cluster kNN calls must not serialize on the cache.
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    native: NativeBackend,
}

// SAFETY: `AnnBackend` is a `Sync` trait (the within-cluster build calls
// the backend from several worker threads).  The executable cache is
// behind a `Mutex`, the manifest and native fallback are immutable, and
// PJRT clients/executables are internally synchronized — the PJRT C API
// is documented as thread-safe for compile/execute.
unsafe impl Sync for XlaAnnBackend {}

const BIG: f32 = 1.0e37;

impl XlaAnnBackend {
    pub fn from_env() -> Result<XlaAnnBackend> {
        let dir = super::artifacts_dir();
        let manifest = super::Manifest::load(&dir)
            .with_context(|| format!("manifest in {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaAnnBackend {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            native: NativeBackend::default(),
        })
    }

    fn get_exe(&self, name: &str, file: &std::path::Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        // hold the lock across check + compile so concurrent cluster
        // workers cannot both compile the same artifact; the returned Arc
        // lets the caller execute without holding the lock
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(name) {
            return Ok(exe.clone());
        }
        let exe = Arc::new(
            super::compile_hlo_text(&self.client, file)
                .with_context(|| format!("compile {name}"))?,
        );
        cache.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    fn assign_xla(&self, x: &Matrix, c: &Matrix) -> Result<Option<Vec<(u32, f32)>>> {
        let art = match self.manifest.kmeans_for(x.rows, x.cols, c.rows) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let np = art.param("n").unwrap();
        let cp = art.param("c").unwrap();
        let d = x.cols;
        let exe = self.get_exe(&art.name, &art.file)?;

        let mut xp = vec![0.0f32; np * d];
        xp[..x.rows * d].copy_from_slice(&x.data);
        let mut cpd = vec![0.0f32; cp * d];
        cpd[..c.rows * d].copy_from_slice(&c.data);
        let mut cmask = vec![0.0f32; cp];
        for v in cmask.iter_mut().take(c.rows) {
            *v = 1.0;
        }
        let lits = [
            xla::Literal::vec1(&xp).reshape(&[np as i64, d as i64])?,
            xla::Literal::vec1(&cpd).reshape(&[cp as i64, d as i64])?,
            xla::Literal::vec1(&cmask).reshape(&[cp as i64])?,
        ];
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let (assign, d2, _sums, _counts) = result.to_tuple4()?;
        let assign = assign.to_vec::<i32>()?;
        let d2 = d2.to_vec::<f32>()?;
        Ok(Some(
            (0..x.rows).map(|i| (assign[i] as u32, d2[i])).collect(),
        ))
    }

    fn knn_xla(&self, x: &Matrix, k: usize) -> Result<Option<(Vec<u32>, Vec<f32>)>> {
        let art = match self.manifest.knn_for(x.rows, x.cols, k) {
            Some(a) => a.clone(),
            None => return Ok(None),
        };
        let np = art.param("n").unwrap();
        let ka = art.param("k").unwrap();
        let d = x.cols;
        let exe = self.get_exe(&art.name, &art.file)?;

        let mut xp = vec![0.0f32; np * d];
        xp[..x.rows * d].copy_from_slice(&x.data);
        let mut vmask = vec![0.0f32; np];
        for v in vmask.iter_mut().take(x.rows) {
            *v = 1.0;
        }
        let lits = [
            xla::Literal::vec1(&xp).reshape(&[np as i64, d as i64])?,
            xla::Literal::vec1(&vmask).reshape(&[np as i64])?,
        ];
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let (idx, dd) = result.to_tuple2()?;
        let idx = idx.to_vec::<i32>()?;
        let dd = dd.to_vec::<f32>()?;
        // slice to n rows and first k slots; convert BIG padding to misses
        let n = x.rows;
        let mut out_idx = vec![u32::MAX; n * k];
        let mut out_dd = vec![f32::INFINITY; n * k];
        for i in 0..n {
            for s in 0..k {
                let v = dd[i * ka + s];
                if v < BIG {
                    out_idx[i * k + s] = idx[i * ka + s] as u32;
                    out_dd[i * k + s] = v;
                }
            }
        }
        Ok(Some((out_idx, out_dd)))
    }
}

impl AnnBackend for XlaAnnBackend {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> Vec<(u32, f32)> {
        match self.assign_xla(x, centroids) {
            Ok(Some(v)) => v,
            Ok(None) => self.native.assign(x, centroids),
            Err(e) => {
                eprintln!("[nomad] XLA assign failed ({e:#}); native fallback");
                self.native.assign(x, centroids)
            }
        }
    }

    fn knn(&self, x: &Matrix, k: usize) -> (Vec<u32>, Vec<f32>) {
        match self.knn_xla(x, k) {
            Ok(Some(v)) => v,
            Ok(None) => self.native.knn(x, k),
            Err(e) => {
                eprintln!("[nomad] XLA knn failed ({e:#}); native fallback");
                self.native.knn(x, k)
            }
        }
    }
}
