//! Artifact manifest parsing and bucket selection.

use crate::bail;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT artifact (an HLO-text file plus its signature).
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub func: String,
    /// bucket parameters as (key, value) pairs — e.g. s/k/neg/r/block
    pub params: Vec<(String, usize)>,
    pub n_inputs: usize,
    pub n_outputs: usize,
}

impl Artifact {
    pub fn param(&self, key: &str) -> Option<usize> {
        self.params.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).context("parse manifest.json")?;
        let mut artifacts = Vec::new();
        for a in v.get("artifacts").as_arr().context("artifacts array")?.iter() {
            let name = a.get("name").as_str().context("name")?.to_string();
            let file = dir.join(a.get("file").as_str().context("file")?);
            let func = a.get("fn").as_str().context("fn")?.to_string();
            let mut params = Vec::new();
            if let Some(obj) = a.get("params").as_obj() {
                for (k, v) in obj {
                    if let Some(u) = v.as_usize() {
                        params.push((k.clone(), u));
                    }
                }
            }
            let n_inputs = a.get("inputs").as_arr().map(|v| v.len()).unwrap_or(0);
            let n_outputs = a.get("outputs").as_arr().map(|v| v.len()).unwrap_or(0);
            if !file.exists() {
                bail!("artifact file missing: {}", file.display());
            }
            artifacts.push(Artifact { name, file, func, params, n_inputs, n_outputs });
        }
        Ok(Manifest { artifacts, dir: dir.to_path_buf() })
    }

    /// All artifacts for a function name.
    pub fn for_fn(&self, func: &str) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.func == func).collect()
    }

    /// Smallest `nomad_step` artifact with bucket `s` >= `size` and exactly
    /// matching k / negs, and mean capacity `r` >= `r_needed`.
    ///
    /// Manifest entries missing the size key are skipped, never unwrapped:
    /// a hand-edited or partially written manifest must degrade to the
    /// native fallback, not panic the runtime.
    pub fn step_for(&self, size: usize, k: usize, negs: usize, r_needed: usize) -> Option<&Artifact> {
        self.for_fn("nomad_step")
            .into_iter()
            .filter_map(|a| {
                let s = a.param("s")?;
                (s >= size
                    && a.param("k") == Some(k)
                    && a.param("neg") == Some(negs)
                    && a.param("r").is_some_and(|r| r >= r_needed))
                .then_some((s, a))
            })
            .min_by_key(|&(s, _)| s)
            .map(|(_, a)| a)
    }

    /// Smallest `kmeans_em_step` artifact fitting (n, d, c).
    pub fn kmeans_for(&self, n: usize, d: usize, c: usize) -> Option<&Artifact> {
        self.for_fn("kmeans_em_step")
            .into_iter()
            .filter_map(|a| {
                let an = a.param("n")?;
                (an >= n && a.param("d") == Some(d) && a.param("c").is_some_and(|ac| ac >= c))
                    .then_some((an, a))
            })
            .min_by_key(|&(an, _)| an)
            .map(|(_, a)| a)
    }

    /// Smallest `knn_build` artifact fitting (n, d) with k >= `k`.
    pub fn knn_for(&self, n: usize, d: usize, k: usize) -> Option<&Artifact> {
        self.for_fn("knn_build")
            .into_iter()
            .filter_map(|a| {
                let an = a.param("n")?;
                (an >= n && a.param("d") == Some(d) && a.param("k").is_some_and(|ak| ak >= k))
                    .then_some((an, a))
            })
            .min_by_key(|&(an, _)| an)
            .map(|(_, a)| a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_generated_manifest() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        let step = m.step_for(100, 15, 8, 50);
        assert!(step.is_some(), "default step bucket present");
        assert_eq!(step.unwrap().param("s"), Some(512));
        // oversize request -> None
        assert!(m.step_for(10_000_000, 15, 8, 50).is_none());
        // mismatched k -> None
        assert!(m.step_for(100, 3, 8, 50).is_none());
    }

    /// Regression: a manifest entry missing a bucket key (or carrying a
    /// non-integer value) must be skipped by the selectors, not panic.
    #[test]
    fn malformed_manifest_entries_are_skipped() {
        let dir = std::env::temp_dir().join("nomad_manifest_malformed");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // the loader checks each artifact file exists
        std::fs::write(dir.join("a.hlo"), "HloModule dummy").unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "no_params", "file": "a.hlo", "fn": "nomad_step"},
                {"name": "missing_s", "file": "a.hlo", "fn": "nomad_step",
                 "params": {"k": 15, "neg": 8, "r": 64}},
                {"name": "bad_type", "file": "a.hlo", "fn": "nomad_step",
                 "params": {"s": "big", "k": 15, "neg": 8, "r": 64}},
                {"name": "good", "file": "a.hlo", "fn": "nomad_step",
                 "params": {"s": 512, "k": 15, "neg": 8, "r": 64}},
                {"name": "kmeans_no_n", "file": "a.hlo", "fn": "kmeans_em_step",
                 "params": {"d": 32, "c": 64}},
                {"name": "knn_no_n", "file": "a.hlo", "fn": "knn_build",
                 "params": {"d": 32, "k": 15}}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 6);
        // only the well-formed bucket is selectable; the rest are skipped
        let step = m.step_for(100, 15, 8, 50).expect("good bucket selected");
        assert_eq!(step.name, "good");
        // selectors over functions with only-malformed entries return None
        assert!(m.kmeans_for(10, 32, 8).is_none());
        assert!(m.knn_for(10, 32, 8).is_none());
    }

    #[test]
    fn kmeans_and_knn_selection() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let a = m.kmeans_for(1000, 64, 100).expect("kmeans artifact");
        assert_eq!(a.param("n"), Some(2048));
        let b = m.knn_for(400, 256, 15).expect("knn artifact");
        assert_eq!(b.param("n"), Some(512));
        assert!(m.kmeans_for(1000, 777, 10).is_none());
    }
}
