//! Experiment harness: one entry point that runs any *method* (NOMAD
//! variants or baselines) on a dataset with timed quality samples
//! ([`QualityPoint`]s — not to be confused with the run store's restart
//! checkpoints, `crate::checkpoint`).  Shared by the examples, the
//! paper-table benches, and the CLI so every number in EXPERIMENTS.md
//! comes from the same code path.

use crate::ann::backend::NativeBackend;
use crate::ann::graph::WeightModel;
use crate::ann::{ClusterIndex, IndexParams};
use crate::baselines::{bh_tsne, umap_like};
use crate::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use crate::data::Dataset;
use crate::embed::{ApproxMode, NomadParams};
use crate::linalg::{pca::pca_init, Matrix};
use crate::metrics::{neighborhood_preservation, random_triplet_accuracy};
use crate::util::rng::Rng;
use std::time::Instant;

/// A data-mapping method under evaluation.
#[derive(Clone, Debug)]
pub enum Method {
    /// NOMAD Projection with `devices` simulated devices.
    Nomad { devices: usize, backend: BackendKind },
    /// NOMAD machinery with exact negatives only (InfoNC-t-SNE).
    InfoNcTsne,
    /// BH t-SNE without early exaggeration / PCA init (t-SNE-CUDA analog).
    TsneCudaLike,
    /// BH t-SNE with early exaggeration + PCA init (OpenTSNE analog).
    OpenTsneLike,
    /// Negative-sampling UMAP (RapidsUMAP analog).
    UmapLike,
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Nomad { devices, backend } => format!(
                "NOMAD-{}dev{}",
                devices,
                if *backend == BackendKind::Xla { "-xla" } else { "" }
            ),
            Method::InfoNcTsne => "InfoNC-t-SNE".into(),
            Method::TsneCudaLike => "tSNE-CUDA-like".into(),
            Method::OpenTsneLike => "OpenTSNE-like".into(),
            Method::UmapLike => "RapidsUMAP-like".into(),
        }
    }
}

/// One **quality snapshot** along a run (NP@k / RTA at a wall-clock
/// point).  Named `QualityPoint` to keep it distinct from the restartable
/// training checkpoints of the run store (`crate::checkpoint`,
/// DESIGN.md §11) — this is an evaluation sample, not a restart point.
#[derive(Clone, Debug)]
pub struct QualityPoint {
    pub epoch: usize,
    pub wall_secs: f64,
    /// modeled GPU-node seconds (NOMAD only; copies wall time otherwise)
    pub modeled_secs: f64,
    pub np_at_10: f64,
    pub rta: f64,
}

/// Full result of a harness run.
pub struct MethodRun {
    pub method: String,
    pub positions: Matrix,
    pub quality: Vec<QualityPoint>,
    pub total_secs: f64,
    pub modeled_secs: f64,
    pub index_secs: f64,
}

/// Quality-evaluation settings.
#[derive(Clone, Copy, Debug)]
pub struct EvalCfg {
    pub np_k: usize,
    pub np_sample: usize,
    pub triplets: usize,
    pub seed: u64,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { np_k: 10, np_sample: 400, triplets: 10_000, seed: 7 }
    }
}

/// Evaluate NP@k and RTA for an embedding.
pub fn evaluate(ds: &Dataset, y: &Matrix, cfg: &EvalCfg) -> (f64, f64) {
    let mut rng = Rng::new(cfg.seed);
    let np = neighborhood_preservation(&ds.x, y, cfg.np_k, cfg.np_sample, &mut rng);
    let rta = random_triplet_accuracy(&ds.x, y, cfg.triplets, &mut rng);
    (np, rta)
}

/// Run a method for `epochs`, sampling a [`QualityPoint`] every
/// `quality_every` epochs (0 = final only).
pub fn run_method(
    ds: &Dataset,
    method: &Method,
    epochs: usize,
    quality_every: usize,
    index: &IndexParams,
    eval_cfg: &EvalCfg,
    seed: u64,
) -> MethodRun {
    match method {
        Method::Nomad { devices, backend } => run_nomad(
            ds,
            *devices,
            *backend,
            ApproxMode::AllNonSelf,
            epochs,
            quality_every,
            index,
            eval_cfg,
            seed,
        ),
        Method::InfoNcTsne => run_nomad(
            ds,
            1,
            BackendKind::Native,
            ApproxMode::None,
            epochs,
            quality_every,
            index,
            eval_cfg,
            seed,
        ),
        Method::TsneCudaLike => run_bh(ds, false, epochs, quality_every, index, eval_cfg, seed),
        Method::OpenTsneLike => run_bh(ds, true, epochs, quality_every, index, eval_cfg, seed),
        Method::UmapLike => run_umap(ds, epochs, quality_every, index, eval_cfg, seed),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_nomad(
    ds: &Dataset,
    devices: usize,
    backend: BackendKind,
    approx: ApproxMode,
    epochs: usize,
    quality_every: usize,
    index: &IndexParams,
    eval_cfg: &EvalCfg,
    seed: u64,
) -> MethodRun {
    let params = NomadParams {
        epochs,
        k: index.k,
        approx,
        seed,
        weight_model: WeightModel::InverseRankPaper,
        ..Default::default()
    };
    let run_cfg = RunConfig {
        n_devices: devices,
        backend,
        snapshot_every: if quality_every > 0 { Some(quality_every) } else { None },
        index: index.clone(),
        ..Default::default()
    };
    let method_name = Method::Nomad { devices, backend }.name();
    let coord = NomadCoordinator::new(params, run_cfg);
    let run = coord.fit(ds, &NativeBackend::default());

    let mut quality = Vec::new();
    for s in &run.snapshots {
        let (np, rta) = evaluate(ds, &s.positions, eval_cfg);
        quality.push(QualityPoint {
            epoch: s.epoch,
            wall_secs: s.wall_secs,
            modeled_secs: s.modeled_secs,
            np_at_10: np,
            rta,
        });
    }
    let (np, rta) = evaluate(ds, &run.positions, eval_cfg);
    quality.push(QualityPoint {
        epoch: epochs,
        wall_secs: run.train_secs,
        modeled_secs: run.modeled_train_secs,
        np_at_10: np,
        rta,
    });
    MethodRun {
        method: if approx == ApproxMode::None { "InfoNC-t-SNE".into() } else { method_name },
        positions: run.positions,
        quality,
        total_secs: run.train_secs,
        modeled_secs: run.modeled_train_secs,
        index_secs: run.index_secs,
    }
}

fn knn_graph_for_baselines(
    ds: &Dataset,
    index: &IndexParams,
    seed: u64,
) -> (ClusterIndex, f64) {
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let idx = ClusterIndex::build(&ds.x, index, &NativeBackend::default(), &mut rng);
    (idx, t0.elapsed().as_secs_f64())
}

fn run_bh(
    ds: &Dataset,
    global_structure: bool,
    epochs: usize,
    quality_every: usize,
    index: &IndexParams,
    eval_cfg: &EvalCfg,
    seed: u64,
) -> MethodRun {
    let (idx, index_secs) = knn_graph_for_baselines(ds, index, seed);
    let mut rng = Rng::new(seed);
    let init = if global_structure {
        pca_init(&ds.x, 2, &mut rng, 1e-2)
    } else {
        let mut m = Matrix::zeros(ds.n(), 2);
        for v in m.data.iter_mut() {
            *v = rng.normal() * 1e-2;
        }
        m
    };
    // perplexity bounded by available neighbors
    let perplexity = ((index.k as f64 - 1.0) / 3.0).max(2.0);
    let sp = bh_tsne::calibrate_affinities(&idx.nbr_idx, &idx.nbr_d2, ds.n(), index.k, perplexity);

    let mut pos = init;
    let mut quality = Vec::new();
    let t0 = Instant::now();
    let step = if quality_every > 0 { quality_every } else { epochs };
    let mut done = 0;
    while done < epochs {
        let chunk = step.min(epochs - done);
        let params = bh_tsne::TsneParams {
            epochs: chunk,
            exaggeration: if global_structure { 12.0 } else { 1.0 },
            // exaggeration only in the first chunk's prefix
            exaggeration_epochs: if global_structure && done == 0 {
                (epochs / 4).min(chunk)
            } else {
                0
            },
            seed,
            ..Default::default()
        };
        pos = bh_tsne::run_with_affinities(&sp, ds.n(), &pos, &params);
        done += chunk;
        let wall = t0.elapsed().as_secs_f64();
        let (np, rta) = evaluate(ds, &pos, eval_cfg);
        quality.push(QualityPoint {
            epoch: done,
            wall_secs: wall,
            modeled_secs: wall,
            np_at_10: np,
            rta,
        });
    }
    let total = t0.elapsed().as_secs_f64();
    MethodRun {
        method: if global_structure { "OpenTSNE-like".into() } else { "tSNE-CUDA-like".into() },
        positions: pos,
        quality,
        total_secs: total,
        modeled_secs: total,
        index_secs,
    }
}

fn run_umap(
    ds: &Dataset,
    epochs: usize,
    quality_every: usize,
    index: &IndexParams,
    eval_cfg: &EvalCfg,
    seed: u64,
) -> MethodRun {
    let (idx, index_secs) = knn_graph_for_baselines(ds, index, seed);
    let mut rng = Rng::new(seed);
    let mut pos = Matrix::zeros(ds.n(), 2);
    for v in pos.data.iter_mut() {
        *v = rng.normal() * 10.0;
    }
    let mut quality = Vec::new();
    let t0 = Instant::now();
    let step = if quality_every > 0 { quality_every } else { epochs };
    let mut done = 0;
    while done < epochs {
        let chunk = step.min(epochs - done);
        let params = umap_like::UmapParams { epochs: chunk, seed: seed + done as u64, ..Default::default() };
        pos = umap_like::run(&idx, &pos, &params);
        done += chunk;
        let wall = t0.elapsed().as_secs_f64();
        let (np, rta) = evaluate(ds, &pos, eval_cfg);
        quality.push(QualityPoint {
            epoch: done,
            wall_secs: wall,
            modeled_secs: wall,
            np_at_10: np,
            rta,
        });
    }
    let total = t0.elapsed().as_secs_f64();
    MethodRun {
        method: "RapidsUMAP-like".into(),
        positions: pos,
        quality,
        total_secs: total,
        modeled_secs: total,
        index_secs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_mixture;

    #[test]
    fn all_methods_run_and_beat_random() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(400, 16, 4, 12.0, 0.2, 0.5, &mut rng);
        let index = IndexParams { n_clusters: 4, k: 8, ..Default::default() };
        let eval_cfg = EvalCfg { np_sample: 200, triplets: 3000, ..Default::default() };
        for method in [
            Method::Nomad { devices: 2, backend: BackendKind::Native },
            Method::InfoNcTsne,
            Method::TsneCudaLike,
            Method::OpenTsneLike,
            Method::UmapLike,
        ] {
            let run = run_method(&ds, &method, 30, 0, &index, &eval_cfg, 1);
            assert_eq!(run.quality.len(), 1, "{}", run.method);
            let cp = &run.quality[0];
            assert!(cp.np_at_10.is_finite() && cp.rta.is_finite());
            assert!(
                cp.rta > 0.5,
                "{}: rta {} should beat random",
                run.method,
                cp.rta
            );
        }
    }
}
