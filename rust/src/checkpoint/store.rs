//! On-disk run store: atomic, crc-guarded, versioned checkpoints
//! (DESIGN.md §11).
//!
//! Layout of a run directory:
//!
//! ```text
//! run_dir/
//!   run.json            manifest: format/version, params fingerprint,
//!                       full run description, live checkpoint list
//!   ckpt-000004/        state after 4 completed epochs
//!     ckpt.json         epoch counter, fingerprint, per-file crc32s
//!     positions.npy     n x 2 f32 global positions
//!     means.npy         R x 3 f32 (mean_x, mean_y, weight); ids are 0..R
//!     loss.npy          [epochs_done] f64 loss history (bitwise exact)
//!     artifact/         optional MapArtifact for `nomad serve --watch`
//!   ckpt-000006/ ...
//! ```
//!
//! **Atomicity**: every checkpoint is assembled in a hidden `.tmp-*`
//! sibling and `rename`d into place (atomic on POSIX), then `run.json`
//! is rewritten the same way — a reader (the serve watcher, a resuming
//! coordinator) never observes a half-written checkpoint.  **Integrity**:
//! `ckpt.json` records the crc32 of each state file; the loader verifies
//! them before parsing, so truncation and bit-flips surface as `Err`,
//! never as silently different training state.  **Retention**: after a
//! successful write, only the newest `retain` checkpoints are kept
//! (0 = keep everything).

use super::CheckpointState;
use crate::distributed::MeanEntry;
use crate::ensure;
use crate::linalg::Matrix;
use crate::obs::metrics;
use crate::serve::artifact::{MapArtifact, Provenance};
use crate::util::clock::Stopwatch;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::npy::{NpyF32, NpyF64};
use crate::viz::png::crc32;
use std::path::{Path, PathBuf};

const RUN_FORMAT: &str = "nomad-run-store";
const RUN_VERSION: i64 = 1;
const CKPT_FORMAT: &str = "nomad-checkpoint";
const CKPT_VERSION: i64 = 1;

/// The state files inside a checkpoint directory, in crc-check order.
const STATE_FILES: [&str; 3] = ["positions.npy", "means.npy", "loss.npy"];

/// Per-save options (owned by the caller — CLI flags or test config).
#[derive(Clone, Copy, Debug)]
pub struct SaveOpts<'a> {
    /// keep only the newest `retain` checkpoints; 0 keeps all
    pub retain: usize,
    /// also materialize a `MapArtifact` under `artifact/` so
    /// `nomad serve --watch` can preview the run live
    pub artifact: bool,
    /// labels for the artifact (ignored unless `artifact`)
    pub labels: Option<&'a [u32]>,
    /// artifact provenance: dataset name and run seed
    pub dataset: &'a str,
    pub seed: u64,
}

impl Default for SaveOpts<'_> {
    fn default() -> Self {
        SaveOpts { retain: 0, artifact: false, labels: None, dataset: "", seed: 0 }
    }
}

/// Handle on a run directory; create once per run, reopen to resume.
pub struct RunStore {
    dir: PathBuf,
    fingerprint: u32,
    run_info: Json,
    /// live checkpoint epochs, ascending
    checkpoints: Vec<usize>,
    /// classified fault records appended by the coordinator's recovery
    /// supervisor, in order (persisted in `run.json`, DESIGN.md §13)
    faults: Vec<Json>,
    /// per-epoch telemetry entries appended by the coordinator's epoch
    /// loop (persisted in `run.json`, DESIGN.md §15)
    telemetry: Vec<Json>,
}

fn ckpt_dirname(epochs_done: usize) -> String {
    format!("ckpt-{epochs_done:06}")
}

impl RunStore {
    /// Initialize a fresh run directory.  Refuses to clobber an existing
    /// store — reopen with [`RunStore::open`] to resume instead.
    pub fn create(dir: &Path, fingerprint: u32, run_info: Json) -> Result<RunStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create run dir {}", dir.display()))?;
        let manifest = dir.join("run.json");
        if manifest.exists() {
            crate::bail!(
                "run store already exists at {} (resume it, or pick a fresh directory)",
                dir.display()
            );
        }
        let store = RunStore {
            dir: dir.to_path_buf(),
            fingerprint,
            run_info,
            checkpoints: Vec::new(),
            faults: Vec::new(),
            telemetry: Vec::new(),
        };
        store.write_manifest()?;
        Ok(store)
    }

    /// Open an existing run directory written by [`RunStore::create`].
    pub fn open(dir: &Path) -> Result<RunStore> {
        let mpath = dir.join("run.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {}", mpath.display()))?;
        let v = Json::parse(&text).context("parse run.json")?;
        ensure!(
            v.get("format").as_str() == Some(RUN_FORMAT),
            "not a run store manifest: {}",
            mpath.display()
        );
        ensure!(
            v.get("version").as_i64() == Some(RUN_VERSION),
            "unsupported run store version {:?}",
            v.get("version").as_i64()
        );
        let fingerprint = v
            .get("fingerprint")
            .as_i64()
            .and_then(|f| u32::try_from(f).ok())
            .context("run.json: fingerprint missing or out of range")?;
        let mut checkpoints = v
            .get("checkpoints")
            .as_arr()
            .context("run.json: checkpoints missing")?
            .iter()
            .map(|e| e.as_usize().context("run.json: checkpoint epoch"))
            .collect::<Result<Vec<usize>>>()?;
        checkpoints.sort_unstable();
        checkpoints.dedup();
        // absent in stores written before fault records existed
        let faults = match v.get("faults").as_arr() {
            Some(a) => a.to_vec(),
            None => Vec::new(),
        };
        // likewise absent in stores written before telemetry existed
        let telemetry = match v.get("telemetry").as_arr() {
            Some(a) => a.to_vec(),
            None => Vec::new(),
        };
        Ok(RunStore {
            dir: dir.to_path_buf(),
            fingerprint,
            run_info: v.get("run").clone(),
            checkpoints,
            faults,
            telemetry,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn fingerprint(&self) -> u32 {
        self.fingerprint
    }

    /// The `"run"` section of `run.json` (see
    /// [`super::run_info_json`]/[`super::parse_run_info`]).
    pub fn run_info(&self) -> &Json {
        &self.run_info
    }

    /// Live checkpoint epochs, ascending.
    pub fn checkpoints(&self) -> &[usize] {
        &self.checkpoints
    }

    /// Newest checkpoint epoch, if any.
    pub fn latest(&self) -> Option<usize> {
        self.checkpoints.last().copied()
    }

    /// Directory of the checkpoint at `epochs_done`.
    pub fn ckpt_dir(&self, epochs_done: usize) -> PathBuf {
        self.dir.join(ckpt_dirname(epochs_done))
    }

    /// The `MapArtifact` directory inside a checkpoint (present when the
    /// run saves with `SaveOpts::artifact`).
    pub fn artifact_dir(&self, epochs_done: usize) -> PathBuf {
        self.ckpt_dir(epochs_done).join("artifact")
    }

    fn write_manifest(&self) -> Result<()> {
        let doc = json::obj(vec![
            ("format", json::s(RUN_FORMAT)),
            ("version", json::num(RUN_VERSION as f64)),
            ("fingerprint", json::num(self.fingerprint as f64)),
            (
                "latest",
                match self.latest() {
                    Some(e) => json::num(e as f64),
                    None => Json::Null,
                },
            ),
            (
                "checkpoints",
                json::arr(self.checkpoints.iter().map(|&e| json::num(e as f64)).collect()),
            ),
            ("faults", json::arr(self.faults.clone())),
            ("telemetry", json::arr(self.telemetry.clone())),
            ("run", self.run_info.clone()),
        ]);
        let tmp = self.dir.join("run.json.tmp");
        std::fs::write(&tmp, doc.pretty())
            .with_context(|| format!("write {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join("run.json"))
            .with_context(|| format!("publish {}/run.json", self.dir.display()))?;
        Ok(())
    }

    /// Persist a checkpoint atomically, update the manifest, apply
    /// retention.  The means table must carry contiguous ids `0..R`
    /// (the coordinator's sorted all-gather invariant) — they are stored
    /// implicitly and reconstructed on load.
    pub fn save(&mut self, st: &CheckpointState, opts: &SaveOpts) -> Result<()> {
        let t_save = Stopwatch::start();
        ensure!(st.positions.cols == 2, "positions must be n x 2");
        ensure!(
            st.loss_history.len() == st.epochs_done,
            "loss history has {} entries for {} completed epochs",
            st.loss_history.len(),
            st.epochs_done
        );
        ensure!(
            st.fingerprint == self.fingerprint,
            "checkpoint fingerprint {:08x} != run store fingerprint {:08x}",
            st.fingerprint,
            self.fingerprint
        );

        let name = ckpt_dirname(st.epochs_done);
        let tmp = self.dir.join(format!(".tmp-{name}"));
        let _ = std::fs::remove_dir_all(&tmp);
        std::fs::create_dir_all(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;

        NpyF32::new(vec![st.positions.rows, 2], st.positions.data.clone())
            .save(&tmp.join("positions.npy"))?;
        let mut mdata: Vec<f32> = Vec::with_capacity(st.means.len() * 3);
        for (i, e) in st.means.iter().enumerate() {
            ensure!(
                e.cluster_id as usize == i,
                "means table ids must be contiguous 0..R (found {} at slot {i})",
                e.cluster_id
            );
            mdata.extend_from_slice(&[e.mean[0], e.mean[1], e.weight]);
        }
        NpyF32::new(vec![st.means.len(), 3], mdata).save(&tmp.join("means.npy"))?;
        NpyF64::new(vec![st.loss_history.len()], st.loss_history.clone())
            .save(&tmp.join("loss.npy"))?;

        let mut crcs: Vec<(&str, Json)> = Vec::new();
        for f in STATE_FILES {
            let bytes = std::fs::read(tmp.join(f))?;
            crcs.push((f, json::num(crc32(&bytes) as f64)));
        }
        let doc = json::obj(vec![
            ("format", json::s(CKPT_FORMAT)),
            ("version", json::num(CKPT_VERSION as f64)),
            ("epochs_done", json::num(st.epochs_done as f64)),
            ("fingerprint", json::num(st.fingerprint as f64)),
            ("n", json::num(st.positions.rows as f64)),
            ("n_clusters", json::num(st.means.len() as f64)),
            ("crc", json::obj(crcs)),
        ]);
        std::fs::write(tmp.join("ckpt.json"), doc.pretty())
            .with_context(|| format!("write {}/ckpt.json", tmp.display()))?;

        if opts.artifact {
            let art = MapArtifact::from_run(
                st.positions.clone(),
                opts.labels.map(|l| l.to_vec()),
                Provenance {
                    dataset: opts.dataset.to_string(),
                    seed: opts.seed,
                    epochs: st.epochs_done,
                    final_loss: *st.loss_history.last().unwrap_or(&f64::NAN),
                },
            )?;
            art.save(&tmp.join("artifact"))?;
        }

        // publish: rename into place (replacing a same-epoch leftover from
        // a previous attempt), then the manifest, then prune
        let final_dir = self.ckpt_dir(st.epochs_done);
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)
                .with_context(|| format!("replace {}", final_dir.display()))?;
        }
        std::fs::rename(&tmp, &final_dir)
            .with_context(|| format!("publish {}", final_dir.display()))?;
        if !self.checkpoints.contains(&st.epochs_done) {
            self.checkpoints.push(st.epochs_done);
            self.checkpoints.sort_unstable();
        }
        let mut pruned: Vec<usize> = Vec::new();
        if opts.retain > 0 && self.checkpoints.len() > opts.retain {
            let cut = self.checkpoints.len() - opts.retain;
            pruned = self.checkpoints.drain(..cut).collect();
        }
        self.write_manifest()?;
        for e in pruned {
            // best effort: a failed prune leaves an orphan dir, not a bad run
            let _ = std::fs::remove_dir_all(self.ckpt_dir(e));
        }
        metrics::counter("nomad_checkpoints_total", "Checkpoints published.", &[]).inc();
        metrics::histogram(
            "nomad_checkpoint_save_seconds",
            "Checkpoint assemble-and-publish wall time.",
            &metrics::DURATION_BUCKETS_S,
            &[],
        )
        .observe(t_save.secs());
        Ok(())
    }

    /// Load and verify the checkpoint at `epochs_done`.  Any corruption —
    /// bad crc, truncated payload, missing manifest keys, shape drift —
    /// is an `Err`, never a panic.
    pub fn load(&self, epochs_done: usize) -> Result<CheckpointState> {
        let dir = self.ckpt_dir(epochs_done);
        let mpath = dir.join("ckpt.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("read {}", mpath.display()))?;
        let v = Json::parse(&text).context("parse ckpt.json")?;
        ensure!(
            v.get("format").as_str() == Some(CKPT_FORMAT),
            "not a checkpoint manifest: {}",
            mpath.display()
        );
        ensure!(
            v.get("version").as_i64() == Some(CKPT_VERSION),
            "unsupported checkpoint version {:?}",
            v.get("version").as_i64()
        );
        let e = v.get("epochs_done").as_usize().context("ckpt.json: epochs_done")?;
        ensure!(
            e == epochs_done,
            "checkpoint {} claims epochs_done {e}",
            dir.display()
        );
        let fingerprint = v
            .get("fingerprint")
            .as_i64()
            .and_then(|f| u32::try_from(f).ok())
            .context("ckpt.json: fingerprint missing or out of range")?;
        let n = v.get("n").as_usize().context("ckpt.json: n")?;
        let r = v.get("n_clusters").as_usize().context("ckpt.json: n_clusters")?;

        for f in STATE_FILES {
            let want = v
                .get("crc")
                .get(f)
                .as_i64()
                .and_then(|c| u32::try_from(c).ok())
                .with_context(|| format!("ckpt.json: crc for {f} missing"))?;
            let bytes = std::fs::read(dir.join(f))
                .with_context(|| format!("read {}/{f}", dir.display()))?;
            let got = crc32(&bytes);
            ensure!(
                got == want,
                "{f} crc mismatch ({got:08x} != {want:08x}) — corrupt checkpoint at {}",
                dir.display()
            );
        }

        let pos = NpyF32::load(&dir.join("positions.npy"))?;
        ensure!(pos.shape == vec![n, 2], "positions shape {:?} != [{n}, 2]", pos.shape);
        let mt = NpyF32::load(&dir.join("means.npy"))?;
        ensure!(mt.shape == vec![r, 3], "means shape {:?} != [{r}, 3]", mt.shape);
        let loss = NpyF64::load(&dir.join("loss.npy"))?;
        ensure!(
            loss.shape == vec![epochs_done],
            "loss shape {:?} != [{epochs_done}]",
            loss.shape
        );

        let means: Vec<MeanEntry> = mt
            .data
            .chunks_exact(3)
            .enumerate()
            .map(|(i, c)| MeanEntry { cluster_id: i as u32, mean: [c[0], c[1]], weight: c[2] })
            .collect();
        Ok(CheckpointState {
            epochs_done,
            positions: Matrix::from_vec(n, 2, pos.data),
            means,
            loss_history: loss.data,
            fingerprint,
        })
    }

    /// Load the newest checkpoint.
    pub fn load_latest(&self) -> Result<CheckpointState> {
        let e = self.latest().context("run store has no checkpoints yet")?;
        self.load(e)
    }

    /// Load the newest checkpoint that reads back clean, skipping torn or
    /// corrupt entries — a machine crash can leave the newest directory
    /// unreadable even through the tmp+rename dance if the filesystem
    /// reordered the data behind the rename.  Errs only when the store
    /// holds no loadable checkpoint at all.
    pub fn load_latest_valid(&self) -> Result<CheckpointState> {
        ensure!(!self.checkpoints.is_empty(), "run store has no checkpoints yet");
        let mut last_err = None;
        for &e in self.checkpoints.iter().rev() {
            match self.load(e) {
                Ok(st) => return Ok(st),
                Err(err) => last_err = Some(err),
            }
        }
        Err(last_err.expect("at least one load attempted"))
            .with_context(|| format!("no valid checkpoint in {}", self.dir.display()))
    }

    /// Append a classified fault record to the run manifest, so an
    /// interrupted-and-recovered run stays visible post-hoc (DESIGN.md
    /// §13).
    pub fn record_fault(
        &mut self,
        kind: &str,
        device: usize,
        restart_epoch: usize,
        detail: &str,
    ) -> Result<()> {
        self.faults.push(json::obj(vec![
            ("kind", json::s(kind)),
            ("device", json::num(device as f64)),
            ("restart_epoch", json::num(restart_epoch as f64)),
            ("detail", json::s(detail)),
        ]));
        self.write_manifest()
    }

    /// Fault records appended so far (parsed back from the manifest on
    /// reopen).
    pub fn faults(&self) -> &[Json] {
        &self.faults
    }

    /// Buffer one per-epoch telemetry entry (see [`epoch_telemetry_json`]).
    /// Entries land in `run.json`'s `"telemetry"` array on the next
    /// manifest rewrite — a checkpoint save or a fault record — never on
    /// their own, so the epoch loop does not pay a manifest write per
    /// epoch.
    pub fn record_epoch_telemetry(&mut self, entry: Json) {
        self.telemetry.push(entry);
    }

    /// Per-epoch telemetry entries (parsed back from the manifest on
    /// reopen; entries buffered after the last manifest rewrite are
    /// memory-only until the next one).
    pub fn telemetry(&self) -> &[Json] {
        &self.telemetry
    }
}

/// One per-epoch telemetry entry for [`RunStore::record_epoch_telemetry`]
/// — the numbers the coordinator's epoch loop knows as the epoch closes.
/// Telemetry only: these values are *read from* training state, never fed
/// back into it.
pub fn epoch_telemetry_json(
    epoch: usize,
    loss: f64,
    lr: f64,
    wire_bytes: u64,
    max_dev_secs: f64,
    modeled_secs: f64,
    wall_secs: f64,
) -> Json {
    json::obj(vec![
        ("epoch", json::num(epoch as f64)),
        ("loss", json::num(loss)),
        ("lr", json::num(lr)),
        ("wire_bytes", json::num(wire_bytes as f64)),
        ("max_dev_secs", json::num(max_dev_secs)),
        ("modeled_secs", json::num(modeled_secs)),
        ("wall_secs", json::num(wall_secs)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("nomad_run_store").join(name);
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn demo_state(epochs_done: usize, n: usize, r: usize) -> CheckpointState {
        let mut pos = Vec::with_capacity(n * 2);
        for i in 0..n {
            pos.push(i as f32 * 0.5);
            pos.push(-(i as f32) * 0.25 + epochs_done as f32);
        }
        CheckpointState {
            epochs_done,
            positions: Matrix::from_vec(n, 2, pos),
            means: (0..r)
                .map(|c| MeanEntry {
                    cluster_id: c as u32,
                    mean: [c as f32, -(c as f32)],
                    weight: 0.5 + c as f32,
                })
                .collect(),
            loss_history: (0..epochs_done).map(|e| 1.0 / (e as f64 + 1.5)).collect(),
            fingerprint: 0xDEAD_BEEF,
        }
    }

    fn demo_store(name: &str) -> RunStore {
        RunStore::create(&tmp(name), 0xDEAD_BEEF, json::obj(vec![("note", json::s("t"))]))
            .unwrap()
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let mut store = demo_store("roundtrip");
        let st = demo_state(4, 30, 3);
        store.save(&st, &SaveOpts::default()).unwrap();
        assert_eq!(store.checkpoints(), &[4]);
        let back = store.load(4).unwrap();
        assert_eq!(back.epochs_done, 4);
        assert_eq!(back.positions.data, st.positions.data, "positions bitwise");
        assert_eq!(back.means, st.means, "means bitwise");
        for (a, b) in back.loss_history.iter().zip(&st.loss_history) {
            assert_eq!(a.to_bits(), b.to_bits(), "loss history bitwise");
        }
        assert_eq!(back.fingerprint, 0xDEAD_BEEF);

        // reopen from disk: manifest carries the list
        let reopened = RunStore::open(store.dir()).unwrap();
        assert_eq!(reopened.latest(), Some(4));
        assert_eq!(reopened.fingerprint(), 0xDEAD_BEEF);
        assert_eq!(reopened.run_info().get("note").as_str(), Some("t"));
        assert!(reopened.load_latest().is_ok());
    }

    #[test]
    fn retention_prunes_oldest() {
        let mut store = demo_store("retention");
        let opts = SaveOpts { retain: 2, ..Default::default() };
        for e in [2usize, 4, 6, 8] {
            store.save(&demo_state(e, 10, 2), &opts).unwrap();
        }
        assert_eq!(store.checkpoints(), &[6, 8]);
        assert!(!store.ckpt_dir(2).exists(), "pruned dir must be gone");
        assert!(!store.ckpt_dir(4).exists());
        assert!(store.ckpt_dir(6).exists());
        // the manifest agrees after reopen
        let re = RunStore::open(store.dir()).unwrap();
        assert_eq!(re.checkpoints(), &[6, 8]);
        assert!(re.load(2).is_err(), "pruned checkpoint must not load");
    }

    #[test]
    fn artifact_materializes_for_the_watcher() {
        let mut store = demo_store("artifact");
        let labels: Vec<u32> = (0..20).map(|i| i % 4).collect();
        let opts = SaveOpts {
            artifact: true,
            labels: Some(&labels),
            dataset: "demo",
            seed: 7,
            ..Default::default()
        };
        store.save(&demo_state(2, 20, 2), &opts).unwrap();
        let art = MapArtifact::load(&store.artifact_dir(2)).unwrap();
        assert_eq!(art.positions.rows, 20);
        assert_eq!(art.labels.as_deref(), Some(&labels[..]));
        assert_eq!(art.provenance.dataset, "demo");
        assert_eq!(art.provenance.epochs, 2);
    }

    #[test]
    fn bit_flip_in_state_is_detected() {
        let mut store = demo_store("bitflip");
        store.save(&demo_state(3, 16, 2), &SaveOpts::default()).unwrap();
        for f in STATE_FILES {
            let p = store.ckpt_dir(3).join(f);
            let orig = std::fs::read(&p).unwrap();
            let mut bad = orig.clone();
            let last = bad.len() - 1;
            bad[last] ^= 0x01; // flip one payload bit
            std::fs::write(&p, &bad).unwrap();
            let e = store.load(3);
            assert!(e.is_err(), "bit flip in {f} must fail the crc check");
            assert!(
                e.unwrap_err().to_string().contains("crc"),
                "error should name the crc check"
            );
            std::fs::write(&p, &orig).unwrap(); // restore for the next file
        }
        assert!(store.load(3).is_ok(), "restored state loads again");
    }

    #[test]
    fn truncation_and_missing_files_are_errors() {
        let mut store = demo_store("truncate");
        store.save(&demo_state(3, 16, 2), &SaveOpts::default()).unwrap();
        let p = store.ckpt_dir(3).join("positions.npy");
        let orig = std::fs::read(&p).unwrap();
        std::fs::write(&p, &orig[..orig.len() - 5]).unwrap();
        assert!(store.load(3).is_err(), "truncated npy must fail");
        std::fs::remove_file(&p).unwrap();
        assert!(store.load(3).is_err(), "missing state file must fail");
        assert!(store.load(99).is_err(), "unknown epoch must fail");
    }

    #[test]
    fn missing_manifest_keys_are_errors_not_panics() {
        let mut store = demo_store("badkeys");
        store.save(&demo_state(2, 8, 2), &SaveOpts::default()).unwrap();
        let mpath = store.ckpt_dir(2).join("ckpt.json");
        let orig = std::fs::read_to_string(&mpath).unwrap();
        for key in ["\"epochs_done\"", "\"fingerprint\"", "\"n\"", "\"crc\"", "\"n_clusters\""] {
            let stripped = {
                let v = Json::parse(&orig).unwrap();
                let mut o = v.as_obj().unwrap().clone();
                o.remove(key.trim_matches('"'));
                Json::Obj(o).pretty()
            };
            std::fs::write(&mpath, &stripped).unwrap();
            assert!(store.load(2).is_err(), "missing {key} must be an error");
        }
        // garbage JSON
        std::fs::write(&mpath, "{not json").unwrap();
        assert!(store.load(2).is_err());
        std::fs::write(&mpath, &orig).unwrap();
        assert!(store.load(2).is_ok());
    }

    #[test]
    fn create_refuses_to_clobber_and_open_rejects_garbage() {
        let dir = tmp("clobber");
        let _ = RunStore::create(&dir, 1, Json::Null).unwrap();
        assert!(RunStore::create(&dir, 1, Json::Null).is_err(), "no silent clobber");
        // wrong format marker
        let dir2 = tmp("badformat");
        std::fs::create_dir_all(&dir2).unwrap();
        std::fs::write(dir2.join("run.json"), r#"{"format": "other", "version": 1}"#).unwrap();
        assert!(RunStore::open(&dir2).is_err());
        // missing entirely
        assert!(RunStore::open(&tmp("missing")).is_err());
    }

    #[test]
    fn load_latest_valid_skips_a_torn_checkpoint() {
        let mut store = demo_store("torn");
        store.save(&demo_state(2, 16, 2), &SaveOpts::default()).unwrap();
        store.save(&demo_state(4, 16, 2), &SaveOpts::default()).unwrap();
        // tear the newest write: truncate its positions payload
        let p = store.ckpt_dir(4).join("positions.npy");
        let orig = std::fs::read(&p).unwrap();
        std::fs::write(&p, &orig[..orig.len() - 7]).unwrap();
        assert!(store.load_latest().is_err(), "strict load must still fail");
        let st = store.load_latest_valid().unwrap();
        assert_eq!(st.epochs_done, 2, "must fall back to the older clean checkpoint");
        // tear the older one too: nothing valid remains
        std::fs::write(store.ckpt_dir(2).join("means.npy"), b"NU").unwrap();
        let e = store.load_latest_valid().unwrap_err().to_string();
        assert!(e.contains("no valid checkpoint"), "{e}");
    }

    #[test]
    fn fault_records_survive_the_manifest_roundtrip() {
        let mut store = demo_store("faultlog");
        store.record_fault("timeout", 1, 25, "device 1: epoch deadline expired").unwrap();
        store.record_fault("disconnect", 0, 25, "connection reset by peer").unwrap();
        let re = RunStore::open(store.dir()).unwrap();
        assert_eq!(re.faults().len(), 2);
        assert_eq!(re.faults()[0].get("kind").as_str(), Some("timeout"));
        assert_eq!(re.faults()[0].get("restart_epoch").as_usize(), Some(25));
        assert_eq!(re.faults()[1].get("device").as_usize(), Some(0));
    }

    #[test]
    fn telemetry_entries_survive_the_manifest_roundtrip() {
        let mut store = demo_store("telemetry");
        store.record_epoch_telemetry(epoch_telemetry_json(0, 1.5, 100.0, 64, 0.01, 0.1, 0.2));
        store.record_epoch_telemetry(epoch_telemetry_json(1, 1.25, 99.0, 64, 0.01, 0.1, 0.4));
        // buffered only: a reopen before any manifest rewrite sees nothing
        assert!(RunStore::open(store.dir()).unwrap().telemetry().is_empty());
        // a checkpoint save flushes the buffer into run.json
        store.save(&demo_state(2, 8, 2), &SaveOpts::default()).unwrap();
        let re = RunStore::open(store.dir()).unwrap();
        assert_eq!(re.telemetry().len(), 2);
        assert_eq!(re.telemetry()[0].get("epoch").as_usize(), Some(0));
        assert_eq!(re.telemetry()[0].get("wire_bytes").as_usize(), Some(64));
        assert_eq!(re.telemetry()[1].get("loss").as_f64(), Some(1.25));
    }

    #[test]
    fn save_rejects_inconsistent_state() {
        let mut store = demo_store("inconsistent");
        // loss length != epochs_done
        let mut st = demo_state(4, 8, 2);
        st.loss_history.pop();
        assert!(store.save(&st, &SaveOpts::default()).is_err());
        // non-contiguous means ids
        let mut st = demo_state(4, 8, 2);
        st.means[1].cluster_id = 7;
        assert!(store.save(&st, &SaveOpts::default()).is_err());
        // fingerprint mismatch with the store
        let mut st = demo_state(4, 8, 2);
        st.fingerprint = 1;
        assert!(store.save(&st, &SaveOpts::default()).is_err());
    }
}
