//! Checkpoint/resume run store (DESIGN.md §11).
//!
//! A long training run is made durable by a **run store**: a versioned
//! on-disk directory holding a run-level manifest (`run.json` — the
//! parameters needed to rebuild the run plus the list of live
//! checkpoints) and one subdirectory per checkpoint with crc32-guarded
//! `.npy` state files (positions, the all-gathered means table, the f64
//! loss history) written atomically (tmp dir + rename) every
//! `--checkpoint-every` epochs under a retention policy.
//!
//! # Why resume is bitwise identical
//!
//! Every stochastic stream in training is forked from the run seed by
//! `(device, epoch, block)` — no RNG state survives across epochs — and
//! the index build / PCA init replay deterministically from the same seed
//! and dataset.  The leader's epoch `e+1` therefore depends only on
//! `(positions, means table, loss history, e)` — exactly what a
//! checkpoint stores, exactly (f32/f64 round-trip bitwise through
//! `.npy`).  Resuming from a checkpoint at `epochs_done = e` and running
//! to completion yields final positions and loss history bitwise equal
//! to the uninterrupted run; `tests/checkpoint_resume.rs` proves this
//! property for every checkpoint epoch at 1/2/8 worker threads.
//!
//! A params **fingerprint** (crc32 of a canonical parameter encoding) is
//! recorded in the manifest and every checkpoint; resuming under any
//! different parameterization is an error, not a silent divergence.

pub mod store;

pub use store::{epoch_telemetry_json, RunStore, SaveOpts};

use crate::ann::graph::WeightModel;
use crate::ann::IndexParams;
use crate::bail;
use crate::distributed::MeanEntry;
use crate::embed::{ApproxMode, NomadParams};
use crate::linalg::Matrix;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::viz::png::crc32;

/// Everything the coordinator needs to restart training at a given epoch.
///
/// `epochs_done = e` means epochs `0..e` have completed: `positions` and
/// `means` are the state *after* epoch `e - 1`'s step and all-gather, and
/// `loss_history` holds `e` entries.  Training resumes at epoch index `e`.
#[derive(Clone, Debug)]
pub struct CheckpointState {
    pub epochs_done: usize,
    /// n x 2 global positions (collected from the devices)
    pub positions: Matrix,
    /// the all-gathered means table, sorted by cluster id (= 0..R)
    pub means: Vec<MeanEntry>,
    /// per-epoch weight-normalized losses, one per completed epoch
    pub loss_history: Vec<f64>,
    /// params fingerprint of the run that wrote this state
    pub fingerprint: u32,
}

/// How the run's dataset was obtained, recorded in `run.json` so
/// `nomad resume` can rebuild it without the original command line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DatasetSpec {
    /// `"synthetic"` (in-tree generator) or `"npy"` (file on disk)
    pub kind: String,
    /// generator name (`arxiv`/`pubmed`/...) or the `.npy` path
    pub source: String,
    /// point count (generator size; validated against an `.npy` reload)
    pub n: usize,
    /// generator seed (unused for `.npy`)
    pub seed: u64,
}

/// Canonical string form of a [`WeightModel`] (run manifests, shard
/// manifests, the params fingerprint).
pub fn weight_model_str(w: WeightModel) -> &'static str {
    match w {
        WeightModel::InverseRankPaper => "inverse-rank-paper",
        WeightModel::InverseRankForward => "inverse-rank-forward",
        WeightModel::Uniform => "uniform",
    }
}

/// Inverse of [`weight_model_str`].
pub fn weight_model_parse(s: &str) -> Result<WeightModel> {
    Ok(match s {
        "inverse-rank-paper" => WeightModel::InverseRankPaper,
        "inverse-rank-forward" => WeightModel::InverseRankForward,
        "uniform" => WeightModel::Uniform,
        other => bail!("unknown weight model '{other}'"),
    })
}

fn approx_str(a: ApproxMode) -> &'static str {
    match a {
        ApproxMode::AllNonSelf => "all-non-self",
        ApproxMode::None => "none",
    }
}

fn approx_parse(s: &str) -> Result<ApproxMode> {
    Ok(match s {
        "all-non-self" => ApproxMode::AllNonSelf,
        "none" => ApproxMode::None,
        other => bail!("unknown approx mode '{other}'"),
    })
}

/// crc32 of a canonical encoding of every parameter that shapes the
/// numerics of a run.  Two runs with equal fingerprints over the same
/// dataset replay bitwise identically; resuming across a mismatch is
/// refused by [`crate::coordinator::NomadCoordinator::resume_from`].
///
/// Deliberately excluded: `n_devices` and thread counts (results are
/// bitwise invariant to both — see `tests/determinism.rs` and
/// `tests/gather_engine.rs`), backend kind (native and XLA must agree
/// numerically by contract), and anything snapshot/IO related.
pub fn params_fingerprint(n: usize, p: &NomadParams, idx: &IndexParams) -> u32 {
    let canon = format!(
        "nomad-fp-v1|n={n}|k={}|negs={}|m_noise={}|epochs={}|lr={:?}|wm={}|approx={}\
         |exag={}|exag_epochs={}|pca={}|init_std={}|seed={}\
         |idx.clusters={}|idx.k={}|idx.iters={}|idx.tol={}|idx.maxc={}",
        p.k,
        p.negs,
        p.m_noise,
        p.epochs,
        p.lr_initial,
        weight_model_str(p.weight_model),
        approx_str(p.approx),
        p.exaggeration,
        p.exaggeration_epochs,
        p.pca_init,
        p.init_std,
        p.seed,
        idx.n_clusters,
        idx.k,
        idx.max_iters,
        idx.tol_frac,
        idx.max_cluster_size,
    );
    crc32(canon.as_bytes())
}

/// Serialize the full run description (params + index + device count +
/// dataset spec) into the `"run"` field of `run.json`.
pub fn run_info_json(
    n: usize,
    n_devices: usize,
    p: &NomadParams,
    idx: &IndexParams,
    ds: &DatasetSpec,
) -> Json {
    json::obj(vec![
        ("n", json::num(n as f64)),
        ("n_devices", json::num(n_devices as f64)),
        (
            "params",
            json::obj(vec![
                ("k", json::num(p.k as f64)),
                ("negs", json::num(p.negs as f64)),
                ("m_noise", json::num(p.m_noise)),
                ("epochs", json::num(p.epochs as f64)),
                (
                    "lr_initial",
                    match p.lr_initial {
                        Some(lr) => json::num(lr),
                        None => Json::Null,
                    },
                ),
                ("weight_model", json::s(weight_model_str(p.weight_model))),
                ("approx", json::s(approx_str(p.approx))),
                ("exaggeration", json::num(p.exaggeration as f64)),
                ("exaggeration_epochs", json::num(p.exaggeration_epochs as f64)),
                ("pca_init", Json::Bool(p.pca_init)),
                ("init_std", json::num(p.init_std as f64)),
                // seeds are the full u64 range; JSON numbers are f64 and
                // would silently round past 2^53 — store as strings
                ("seed", json::s(&p.seed.to_string())),
            ]),
        ),
        (
            "index",
            json::obj(vec![
                ("n_clusters", json::num(idx.n_clusters as f64)),
                ("k", json::num(idx.k as f64)),
                ("max_iters", json::num(idx.max_iters as f64)),
                ("tol_frac", json::num(idx.tol_frac)),
                ("max_cluster_size", json::num(idx.max_cluster_size as f64)),
            ]),
        ),
        (
            "dataset",
            json::obj(vec![
                ("kind", json::s(&ds.kind)),
                ("source", json::s(&ds.source)),
                ("n", json::num(ds.n as f64)),
                ("seed", json::s(&ds.seed.to_string())),
            ]),
        ),
    ])
}

/// Parse [`run_info_json`]'s output back into run configuration —
/// the `nomad resume` subcommand's way of rebuilding a run from its
/// store alone.  Missing or ill-typed keys are errors (never panics).
pub fn parse_run_info(v: &Json) -> Result<(usize, usize, NomadParams, IndexParams, DatasetSpec)> {
    let n = v.get("n").as_usize().context("run info: n")?;
    let n_devices = v.get("n_devices").as_usize().context("run info: n_devices")?;

    let p = v.get("params");
    let params = NomadParams {
        k: p.get("k").as_usize().context("run info: params.k")?,
        negs: p.get("negs").as_usize().context("run info: params.negs")?,
        m_noise: p.get("m_noise").as_f64().context("run info: params.m_noise")?,
        epochs: p.get("epochs").as_usize().context("run info: params.epochs")?,
        lr_initial: match p.get("lr_initial") {
            Json::Null => None,
            other => Some(other.as_f64().context("run info: params.lr_initial")?),
        },
        weight_model: weight_model_parse(
            p.get("weight_model").as_str().context("run info: params.weight_model")?,
        )?,
        approx: approx_parse(p.get("approx").as_str().context("run info: params.approx")?)?,
        exaggeration: p.get("exaggeration").as_f64().context("run info: params.exaggeration")?
            as f32,
        exaggeration_epochs: p
            .get("exaggeration_epochs")
            .as_usize()
            .context("run info: params.exaggeration_epochs")?,
        pca_init: p.get("pca_init").as_bool().context("run info: params.pca_init")?,
        init_std: p.get("init_std").as_f64().context("run info: params.init_std")? as f32,
        seed: p
            .get("seed")
            .as_str()
            .context("run info: params.seed")?
            .parse::<u64>()
            .context("run info: params.seed u64")?,
    };

    let i = v.get("index");
    let index = IndexParams {
        n_clusters: i.get("n_clusters").as_usize().context("run info: index.n_clusters")?,
        k: i.get("k").as_usize().context("run info: index.k")?,
        max_iters: i.get("max_iters").as_usize().context("run info: index.max_iters")?,
        tol_frac: i.get("tol_frac").as_f64().context("run info: index.tol_frac")?,
        max_cluster_size: i
            .get("max_cluster_size")
            .as_usize()
            .context("run info: index.max_cluster_size")?,
    };

    let d = v.get("dataset");
    let dataset = DatasetSpec {
        kind: d.get("kind").as_str().context("run info: dataset.kind")?.to_string(),
        source: d.get("source").as_str().context("run info: dataset.source")?.to_string(),
        n: d.get("n").as_usize().context("run info: dataset.n")?,
        seed: d
            .get("seed")
            .as_str()
            .context("run info: dataset.seed")?
            .parse::<u64>()
            .context("run info: dataset.seed u64")?,
    };

    Ok((n, n_devices, params, index, dataset))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_params() -> NomadParams {
        NomadParams {
            epochs: 12,
            k: 7,
            negs: 5,
            lr_initial: Some(3.5),
            seed: 99,
            ..Default::default()
        }
    }

    #[test]
    fn run_info_roundtrips() {
        let p = demo_params();
        let idx = IndexParams { n_clusters: 6, k: 7, ..Default::default() };
        let ds = DatasetSpec {
            kind: "synthetic".into(),
            source: "arxiv".into(),
            n: 500,
            seed: 0,
        };
        let doc = run_info_json(500, 3, &p, &idx, &ds);
        // through a serialize/parse cycle, like run.json on disk
        let v = Json::parse(&doc.pretty()).unwrap();
        let (n, dev, p2, idx2, ds2) = parse_run_info(&v).unwrap();
        assert_eq!((n, dev), (500, 3));
        assert_eq!(ds2, ds);
        assert_eq!(
            params_fingerprint(n, &p2, &idx2),
            params_fingerprint(500, &p, &idx),
            "fingerprint must survive the round trip"
        );
        assert_eq!(p2.lr_initial, Some(3.5));
        assert_eq!(p2.weight_model, p.weight_model);
        assert_eq!(p2.approx, p.approx);
    }

    #[test]
    fn full_range_u64_seeds_roundtrip_exactly() {
        // seeds ride through JSON as strings: f64 numbers would round past
        // 2^53 and make a legitimate store unresumable
        for seed in [u64::MAX, (1u64 << 53) + 1, 9007199254740993] {
            let p = NomadParams { seed, ..demo_params() };
            let idx = IndexParams::default();
            let ds = DatasetSpec {
                kind: "synthetic".into(),
                source: "arxiv".into(),
                n: 10,
                seed,
            };
            let v = Json::parse(&run_info_json(10, 1, &p, &idx, &ds).pretty()).unwrap();
            let (_, _, p2, idx2, ds2) = parse_run_info(&v).unwrap();
            assert_eq!(p2.seed, seed, "params seed must be exact");
            assert_eq!(ds2.seed, seed, "dataset seed must be exact");
            assert_eq!(params_fingerprint(10, &p2, &idx2), params_fingerprint(10, &p, &idx));
        }
    }

    #[test]
    fn missing_run_info_keys_are_errors() {
        let p = demo_params();
        let idx = IndexParams::default();
        let ds = DatasetSpec { kind: "npy".into(), source: "x.npy".into(), n: 10, seed: 0 };
        let doc = run_info_json(10, 1, &p, &idx, &ds);
        // drop each top-level section in turn
        for key in ["n", "n_devices", "params", "index", "dataset"] {
            let mut obj = doc.as_obj().unwrap().clone();
            obj.remove(key);
            assert!(
                parse_run_info(&Json::Obj(obj)).is_err(),
                "missing '{key}' must be an error"
            );
        }
        // and a params sub-key
        let mut obj = doc.as_obj().unwrap().clone();
        let mut params = obj.get("params").unwrap().as_obj().unwrap().clone();
        params.remove("seed");
        obj.insert("params".into(), Json::Obj(params));
        assert!(parse_run_info(&Json::Obj(obj)).is_err());
    }

    #[test]
    fn fingerprint_is_sensitive_to_every_numeric_knob() {
        let base = demo_params();
        let idx = IndexParams::default();
        let fp = params_fingerprint(100, &base, &idx);
        let mut cases: Vec<NomadParams> = Vec::new();
        cases.push(NomadParams { k: base.k + 1, ..base.clone() });
        cases.push(NomadParams { negs: base.negs + 1, ..base.clone() });
        cases.push(NomadParams { epochs: base.epochs + 1, ..base.clone() });
        cases.push(NomadParams { seed: base.seed + 1, ..base.clone() });
        cases.push(NomadParams { lr_initial: None, ..base.clone() });
        cases.push(NomadParams { approx: ApproxMode::None, ..base.clone() });
        cases.push(NomadParams { pca_init: !base.pca_init, ..base.clone() });
        for (i, c) in cases.iter().enumerate() {
            assert_ne!(fp, params_fingerprint(100, c, &idx), "case {i} must change fp");
        }
        assert_ne!(fp, params_fingerprint(101, &base, &idx), "n must change fp");
        let idx2 = IndexParams { n_clusters: idx.n_clusters + 1, ..idx.clone() };
        assert_ne!(fp, params_fingerprint(100, &base, &idx2));
        // and stability: same inputs, same fingerprint
        assert_eq!(fp, params_fingerprint(100, &demo_params(), &IndexParams::default()));
    }
}
