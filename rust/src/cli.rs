//! Minimal CLI argument parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; used by the `nomad` binary and the examples.
//! Flags are free-form: subcommands pull what they need through the typed
//! accessors (e.g. the boolean `--quantize-build` consumed by the `nomad`
//! binary's backend selection).
//!
//! Malformed values are **errors**: `--threads abc` used to silently fall
//! back to the default (running single-threaded with no warning); now the
//! typed accessors exit with a message.  The fallible `try_*` variants
//! expose the same checks as `Result` for tests and library callers.

use crate::util::error::Result;
use std::collections::HashMap;
use std::fmt::Display;
use std::str::FromStr;

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an explicit list (tests) — `std::env::args().skip(1)` in
    /// production via [`Args::from_env`].
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Parse `--key`'s value as `T`; `Ok(None)` when the flag is absent,
    /// `Err` when present but unparsable.
    pub fn try_parse<T: FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse::<T>() {
                Ok(t) => Ok(Some(t)),
                Err(e) => Err(crate::util::error::Error::msg(format!(
                    "--{key}: invalid value '{v}' ({e})"
                ))),
            },
        }
    }

    /// Parse a boolean flag: absent -> false; bare `--flag` (stored as
    /// "true") or true/1/yes -> true; false/0/no -> false; anything else
    /// is an error.
    pub fn try_bool(&self, key: &str) -> Result<bool> {
        match self.get(key) {
            None => Ok(false),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(crate::util::error::Error::msg(format!(
                "--{key}: invalid boolean '{v}' (true/false/1/0/yes/no)"
            ))),
        }
    }

    /// Unwrap a typed-accessor result, exiting with the parse message on a
    /// malformed value — the CLI-facing behavior of `usize`/`f64`/`u64`.
    fn require<T>(r: Result<T>) -> T {
        match r {
            Ok(v) => v,
            Err(e) => {
                eprintln!("error: {e}");
                std::process::exit(2);
            }
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        Self::require(self.try_parse::<usize>(key)).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        Self::require(self.try_parse::<f64>(key)).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        Self::require(self.try_parse::<u64>(key)).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        Self::require(self.try_bool(key))
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Bridge a `--threads N` flag to the `NOMAD_THREADS` env var that
    /// [`crate::util::parallel::num_threads`] reads.  Every binary that
    /// accepts the flag (the `nomad` CLI, the examples) calls this once,
    /// right after parsing.  A malformed count (`--threads abc`) is an
    /// error, not a silent fall-through to single-threaded execution.
    pub fn apply_thread_flag(&self) {
        if let Some(t) = Self::require(self.try_parse::<usize>("threads")) {
            std::env::set_var("NOMAD_THREADS", t.to_string());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(sv(&["embed", "--n", "100", "--pca", "--k=7", "out.png"]));
        assert_eq!(a.positional, vec!["embed", "out.png"]);
        assert_eq!(a.usize("n", 0), 100);
        assert_eq!(a.usize("k", 0), 7);
        assert!(a.bool("pca"));
        assert!(!a.bool("missing"));
        assert_eq!(a.usize("absent", 9), 9);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = Args::parse(sv(&["--verbose", "--n", "5"]));
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("n", 0), 5);
    }

    #[test]
    fn malformed_values_are_errors_not_defaults() {
        let a = Args::parse(sv(&["--threads", "abc", "--lr", "fast", "--seed", "-3"]));
        assert!(a.try_parse::<usize>("threads").is_err());
        assert!(a.try_parse::<f64>("lr").is_err());
        assert!(a.try_parse::<u64>("seed").is_err(), "negative u64 must not parse");
        // absent flags stay Ok(None) -> default
        assert_eq!(a.try_parse::<usize>("missing").unwrap(), None);
        let e = a.try_parse::<usize>("threads").unwrap_err().to_string();
        assert!(e.contains("--threads") && e.contains("abc"), "{e}");
    }

    #[test]
    fn eq_form_parses_and_errors_like_space_form() {
        let a = Args::parse(sv(&["--workers=8", "--port=http", "--cache=0"]));
        assert_eq!(a.try_parse::<usize>("workers").unwrap(), Some(8));
        assert_eq!(a.try_parse::<usize>("cache").unwrap(), Some(0));
        assert!(a.try_parse::<u16>("port").is_err());
    }

    #[test]
    fn boolean_forms() {
        let a = Args::parse(sv(&[
            "--bare",
            "--yes=yes",
            "--off=false",
            "--zero=0",
            "--bad=maybe",
        ]));
        assert!(a.try_bool("bare").unwrap());
        assert!(a.try_bool("yes").unwrap());
        assert!(!a.try_bool("off").unwrap());
        assert!(!a.try_bool("zero").unwrap());
        assert!(!a.try_bool("absent").unwrap());
        assert!(a.try_bool("bad").is_err());
    }
}
