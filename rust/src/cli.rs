//! Minimal CLI argument parser (no `clap` offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; used by the `nomad` binary and the examples.

use std::collections::HashMap;

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an explicit list (tests) — `std::env::args().skip(1)` in
    /// production via [`Args::from_env`].
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Bridge a `--threads N` flag to the `NOMAD_THREADS` env var that
    /// [`crate::util::parallel::num_threads`] reads.  Every binary that
    /// accepts the flag (the `nomad` CLI, the examples) calls this once,
    /// right after parsing.
    pub fn apply_thread_flag(&self) {
        if let Some(t) = self.get("threads") {
            std::env::set_var("NOMAD_THREADS", t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(sv(&["embed", "--n", "100", "--pca", "--k=7", "out.png"]));
        assert_eq!(a.positional, vec!["embed", "out.png"]);
        assert_eq!(a.usize("n", 0), 100);
        assert_eq!(a.usize("k", 0), 7);
        assert!(a.bool("pca"));
        assert!(!a.bool("missing"));
        assert_eq!(a.usize("absent", 9), 9);
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = Args::parse(sv(&["--verbose", "--n", "5"]));
        assert!(a.bool("verbose"));
        assert_eq!(a.usize("n", 0), 5);
    }
}
