//! Synthetic vector-corpus generators — the dataset substitutions.
//!
//! The paper evaluates on ArXiv (Nomic-Embed), ImageNet (OpenCLIP), PubMed
//! (custom BERT) and Multilingual Wikipedia (BGE-M3) embeddings, none of
//! which are available offline.  These generators produce corpora with the
//! *geometric* properties the evaluation metrics actually measure —
//! cluster structure across scales, anisotropy, power-law cluster sizes —
//! so that neighborhood preservation and random-triplet accuracy remain
//! meaningful and method *orderings* transfer (see DESIGN.md §3).
//!
//! Every generator returns a [`Dataset`] with ground-truth labels at one or
//! more hierarchy levels, which the metrics and the map renderer consume.

pub mod shard;

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// A generated corpus: vectors plus (possibly hierarchical) labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    /// labels[level][i] — level 0 is the coarsest.
    pub labels: Vec<Vec<u32>>,
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows
    }

    pub fn dim(&self) -> usize {
        self.x.cols
    }

    pub fn fine_labels(&self) -> &[u32] {
        self.labels.last().expect("at least one label level")
    }
}

/// Power-law cluster sizes: size_i ∝ (i+1)^{-alpha}, normalized to n with
/// every cluster guaranteed non-empty (each gets 1, the remainder is split
/// proportionally with largest-remainder rounding).
fn power_law_sizes(n: usize, clusters: usize, alpha: f64, rng: &mut Rng) -> Vec<usize> {
    assert!(n >= clusters, "n {n} < clusters {clusters}");
    let mut w: Vec<f64> = (0..clusters).map(|i| ((i + 1) as f64).powf(-alpha)).collect();
    rng.shuffle(&mut w);
    let total: f64 = w.iter().sum();
    let spare = n - clusters;
    let exact: Vec<f64> = w.iter().map(|v| v / total * spare as f64).collect();
    let mut sizes: Vec<usize> = exact.iter().map(|e| 1 + e.floor() as usize).collect();
    let mut left = n - sizes.iter().sum::<usize>();
    // largest remainders get the leftover units
    let mut order: Vec<usize> = (0..clusters).collect();
    order.sort_by(|&a, &b| (exact[b] - exact[b].floor()).total_cmp(&(exact[a] - exact[a].floor())));
    for &i in order.iter().cycle().take(left.min(clusters * 2)) {
        if left == 0 {
            break;
        }
        sizes[i] += 1;
        left -= 1;
    }
    debug_assert_eq!(sizes.iter().sum::<usize>(), n);
    sizes
}

/// Gaussian mixture with anisotropic clusters on a low-dimensional manifold
/// embedded in `dim` — the base generator all corpus analogs use.
///
/// `spread` controls between-cluster distance relative to within-cluster
/// std; `aniso` in [0,1] controls how elongated clusters are.
pub fn gaussian_mixture(
    n: usize,
    dim: usize,
    clusters: usize,
    spread: f32,
    aniso: f32,
    alpha: f64,
    rng: &mut Rng,
) -> Dataset {
    let sizes = power_law_sizes(n, clusters, alpha, rng);
    let mut x = Matrix::zeros(n, dim);
    let mut labels = vec![0u32; n];

    // cluster centers: random gaussian, scaled
    let centers: Vec<Vec<f32>> = (0..clusters)
        .map(|_| (0..dim).map(|_| rng.normal() * spread).collect())
        .collect();
    // per-cluster anisotropic scales
    let scales: Vec<Vec<f32>> = (0..clusters)
        .map(|_| {
            (0..dim)
                .map(|_| 1.0 + aniso * (rng.f32() * 4.0 - 1.0).max(-0.9))
                .collect()
        })
        .collect();

    let mut row = 0;
    for (c, &sz) in sizes.iter().enumerate() {
        for _ in 0..sz {
            let out = x.row_mut(row);
            for d in 0..dim {
                out[d] = centers[c][d] + rng.normal() * scales[c][d];
            }
            labels[row] = c as u32;
            row += 1;
        }
    }
    // shuffle rows so shards don't trivially align with clusters
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let xs = x.gather(&perm);
    let ls: Vec<u32> = perm.iter().map(|&i| labels[i]).collect();
    Dataset { x: xs, labels: vec![ls], name: format!("gmix_n{n}_d{dim}_c{clusters}") }
}

/// ArXiv-abstract-embedding analog: many topical clusters, power-law sizes,
/// moderately anisotropic, 256-d.
pub fn text_corpus_like(n: usize, rng: &mut Rng) -> Dataset {
    let mut d = gaussian_mixture(n, 256, 96.min(n / 20).max(4), 6.0, 0.6, 0.9, rng);
    d.name = format!("arxiv_like_n{n}");
    d
}

/// ImageNet/OpenCLIP analog: class/superclass hierarchy, 64-d (CLIP-style
/// geometry after PCA whitening), tighter clusters.
pub fn image_corpus_like(n: usize, rng: &mut Rng) -> Dataset {
    let supers = 12.min(n / 50).max(2);
    let per_super = 8;
    let d = hierarchical(n, 64, &[supers, per_super], 8.0, 3.0, rng);
    Dataset { name: format!("imagenet_like_n{n}"), ..d }
}

/// PubMed analog: one dominant manifold with many overlapping subclusters —
/// the hardest case for NP@k (the paper's Table 1 scores are ~6%).
pub fn pubmed_like(n: usize, rng: &mut Rng) -> Dataset {
    let mut d = gaussian_mixture(n, 256, 48.min(n / 30).max(4), 2.5, 0.8, 0.7, rng);
    d.name = format!("pubmed_like_n{n}");
    d
}

/// Multilingual-Wikipedia analog: 3-level hierarchy
/// (language -> topic -> article cluster), 64-d.
pub fn wikipedia_like(n: usize, rng: &mut Rng) -> Dataset {
    let langs = 10.min(n / 100).max(2);
    let d = hierarchical(n, 64, &[langs, 6, 5], 10.0, 4.0, rng);
    Dataset { name: format!("wikipedia_like_n{n}"), ..d }
}

/// Generic hierarchical mixture: `branching` gives children per level;
/// level-l centers are sampled around their parent with geometrically
/// decreasing spread (factor `decay`).
pub fn hierarchical(
    n: usize,
    dim: usize,
    branching: &[usize],
    top_spread: f32,
    decay: f32,
    rng: &mut Rng,
) -> Dataset {
    assert!(!branching.is_empty());
    // enumerate leaves of the tree; each leaf is a cluster
    let mut paths: Vec<Vec<usize>> = vec![vec![]];
    for &b in branching {
        let mut next = Vec::with_capacity(paths.len() * b);
        for p in &paths {
            for c in 0..b {
                let mut q = p.clone();
                q.push(c);
                next.push(q);
            }
        }
        paths = next;
    }
    // centers per node, sampled level by level
    let mut leaf_centers: Vec<Vec<f32>> = Vec::with_capacity(paths.len());
    let mut node_centers: std::collections::HashMap<Vec<usize>, Vec<f32>> =
        std::collections::HashMap::new();
    node_centers.insert(vec![], vec![0.0; dim]);
    for p in &paths {
        for l in 1..=p.len() {
            let key = p[..l].to_vec();
            if !node_centers.contains_key(&key) {
                let parent = node_centers[&p[..l - 1]].clone();
                let spread = top_spread / decay.powi(l as i32 - 1);
                let c: Vec<f32> = parent
                    .iter()
                    .map(|v| v + rng.normal() * spread)
                    .collect();
                node_centers.insert(key, c);
            }
        }
        leaf_centers.push(node_centers[p].clone());
    }

    let leaves = paths.len();
    let sizes = power_law_sizes(n, leaves, 0.8, rng);
    let noise = top_spread / decay.powi(branching.len() as i32);
    let mut x = Matrix::zeros(n, dim);
    let levels = branching.len();
    let mut labels: Vec<Vec<u32>> = vec![vec![0; n]; levels];
    let mut row = 0;
    for (leaf, &sz) in sizes.iter().enumerate() {
        let path = &paths[leaf];
        for _ in 0..sz {
            let out = x.row_mut(row);
            for d in 0..dim {
                out[d] = leaf_centers[leaf][d] + rng.normal() * noise;
            }
            // label at level l = index of the ancestor at that level
            let mut flat = 0usize;
            for l in 0..levels {
                flat = flat * branching[l] + path[l];
                labels[l][row] = flat as u32;
            }
            row += 1;
        }
    }
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let xs = x.gather(&perm);
    let ls: Vec<Vec<u32>> = labels
        .iter()
        .map(|lv| perm.iter().map(|&i| lv[i]).collect())
        .collect();
    Dataset { x: xs, labels: ls, name: format!("hier_n{n}_d{dim}") }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::d2;

    #[test]
    fn sizes_sum_to_n() {
        let mut rng = Rng::new(0);
        for (n, c) in [(100, 7), (1000, 13), (50, 50)] {
            let s = power_law_sizes(n, c, 1.0, &mut rng);
            assert_eq!(s.iter().sum::<usize>(), n);
            assert!(s.iter().all(|&v| v >= 1));
        }
    }

    #[test]
    fn mixture_shapes_and_labels() {
        let mut rng = Rng::new(1);
        let d = gaussian_mixture(500, 16, 8, 5.0, 0.5, 1.0, &mut rng);
        assert_eq!(d.n(), 500);
        assert_eq!(d.dim(), 16);
        assert_eq!(d.labels[0].len(), 500);
        assert!(d.labels[0].iter().all(|&l| l < 8));
        // every cluster non-empty
        let mut seen = vec![false; 8];
        for &l in &d.labels[0] {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn clusters_are_separated() {
        let mut rng = Rng::new(2);
        let ds = gaussian_mixture(600, 32, 4, 20.0, 0.0, 0.0, &mut rng);
        // mean within-cluster distance << mean between-cluster distance
        let mut within = 0.0f64;
        let mut wn = 0;
        let mut between = 0.0f64;
        let mut bn = 0;
        for i in (0..600).step_by(7) {
            for j in (1..600).step_by(11) {
                let dist = d2(ds.x.row(i), ds.x.row(j)) as f64;
                if ds.labels[0][i] == ds.labels[0][j] {
                    within += dist;
                    wn += 1;
                } else {
                    between += dist;
                    bn += 1;
                }
            }
        }
        let w = within / wn.max(1) as f64;
        let b = between / bn.max(1) as f64;
        assert!(b > 3.0 * w, "between {b} within {w}");
    }

    #[test]
    fn hierarchy_levels_consistent() {
        let mut rng = Rng::new(3);
        let ds = hierarchical(400, 16, &[3, 4], 10.0, 3.0, &mut rng);
        assert_eq!(ds.labels.len(), 2);
        // finer labels refine coarser: same fine label => same coarse label
        let mut fine_to_coarse = std::collections::HashMap::new();
        for i in 0..400 {
            let f = ds.labels[1][i];
            let c = ds.labels[0][i];
            let e = fine_to_coarse.entry(f).or_insert(c);
            assert_eq!(*e, c);
        }
        assert!(ds.labels[0].iter().all(|&l| l < 3));
        assert!(ds.labels[1].iter().all(|&l| l < 12));
    }

    #[test]
    fn named_generators_produce_expected_dims() {
        let mut rng = Rng::new(4);
        assert_eq!(text_corpus_like(300, &mut rng).dim(), 256);
        assert_eq!(image_corpus_like(300, &mut rng).dim(), 64);
        assert_eq!(pubmed_like(300, &mut rng).dim(), 256);
        assert_eq!(wikipedia_like(300, &mut rng).dim(), 64);
    }

    #[test]
    fn generation_is_deterministic() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = text_corpus_like(200, &mut r1);
        let b = text_corpus_like(200, &mut r2);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.labels, b.labels);
    }
}
