//! Mmap-backed per-cluster shard files (DESIGN.md §12).
//!
//! `nomad shard` cuts a built index into one record per cluster — the
//! cluster's [`BlockParts`] training topology, *not* the high-dimensional
//! corpus — and writes them back-to-back into `shards.bin` with a JSON
//! manifest (`shards.json`) of per-cluster offsets and crc32s.  A `nomad
//! worker` process opens the set with [`ShardSet::open`] (one `mmap`) and
//! pages in **only the clusters it was assigned**: record slices are
//! touched lazily by [`ShardSet::load_parts`], so a worker's resident set
//! is proportional to its shard, never to the corpus.
//!
//! Determinism: a record stores exactly what
//! [`ClusterBlock::from_parts`](crate::embed::ClusterBlock::from_parts)
//! consumes, with every f32 serialized via `to_le_bytes`.  A block built
//! from a shard record is **identical** to one built in-process from the
//! live index — the bitwise equality of multi-process runs depends on it.
//!
//! ```text
//! shards.json   manifest: format/version, run-shaping params (n, seed,
//!               weight model, index params, dataset spec), and per
//!               cluster {id, n, offset, len, crc}
//! shards.bin    records, each:
//!               magic u32 | cluster_id u32 | n u32 | k u32
//!               | global_ids u32 x n | nbr_idx i32 x n*k | nbr_w f32 x n*k
//! ```

use crate::ann::graph::{EdgeWeights, WeightModel};
use crate::ann::{ClusterIndex, IndexParams};
use crate::checkpoint::{weight_model_parse, weight_model_str, DatasetSpec};
use crate::embed::{BlockParts, ClusterBlock};
use crate::ensure;
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use crate::util::mmap::Mmap;
use crate::viz::png::crc32;
use std::io::Write;
use std::path::Path;

/// Manifest `format` field.
pub const SHARD_FORMAT: &str = "nomad-shards";
/// Manifest (and record) format version.
pub const SHARD_VERSION: usize = 1;
/// Manifest file name inside a shard directory.
pub const MANIFEST_FILE: &str = "shards.json";
/// Data file name inside a shard directory.
pub const DATA_FILE: &str = "shards.bin";
/// Per-record magic ("NSRD" little-endian).
const RECORD_MAGIC: u32 = u32::from_le_bytes(*b"NSRD");
/// Fixed record header: magic + cluster_id + n + k.
const RECORD_HEADER: usize = 16;

/// One cluster's location inside `shards.bin`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterEntry {
    pub id: usize,
    /// real point count
    pub n: usize,
    pub offset: u64,
    pub len: u64,
    pub crc: u32,
}

/// The parsed `shards.json`.
#[derive(Clone, Debug)]
pub struct ShardManifest {
    /// full dataset size
    pub n: usize,
    /// corpus dimensionality (provenance only)
    pub dim: usize,
    /// kNN fanout of the records
    pub k: usize,
    /// run seed the index was built from
    pub seed: u64,
    pub weight_model: WeightModel,
    pub index: IndexParams,
    pub dataset: DatasetSpec,
    /// entries in cluster-id order, one per cluster
    pub clusters: Vec<ClusterEntry>,
}

impl ShardManifest {
    /// Per-cluster real point counts, in cluster-id order (what
    /// [`shard_clusters`](crate::distributed::sharder::shard_clusters)
    /// consumes when the coordinator plans a remote run).
    pub fn sizes(&self) -> Vec<usize> {
        self.clusters.iter().map(|c| c.n).collect()
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("format", json::s(SHARD_FORMAT)),
            ("version", json::num(SHARD_VERSION as f64)),
            ("n", json::num(self.n as f64)),
            ("dim", json::num(self.dim as f64)),
            ("k", json::num(self.k as f64)),
            // u64 seeds ride as strings (JSON numbers are f64 and would
            // round past 2^53), same as the checkpoint store's run.json
            ("seed", json::s(&self.seed.to_string())),
            ("weight_model", json::s(weight_model_str(self.weight_model))),
            (
                "index",
                json::obj(vec![
                    ("n_clusters", json::num(self.index.n_clusters as f64)),
                    ("k", json::num(self.index.k as f64)),
                    ("max_iters", json::num(self.index.max_iters as f64)),
                    ("tol_frac", json::num(self.index.tol_frac)),
                    ("max_cluster_size", json::num(self.index.max_cluster_size as f64)),
                ]),
            ),
            (
                "dataset",
                json::obj(vec![
                    ("kind", json::s(&self.dataset.kind)),
                    ("source", json::s(&self.dataset.source)),
                    ("n", json::num(self.dataset.n as f64)),
                    ("seed", json::s(&self.dataset.seed.to_string())),
                ]),
            ),
            ("data_file", json::s(DATA_FILE)),
            (
                "clusters",
                json::arr(
                    self.clusters
                        .iter()
                        .map(|c| {
                            json::obj(vec![
                                ("id", json::num(c.id as f64)),
                                ("n", json::num(c.n as f64)),
                                ("offset", json::num(c.offset as f64)),
                                ("len", json::num(c.len as f64)),
                                ("crc", json::num(c.crc as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    fn from_json(v: &Json) -> Result<ShardManifest> {
        let format = v.get("format").as_str().context("shard manifest: format")?;
        ensure!(format == SHARD_FORMAT, "not a shard manifest (format '{format}')");
        let version = v.get("version").as_usize().context("shard manifest: version")?;
        ensure!(
            version == SHARD_VERSION,
            "shard manifest version {version}, this build reads v{SHARD_VERSION}"
        );
        let i = v.get("index");
        let d = v.get("dataset");
        let mut clusters = Vec::new();
        let entries = v.get("clusters").as_arr().context("shard manifest: clusters")?;
        for (pos, c) in entries.iter().enumerate() {
            let entry = ClusterEntry {
                id: c.get("id").as_usize().context("cluster: id")?,
                n: c.get("n").as_usize().context("cluster: n")?,
                offset: c.get("offset").as_f64().context("cluster: offset")? as u64,
                len: c.get("len").as_f64().context("cluster: len")? as u64,
                crc: c.get("crc").as_f64().context("cluster: crc")? as u32,
            };
            ensure!(entry.id == pos, "cluster entries out of order: {} at {pos}", entry.id);
            clusters.push(entry);
        }
        Ok(ShardManifest {
            n: v.get("n").as_usize().context("shard manifest: n")?,
            dim: v.get("dim").as_usize().context("shard manifest: dim")?,
            k: v.get("k").as_usize().context("shard manifest: k")?,
            seed: v
                .get("seed")
                .as_str()
                .context("shard manifest: seed")?
                .parse::<u64>()
                .context("shard manifest: seed u64")?,
            weight_model: weight_model_parse(
                v.get("weight_model").as_str().context("shard manifest: weight_model")?,
            )?,
            index: IndexParams {
                n_clusters: i.get("n_clusters").as_usize().context("index: n_clusters")?,
                k: i.get("k").as_usize().context("index: k")?,
                max_iters: i.get("max_iters").as_usize().context("index: max_iters")?,
                tol_frac: i.get("tol_frac").as_f64().context("index: tol_frac")?,
                max_cluster_size: i
                    .get("max_cluster_size")
                    .as_usize()
                    .context("index: max_cluster_size")?,
            },
            dataset: DatasetSpec {
                kind: d.get("kind").as_str().context("dataset: kind")?.to_string(),
                source: d.get("source").as_str().context("dataset: source")?.to_string(),
                n: d.get("n").as_usize().context("dataset: n")?,
                seed: d
                    .get("seed")
                    .as_str()
                    .context("dataset: seed")?
                    .parse::<u64>()
                    .context("dataset: seed u64")?,
            },
            clusters,
        })
    }

    /// Load `dir/shards.json`.
    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        ShardManifest::from_json(&v).with_context(|| format!("{}", path.display()))
    }
}

// ---------------------------------------------------------------- writer

fn encode_record(parts: &BlockParts) -> Vec<u8> {
    let n = parts.global_ids.len();
    let k = parts.k;
    let mut out = Vec::with_capacity(RECORD_HEADER + 4 * n + 8 * n * k);
    out.extend_from_slice(&RECORD_MAGIC.to_le_bytes());
    out.extend_from_slice(&parts.cluster_id.to_le_bytes());
    out.extend_from_slice(&(n as u32).to_le_bytes());
    out.extend_from_slice(&(k as u32).to_le_bytes());
    for &g in &parts.global_ids {
        out.extend_from_slice(&g.to_le_bytes());
    }
    for &j in &parts.nbr_idx {
        out.extend_from_slice(&j.to_le_bytes());
    }
    for &w in &parts.nbr_w {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out
}

fn decode_record(bytes: &[u8]) -> Result<BlockParts> {
    ensure!(bytes.len() >= RECORD_HEADER, "shard record truncated ({} bytes)", bytes.len());
    let u32_at = |off: usize| -> Result<u32> {
        let s = bytes
            .get(off..off + 4)
            .with_context(|| format!("shard record truncated at offset {off}"))?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    };
    ensure!(u32_at(0)? == RECORD_MAGIC, "bad shard record magic");
    let cluster_id = u32_at(4)?;
    let n = u32_at(8)? as usize;
    let k = u32_at(12)? as usize;
    let need = RECORD_HEADER
        .checked_add(n.checked_mul(4).context("record size overflows")?)
        .and_then(|v| v.checked_add(n.checked_mul(k)?.checked_mul(8)?))
        .context("record size overflows")?;
    ensure!(
        bytes.len() == need,
        "shard record is {} bytes, header claims {need}",
        bytes.len()
    );
    let mut off = RECORD_HEADER;
    let mut global_ids = Vec::with_capacity(n);
    for _ in 0..n {
        global_ids.push(u32_at(off)?);
        off += 4;
    }
    let mut nbr_idx = Vec::with_capacity(n * k);
    for _ in 0..n * k {
        nbr_idx.push(u32_at(off)? as i32);
        off += 4;
    }
    let mut nbr_w = Vec::with_capacity(n * k);
    for _ in 0..n * k {
        nbr_w.push(f32::from_le_bytes(u32_at(off)?.to_le_bytes()));
        off += 4;
    }
    Ok(BlockParts { cluster_id, global_ids, k, nbr_idx, nbr_w })
}

/// Cut a built index into a shard set at `dir` (created if needed).
/// Atomic like the checkpoint store: data and manifest are written to
/// temp names and renamed, manifest last — a crashed write never leaves a
/// set that parses.
#[allow(clippy::too_many_arguments)]
pub fn write_shards(
    dir: &Path,
    index: &ClusterIndex,
    weights: &EdgeWeights,
    dim: usize,
    seed: u64,
    weight_model: WeightModel,
    index_params: &IndexParams,
    dataset: &DatasetSpec,
) -> Result<ShardManifest> {
    std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
    let data_tmp = dir.join(format!("{DATA_FILE}.tmp"));
    let mut f = std::fs::File::create(&data_tmp)
        .with_context(|| format!("create {}", data_tmp.display()))?;
    let mut clusters = Vec::with_capacity(index.n_clusters());
    let mut offset = 0u64;
    for c in 0..index.n_clusters() {
        let parts = BlockParts::extract(index, weights, c);
        let bytes = encode_record(&parts);
        f.write_all(&bytes)?;
        clusters.push(ClusterEntry {
            id: c,
            n: parts.global_ids.len(),
            offset,
            len: bytes.len() as u64,
            crc: crc32(&bytes),
        });
        offset += bytes.len() as u64;
    }
    f.sync_all().ok();
    drop(f);
    std::fs::rename(&data_tmp, dir.join(DATA_FILE))?;

    let manifest = ShardManifest {
        n: index.n(),
        dim,
        k: index.k,
        seed,
        weight_model,
        index: index_params.clone(),
        dataset: dataset.clone(),
        clusters,
    };
    let man_tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
    std::fs::write(&man_tmp, manifest.to_json().pretty())
        .with_context(|| format!("write {}", man_tmp.display()))?;
    std::fs::rename(&man_tmp, dir.join(MANIFEST_FILE))?;
    Ok(manifest)
}

// ---------------------------------------------------------------- reader

/// An opened shard set: parsed manifest + one read-only mapping of the
/// data file.  Cheap to open; pages of `shards.bin` are faulted in only
/// when a cluster is actually loaded.
pub struct ShardSet {
    pub manifest: ShardManifest,
    data: Mmap,
}

impl ShardSet {
    pub fn open(dir: &Path) -> Result<ShardSet> {
        let manifest = ShardManifest::load(dir)?;
        let data = Mmap::open(&dir.join(DATA_FILE))?;
        // validate the offset table against the mapped length up front so
        // a truncated data file fails at open, not mid-training
        let mut expect = 0u64;
        for c in &manifest.clusters {
            ensure!(
                c.offset == expect,
                "cluster {} record at offset {}, expected {expect}",
                c.id,
                c.offset
            );
            expect += c.len;
        }
        ensure!(
            expect == data.len() as u64,
            "shard data file is {} bytes, manifest accounts for {expect}",
            data.len()
        );
        Ok(ShardSet { manifest, data })
    }

    /// Load one cluster's topology, crc-checking its record slice.
    pub fn load_parts(&self, cluster: usize) -> Result<BlockParts> {
        let entry = self
            .manifest
            .clusters
            .get(cluster)
            .with_context(|| format!("cluster {cluster} not in shard set"))?;
        let lo = entry.offset as usize;
        let hi = lo
            .checked_add(entry.len as usize)
            .with_context(|| format!("cluster {cluster} record extent overflows"))?;
        let bytes = self
            .data
            .bytes()
            .get(lo..hi)
            .with_context(|| format!("cluster {cluster} record {lo}..{hi} outside data file"))?;
        let got = crc32(bytes);
        ensure!(
            got == entry.crc,
            "cluster {cluster} record crc {got:08x} != manifest {:08x} (corrupt shard file)",
            entry.crc
        );
        let parts = decode_record(bytes)?;
        ensure!(
            parts.cluster_id as usize == cluster,
            "record claims cluster {}, manifest slot is {cluster}",
            parts.cluster_id
        );
        Ok(parts)
    }

    /// Load one cluster as a step-ready [`ClusterBlock`] (positions zeroed
    /// — the coordinator ingests them over the wire).
    pub fn load_block(
        &self,
        cluster: usize,
        n_total: usize,
        m_noise: f64,
        negs: usize,
    ) -> Result<ClusterBlock> {
        Ok(ClusterBlock::from_parts(self.load_parts(cluster)?, None, n_total, m_noise, negs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::ann::graph::edge_weights;
    use crate::data::gaussian_mixture;
    use crate::util::rng::Rng;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("nomad_shard_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn build_set(name: &str, n: usize) -> (std::path::PathBuf, ClusterIndex, EdgeWeights) {
        let mut rng = Rng::new(3);
        let ds = gaussian_mixture(n, 8, 4, 8.0, 0.2, 0.5, &mut rng);
        let ip = IndexParams { n_clusters: 4, k: 5, ..Default::default() };
        let idx = ClusterIndex::build(&ds.x, &ip, &NativeBackend::default(), &mut rng);
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        let dir = tmp_dir(name);
        let spec = DatasetSpec { kind: "synthetic".into(), source: "test".into(), n, seed: 3 };
        write_shards(&dir, &idx, &ew, 8, 3, WeightModel::InverseRankForward, &ip, &spec)
            .unwrap();
        (dir, idx, ew)
    }

    #[test]
    fn roundtrip_every_cluster_bitwise() {
        let (dir, idx, ew) = build_set("roundtrip", 400);
        let set = ShardSet::open(&dir).unwrap();
        assert_eq!(set.manifest.clusters.len(), idx.n_clusters());
        assert_eq!(set.manifest.n, 400);
        for c in 0..idx.n_clusters() {
            let live = BlockParts::extract(&idx, &ew, c);
            let loaded = set.load_parts(c).unwrap();
            assert_eq!(live, loaded, "cluster {c} must round-trip exactly");
            // and through to a step-ready block
            let block = set.load_block(c, 400, 5.0, 4).unwrap();
            assert_eq!(block.n_real, live.global_ids.len());
            assert_eq!(block.nbr_w[..block.n_real * block.k], live.nbr_w[..]);
        }
        assert_eq!(set.manifest.sizes(), idx.clusters.iter().map(|c| c.len()).collect::<Vec<_>>());
    }

    #[test]
    fn corrupt_record_byte_fails_crc() {
        let (dir, _, _) = build_set("corrupt", 300);
        let path = dir.join(DATA_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let set = ShardSet::open(&dir).unwrap();
        // exactly one cluster's record covers the flipped byte
        let bad: Vec<usize> =
            (0..set.manifest.clusters.len()).filter(|&c| set.load_parts(c).is_err()).collect();
        assert_eq!(bad.len(), 1, "one corrupt record, errors {bad:?}");
        let e = set.load_parts(bad[0]).unwrap_err().to_string();
        assert!(e.contains("crc"), "{e}");
    }

    #[test]
    fn truncated_data_file_fails_at_open() {
        let (dir, _, _) = build_set("trunc", 300);
        let path = dir.join(DATA_FILE);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 10]).unwrap();
        assert!(ShardSet::open(&dir).is_err());
    }

    #[test]
    fn wrong_version_or_format_rejected() {
        let (dir, _, _) = build_set("version", 300);
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("\"version\": 1", "\"version\": 99")).unwrap();
        let e = ShardSet::open(&dir).unwrap_err().to_string();
        assert!(e.contains("version"), "{e}");
        std::fs::write(&path, text.replace("nomad-shards", "other-format")).unwrap();
        assert!(ShardSet::open(&dir).is_err());
    }

    #[test]
    fn missing_cluster_is_an_error() {
        let (dir, _, _) = build_set("missing", 300);
        let set = ShardSet::open(&dir).unwrap();
        assert!(set.load_parts(set.manifest.clusters.len()).is_err());
    }
}
