//! From-scratch benchmark framework (no `criterion` offline).
//!
//! Provides timed runs with warmup, summary statistics, aligned table
//! printing (the paper-table regenerators in `rust/benches/` use this to
//! print the same rows/series the paper reports), and JSON result dumps to
//! `bench_results/` for EXPERIMENTS.md bookkeeping.

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::Summary;
use std::time::Instant;

/// Time `f` with `warmup` discarded runs and `runs` measured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::of(&samples)
}

/// One measured value in a result table.
#[derive(Clone, Debug)]
pub struct Cell {
    pub text: String,
}

impl From<String> for Cell {
    fn from(text: String) -> Cell {
        Cell { text }
    }
}

impl From<&str> for Cell {
    fn from(text: &str) -> Cell {
        Cell { text: text.to_string() }
    }
}

/// Aligned-table printer + JSON sink.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<Cell>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<Cell>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    /// Print with aligned columns.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.text.len());
            }
        }
        let line = |cells: Vec<&str>| {
            let mut out = String::new();
            for (i, c) in cells.iter().enumerate() {
                out.push_str(&format!("{:w$}  ", c, w = widths[i]));
            }
            println!("{}", out.trim_end());
        };
        line(self.headers.iter().map(|h| h.as_str()).collect());
        line(widths.iter().map(|_| "-").collect::<Vec<_>>());
        for r in &self.rows {
            line(r.iter().map(|c| c.text.as_str()).collect());
        }
    }

    /// Dump to `bench_results/<name>.json`.
    pub fn save_json(&self, name: &str) {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                obj(self
                    .headers
                    .iter()
                    .zip(r)
                    .map(|(h, c)| (h.as_str(), s(&c.text)))
                    .collect())
            })
            .collect();
        let v = obj(vec![("title", s(&self.title)), ("rows", arr(rows))]);
        let _ = std::fs::create_dir_all("bench_results");
        let _ = std::fs::write(format!("bench_results/{name}.json"), v.pretty());
    }
}

/// Schema version of the [`bench_envelope`] wrapper around every
/// `BENCH_*.json` payload.
pub const BENCH_SCHEMA_VERSION: i64 = 1;

/// Wrap a bench payload in the shared result envelope — schema version,
/// bench name, thread budget, and build profile — so every `BENCH_*.json`
/// self-describes the run that produced it and downstream tooling can
/// compare like with like.
pub fn bench_envelope(name: &str, payload: Json) -> Json {
    obj(vec![
        ("schema_version", num(BENCH_SCHEMA_VERSION as f64)),
        ("bench", s(name)),
        ("threads", num(crate::util::parallel::num_threads() as f64)),
        (
            "profile",
            s(if cfg!(debug_assertions) { "debug" } else { "release" }),
        ),
        ("payload", payload),
    ])
}

/// Dump a machine-readable bench payload to
/// `bench_results/BENCH_<name>.json` — the CI smoke run and perf-tracking
/// tooling consume these (shapes, ns/op, speedups), while
/// [`Table::save_json`] keeps the human-oriented table mirror.  The
/// payload lands under the `"payload"` key of the [`bench_envelope`].
pub fn save_bench_json(name: &str, payload: Json) {
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write(
        format!("bench_results/BENCH_{name}.json"),
        bench_envelope(name, payload).pretty(),
    );
}

/// Format seconds human-readably.
pub fn fmt_secs(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

/// Format a mean ± sem pair as a percentage.
pub fn fmt_pct(mean: f64, sem: f64) -> String {
    if sem > 0.0 {
        format!("{:.1}%±{:.1}", mean * 100.0, sem * 100.0)
    } else {
        format!("{:.1}%", mean * 100.0)
    }
}

/// JSON helper re-exports for bench binaries.
pub mod jsonx {
    pub use crate::util::json::{arr, num, obj, s, Json};
}

/// Record an experiment result line to `bench_results/experiments.log`
/// (append-only; EXPERIMENTS.md cites these).
pub fn log_experiment(id: &str, payload: Json) {
    let _ = std::fs::create_dir_all("bench_results");
    let line = obj(vec![("id", s(id)), ("data", payload)]).to_string();
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open("bench_results/experiments.log")
    {
        let _ = writeln!(f, "{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_sane_values() {
        let s = time_fn(1, 5, || {
            std::hint::black_box((0..10_000).sum::<u64>());
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0 && s.mean < 1.0);
    }

    #[test]
    fn table_rows_align() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["x".into(), "123".into()]);
        t.row(vec!["longer".into(), "1".into()]);
        t.print(); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    fn bench_envelope_roundtrips_with_the_schema() {
        let payload = obj(vec![("ns_per_op", num(12.5))]);
        let env = bench_envelope("demo", payload);
        let back = Json::parse(&env.pretty()).unwrap();
        assert_eq!(back.get("schema_version").as_i64(), Some(BENCH_SCHEMA_VERSION));
        assert_eq!(back.get("bench").as_str(), Some("demo"));
        assert!(back.get("threads").as_usize().unwrap() >= 1);
        let profile = back.get("profile").as_str().unwrap();
        assert!(profile == "debug" || profile == "release", "{profile}");
        assert_eq!(back.get("payload").get("ns_per_op").as_f64(), Some(12.5));
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_secs(0.5e-4).ends_with("µs"));
        assert!(fmt_secs(0.5).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
        assert!(fmt_secs(600.0).ends_with("min"));
        assert_eq!(fmt_pct(0.061, 0.003), "6.1%±0.3");
    }
}
