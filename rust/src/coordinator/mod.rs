//! The NOMAD Projection leader (Layer 3's core).
//!
//! `NomadCoordinator::fit` runs the full pipeline of the paper:
//!
//! 1. build the K-Means ANN index (LSH init -> EM -> within-cluster exact
//!    kNN) — §3.2;
//! 2. compute the inverse-rank edge distribution p(j|i) — Eq 6;
//! 3. PCA-initialize the 2-d positions — §3.4;
//! 4. cut clusters into padded [`ClusterBlock`]s and shard them across
//!    devices (Fig 2) — in-process threads, or `nomad worker` processes
//!    dialed over TCP/Unix sockets ([`Placement`]);
//! 5. epoch-synchronous SGD with lr = n/10 linearly annealed to 0, where
//!    each epoch all-gathers only the cluster-mean table — §3.3/§3.4;
//! 6. collect positions, loss curve, snapshots, and communication stats.
//!
//! The epoch loop is placement-blind: it speaks [`DeviceCmd`]/
//! [`DeviceReply`] over a [`DeviceLink`] whichever transport backs it, and
//! every RNG stream is forked from `(device seed, epoch, block)` — so a
//! multi-process run is **bitwise identical** to the in-process run with
//! the same seeds (`tests/multiprocess.rs`, CI worker-smoke).

use crate::ann::backend::AnnBackend;
use crate::ann::graph::{edge_weights, EdgeWeights};
use crate::ann::{ClusterIndex, IndexParams};
use crate::checkpoint::{
    epoch_telemetry_json, params_fingerprint, CheckpointState, RunStore, SaveOpts,
};
use crate::data::shard::ShardManifest;
use crate::data::Dataset;
use crate::distributed::comm_model::{self, CommStats, EpochWork, HwProfile};
use crate::distributed::device::{spawn_device, DeviceCmd, DeviceLink, DeviceReply};
use crate::distributed::fault::{FaultEvent, FaultKind, FaultPlan};
use crate::distributed::proto::{Assignment, WireMsg};
use crate::distributed::sharder::{active_shards, shard_clusters};
use crate::distributed::transport::{connect_with, coordinator_handshake, Endpoint};
use crate::distributed::{MeanEntry, MEAN_ENTRY_BYTES};
use crate::embed::sgd::{Exaggeration, LrSchedule};
use crate::embed::{ApproxMode, ClusterBlock, NomadParams, StepBackend};
use crate::ensure;
use crate::linalg::{pca::pca_init, Matrix};
use crate::obs::metrics;
use crate::obs::trace::{self, COORDINATOR, NO_BLOCK};
use crate::util::clock::{deadline_in, Stopwatch};
use crate::util::error::{Context, Error, Result};
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which step/ANN execution engine devices use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure Rust (always available)
    Native,
    /// AOT XLA artifacts via PJRT; falls back to native per-block when no
    /// artifact bucket matches
    Xla,
}

/// Where the simulated devices live.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Placement {
    /// one thread per device inside this process (the default; `n_devices`
    /// from [`RunConfig`] decides how many)
    #[default]
    InProcess,
    /// one `nomad worker` OS process per device: `endpoints` are dialed in
    /// device order (`host:port` or `unix:/path`), and workers page their
    /// assigned clusters from the shard set at `shards` (written by
    /// `nomad shard`); `RunConfig::n_devices` is ignored — the endpoint
    /// count is the device count
    Remote { endpoints: Vec<String>, shards: PathBuf },
}

/// Run-level configuration (owned by the launcher/CLI, not the paper).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub n_devices: usize,
    pub backend: BackendKind,
    /// collect a positions snapshot every `k` epochs (for quality-vs-time
    /// curves); None disables
    pub snapshot_every: Option<usize>,
    /// index build parameters
    pub index: IndexParams,
    /// thread devices or worker processes
    pub placement: Placement,
    /// print progress lines
    pub verbose: bool,
    /// deadlines + supervised-recovery policy for remote placements
    pub recovery: RecoveryCfg,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_devices: 1,
            backend: BackendKind::Native,
            snapshot_every: None,
            index: IndexParams::default(),
            placement: Placement::InProcess,
            verbose: false,
            recovery: RecoveryCfg::default(),
        }
    }
}

/// Failure-handling policy under [`Placement::Remote`] (DESIGN.md §13).
///
/// Every remote wait is bounded: link reads/writes by `io_timeout`, the
/// per-epoch reply barrier by `epoch_base + epoch_per_block x` the busiest
/// device's block count, and dials by `connect_patience`.  When a link
/// faults, the coordinator classifies the error ([`FaultKind`]), drops the
/// session, rolls back to the newest *valid* checkpoint (or the run's
/// starting state), re-establishes every device — on a recovery attempt a
/// dead endpoint's logical device rotates onto the next surviving endpoint
/// — and replays.  Replayed epochs are bitwise identical because RNG
/// streams are forked from `(seed, logical device, epoch, block)` and the
/// re-placed worker receives the dead device's original assignment.
#[derive(Clone, Debug)]
pub struct RecoveryCfg {
    /// steady-state read/write deadline on every remote link; `None`
    /// blocks forever (not recommended outside debugging)
    pub io_timeout: Option<Duration>,
    /// base of the per-epoch reply deadline
    pub epoch_base: Duration,
    /// per-block slack added to the epoch deadline for the busiest device
    pub epoch_per_block: Duration,
    /// dial patience per endpoint attempt (capped exponential backoff
    /// happens inside [`connect_with`])
    pub connect_patience: Duration,
    /// checkpoint-rollback recoveries before the run gives up with the
    /// last classified fault
    pub max_recoveries: usize,
    /// coordinator-side fault injection, one optional plan per device
    /// link, applied on the *first* establishment only (chaos tests)
    pub fault_plans: Vec<FaultPlan>,
}

impl Default for RecoveryCfg {
    fn default() -> Self {
        RecoveryCfg {
            io_timeout: Some(Duration::from_secs(30)),
            epoch_base: Duration::from_secs(60),
            epoch_per_block: Duration::from_secs(10),
            connect_patience: Duration::from_secs(10),
            max_recoveries: 3,
            fault_plans: Vec::new(),
        }
    }
}

/// Checkpointing policy for a resumable run (DESIGN.md §11).  Owned by
/// the launcher (CLI flags) and handed to
/// [`NomadCoordinator::fit_resumable`]/[`resume_from`](NomadCoordinator::resume_from)
/// together with the [`RunStore`] to write into.
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    /// write a checkpoint every `every` epochs (the final epoch is always
    /// checkpointed too); 0 disables periodic writes entirely
    pub every: usize,
    /// keep only the newest `retain` checkpoints; 0 keeps all
    pub retain: usize,
    /// materialize a `MapArtifact` per checkpoint so
    /// `nomad serve --watch` can preview the run live
    pub artifact: bool,
    /// labels for the artifact preview
    pub labels: Option<Vec<u32>>,
    /// dataset name recorded in artifact provenance
    pub dataset: String,
}

impl Default for CheckpointCfg {
    fn default() -> Self {
        CheckpointCfg {
            every: 25,
            retain: 3,
            artifact: true,
            labels: None,
            dataset: String::new(),
        }
    }
}

/// A positions snapshot taken during training.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub epoch: usize,
    pub wall_secs: f64,
    pub modeled_secs: f64,
    pub positions: Matrix,
}

/// Everything `fit` produces.
pub struct NomadRun {
    pub positions: Matrix,
    pub loss_history: Vec<f64>,
    /// the final all-gathered means table (for determinism checks and warm
    /// restarts)
    pub final_means: Vec<MeanEntry>,
    pub snapshots: Vec<Snapshot>,
    pub comm: CommStats,
    pub index_secs: f64,
    pub train_secs: f64,
    pub modeled_train_secs: f64,
    pub n_clusters: usize,
    pub device_step_secs: Vec<f64>,
    /// epoch-work description of the final epoch (for cost-model
    /// extrapolations in the scaling benches)
    pub last_epoch_work: EpochWork,
}

/// The leader. Construct with [`NomadCoordinator::new`], then [`fit`].
pub struct NomadCoordinator {
    pub params: NomadParams,
    pub run: RunConfig,
    pub hw: HwProfile,
}

impl NomadCoordinator {
    pub fn new(params: NomadParams, run: RunConfig) -> Self {
        NomadCoordinator { params, run, hw: HwProfile::h100() }
    }

    /// Build the index + edges + init for `x` (steps 1–3).  Exposed
    /// separately so benches can reuse an index across configurations.
    pub fn prepare(&self, x: &Matrix, ann: &dyn AnnBackend) -> Prepared {
        let mut rng = Rng::new(self.params.seed);
        let t0 = Stopwatch::start();
        let index = ClusterIndex::build(x, &self.run.index, ann, &mut rng);
        debug_assert!(index.edges_respect_clusters());
        let weights = edge_weights(&index, self.params.weight_model);
        let init = if self.params.pca_init {
            pca_init(x, 2, &mut rng, self.params.init_std)
        } else {
            let mut m = Matrix::zeros(x.rows, 2);
            for v in m.data.iter_mut() {
                *v = rng.normal() * self.params.init_std;
            }
            m
        };
        Prepared { index, weights, init, index_secs: t0.secs() }
    }

    /// Full training run on a dataset.
    pub fn fit(&self, ds: &Dataset, ann: &dyn AnnBackend) -> NomadRun {
        let prep = self.prepare(&ds.x, ann);
        self.fit_prepared(ds.n(), &prep)
    }

    /// Train from a prebuilt index/init (steps 4–6).  Panics on transport
    /// failure under [`Placement::Remote`] — fallible callers (and every
    /// remote driver) should prefer
    /// [`fit_resumable`](NomadCoordinator::fit_resumable) with `sink: None`.
    pub fn fit_prepared(&self, n: usize, prep: &Prepared) -> NomadRun {
        self.run_epochs(n, prep, None, None)
            .expect("in-process fit without a checkpoint sink has no fallible IO")
    }

    /// Train like [`fit_prepared`](NomadCoordinator::fit_prepared), writing
    /// a checkpoint into `sink`'s [`RunStore`] every
    /// [`CheckpointCfg::every`] epochs (and at the final epoch), so the run
    /// can be killed and resumed at any time (DESIGN.md §11).
    pub fn fit_resumable(
        &self,
        n: usize,
        prep: &Prepared,
        sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        self.run_epochs(n, prep, None, sink)
    }

    /// Resume training from a checkpoint.  Requires the *same* dataset,
    /// params, and index config as the original run (enforced via the
    /// params fingerprint) and a `prep` rebuilt from them; produces final
    /// positions and loss history **bitwise identical** to the
    /// uninterrupted run, because every RNG stream is forked from
    /// `(device, epoch, block)` and the checkpoint restores exactly the
    /// leader state epoch `epochs_done` starts from.
    pub fn resume_from(
        &self,
        n: usize,
        prep: &Prepared,
        state: CheckpointState,
        sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        let fp = params_fingerprint(n, &self.params, &self.run.index);
        ensure!(
            state.fingerprint == fp,
            "checkpoint fingerprint {:08x} does not match this run's params ({fp:08x}) — \
             resuming under different parameters would silently diverge",
            state.fingerprint
        );
        self.run_epochs(n, prep, Some(state), sink)
    }

    /// The epoch engine behind `fit_prepared`/`fit_resumable`/`resume_from`:
    /// a supervision loop around [`attempt_session`](Self::attempt_session).
    /// Each attempt establishes every device link and drives the epochs to
    /// completion; on a classified link fault under [`Placement::Remote`]
    /// the supervisor rolls back to the newest valid checkpoint and replays
    /// — bitwise identically — up to [`RecoveryCfg::max_recoveries`] times.
    fn run_epochs(
        &self,
        n: usize,
        prep: &Prepared,
        resume: Option<CheckpointState>,
        mut sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        let p = &self.params;
        let n_clusters = prep.index.n_clusters();

        // ---- sharding (Fig 2) -------------------------------------------
        let sizes: Vec<usize> = prep.index.clusters.iter().map(|c| c.len()).collect();
        let n_devices = match &self.run.placement {
            Placement::InProcess => self.run.n_devices,
            Placement::Remote { endpoints, .. } => endpoints.len(),
        };
        let remote = matches!(self.run.placement, Placement::Remote { .. });
        if remote {
            ensure!(n_devices > 0, "remote placement needs at least one worker endpoint");
        }
        let shards = shard_clusters(&sizes, n_devices);
        // thread budgets divide across the shards that own blocks: when
        // n_devices > n_clusters the empty shards must not hold a share
        let n_active = active_shards(&shards).max(1);

        // fingerprint + resume-state validation (DESIGN.md §11)
        let fp = params_fingerprint(n, p, &self.run.index);
        if let Some(st) = &resume {
            ensure!(st.fingerprint == fp, "checkpoint fingerprint mismatch");
            ensure!(
                st.positions.rows == n && st.positions.cols == 2,
                "checkpoint positions are {}x{}, run has {n} points",
                st.positions.rows,
                st.positions.cols
            );
            ensure!(
                st.means.len() == n_clusters,
                "checkpoint means table has {} clusters, index has {n_clusters}",
                st.means.len()
            );
            ensure!(
                st.epochs_done <= p.epochs,
                "checkpoint is at epoch {} but the run only has {} epochs",
                st.epochs_done,
                p.epochs
            );
            ensure!(
                st.loss_history.len() == st.epochs_done,
                "checkpoint loss history is inconsistent"
            );
        }

        // the shard manifest is validated once, up front: a mismatch is a
        // configuration error, never a recoverable fault
        if let Placement::Remote { shards: shard_dir, .. } = &self.run.placement {
            let manifest = ShardManifest::load(shard_dir)?;
            validate_manifest(&manifest, &sizes, n, p, &self.run.index)?;
        }

        // per-epoch reply deadline, scaled to the busiest device's block
        // count; remote only — an in-process device shares our fate and
        // can only stall by panicking, which surfaces as a channel hangup
        let rec = &self.run.recovery;
        let max_blocks = shards.iter().map(|s| s.len()).max().unwrap_or(0);
        let deadline = remote.then(|| rec.epoch_base + rec.epoch_per_block * max_blocks as u32);

        let base_resume = resume;
        let mut rollback: Option<CheckpointState> = base_resume.clone();
        let mut faults: Vec<FaultEvent> = Vec::new();
        let mut recoveries = 0usize;
        let mut lost_wire = 0u64;
        let t_train = Stopwatch::start();

        loop {
            let (outcome, session_wire) = self.attempt_session(
                n,
                prep,
                &shards,
                n_active,
                fp,
                &rollback,
                &mut sink,
                deadline,
                recoveries == 0,
                t_train,
            );
            let fault = match outcome {
                Ok(out) => {
                    let train_secs = t_train.secs();
                    let comm = CommStats {
                        epochs: p.epochs - out.start_epoch,
                        allgather_bytes_total: out.allgather_bytes,
                        positive_phase_bytes_total: 0,
                        wire_bytes_total: lost_wire + session_wire,
                        wire_epoch_bytes: out.wire_epoch_bytes,
                        modeled_secs_total: out.modeled_total,
                        measured_secs_total: train_secs,
                        faults,
                        recoveries,
                    };
                    // mirror the run totals onto the obs registry, so
                    // `/metrics` and BENCH_distributed.json report from the
                    // same accounting (DESIGN.md §15)
                    metrics::counter("nomad_epochs_total", "Training epochs completed.", &[])
                        .add(comm.epochs as u64);
                    metrics::counter(
                        "nomad_wire_bytes_total",
                        "Wire bytes moved across all device links, both directions.",
                        &[],
                    )
                    .add(comm.wire_bytes_total);
                    metrics::counter(
                        "nomad_allgather_bytes_total",
                        "Modeled means-table all-gather bytes.",
                        &[],
                    )
                    .add(comm.allgather_bytes_total);
                    return Ok(NomadRun {
                        positions: out.positions,
                        loss_history: out.loss_history,
                        final_means: out.means_table,
                        snapshots: out.snapshots,
                        comm,
                        index_secs: prep.index_secs,
                        train_secs,
                        modeled_train_secs: out.modeled_total,
                        n_clusters,
                        device_step_secs: out.device_step_secs,
                        last_epoch_work: out.last_work,
                    });
                }
                Err(SessionErr::Fatal(e)) => return Err(e),
                Err(SessionErr::Fault { device, err }) => {
                    lost_wire += session_wire;
                    (device, err)
                }
            };
            let (device, err) = fault;
            let kind = FaultKind::classify(&err);
            // in-process device faults are process bugs, not infrastructure
            // failures — fail fast instead of replaying a broken binary
            if !remote {
                return Err(err);
            }
            if recoveries >= rec.max_recoveries {
                return Err(err).with_context(|| {
                    format!(
                        "giving up after {recoveries} recovery(ies): device {device} \
                         fault classified {}",
                        kind.name()
                    )
                });
            }
            // roll back to the newest checkpoint that reads back clean
            // (torn writes are skipped), else the state this call started
            // from, else epoch 0
            rollback = match sink.as_mut() {
                Some((store, _)) => store.load_latest_valid().ok().or_else(|| base_resume.clone()),
                None => base_resume.clone(),
            };
            let restart_epoch = rollback.as_ref().map_or(0, |st| st.epochs_done);
            if self.run.verbose {
                eprintln!(
                    "[nomad] device {device} fault ({}): {err}; rolling back to epoch \
                     {restart_epoch}",
                    kind.name()
                );
            }
            faults.push(FaultEvent { kind, device, restart_epoch, detail: err.to_string() });
            metrics::counter(
                "nomad_faults_total",
                "Classified device-link faults.",
                &[("kind", kind.name())],
            )
            .inc();
            metrics::counter("nomad_recoveries_total", "Checkpoint-rollback recoveries.", &[])
                .inc();
            if let Some((store, _)) = sink.as_mut() {
                store.record_fault(kind.name(), device, restart_epoch, &err.to_string())?;
            }
            recoveries += 1;
        }
    }

    /// One establish + drive attempt.  Returns the session outcome plus the
    /// wire bytes this session moved — counted even when it faulted, so
    /// `wire_bytes_total` stays honest across recoveries.
    #[allow(clippy::too_many_arguments)]
    fn attempt_session(
        &self,
        n: usize,
        prep: &Prepared,
        shards: &[Vec<usize>],
        n_active: usize,
        fp: u32,
        rollback: &Option<CheckpointState>,
        sink: &mut Option<(&mut RunStore, &CheckpointCfg)>,
        deadline: Option<Duration>,
        first_attempt: bool,
        t_train: Stopwatch,
    ) -> (std::result::Result<SessionOut, SessionErr>, u64) {
        let p = &self.params;

        // ---- devices: spawn threads, or dial worker processes -----------
        let mut links: Vec<DeviceLink> = match &self.run.placement {
            Placement::InProcess => {
                let n_clusters = prep.index.n_clusters();
                let blocks: Vec<ClusterBlock> = (0..n_clusters)
                    .map(|c| {
                        ClusterBlock::build(
                            &prep.index,
                            &prep.weights,
                            c,
                            &prep.init.data,
                            n,
                            p.m_noise,
                            p.negs,
                        )
                    })
                    .collect();
                let mut block_by_id: Vec<Option<ClusterBlock>> =
                    blocks.into_iter().map(Some).collect();
                let backend_kind = self.run.backend;
                let mut links = Vec::with_capacity(shards.len());
                for (d, shard) in shards.iter().enumerate() {
                    let my_blocks: Vec<ClusterBlock> = shard
                        .iter()
                        .map(|&c| block_by_id[c].take().expect("cluster sharded once"))
                        .collect();
                    let make: Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> =
                        match backend_kind {
                            BackendKind::Native => Box::new(|| {
                                Box::new(crate::embed::native::NativeStepBackend::default())
                                    as Box<dyn StepBackend>
                            }),
                            BackendKind::Xla => xla_step_factory(),
                        };
                    links.push(spawn_device(d, my_blocks, n, p.m_noise, p.seed, n_active, make));
                }
                links
            }
            Placement::Remote { endpoints, .. } => {
                match connect_remote(
                    endpoints,
                    shards,
                    n_active,
                    n,
                    p,
                    &self.run.recovery,
                    first_attempt,
                    self.run.verbose,
                ) {
                    Ok(links) => links,
                    Err((device, err)) => return (Err(SessionErr::Fault { device, err }), 0),
                }
            }
        };

        let out = self.drive_session(&mut links, n, prep, fp, rollback, sink, deadline, t_train);
        if out.is_ok() {
            for link in links.iter_mut() {
                link.stop();
            }
        }
        // a faulted session's links are simply dropped: surviving worker
        // sessions notice the close and exit, and the re-established links
        // start fresh sessions
        let wire = links.iter().map(|l| l.wire_bytes()).sum();
        (out, wire)
    }

    /// Drive one established session from the rollback state to the final
    /// epoch: ingest barrier, epoch loop, snapshots, checkpoints, final
    /// export.  Link errors come back attributed to the device they
    /// surfaced on; checkpoint-store errors are fatal (a rollback could not
    /// write its way out of those either).
    #[allow(clippy::too_many_arguments)]
    fn drive_session(
        &self,
        links: &mut [DeviceLink],
        n: usize,
        prep: &Prepared,
        fp: u32,
        rollback: &Option<CheckpointState>,
        sink: &mut Option<(&mut RunStore, &CheckpointCfg)>,
        deadline: Option<Duration>,
        t_train: Stopwatch,
    ) -> std::result::Result<SessionOut, SessionErr> {
        let p = &self.params;

        // ---- ingest barrier ---------------------------------------------
        // rolled-back/resumed runs load the checkpoint positions; fresh
        // *remote* runs load the init positions (worker blocks start zeroed
        // — positions always travel over the wire, never through the shard
        // files); fresh in-process runs built their blocks from init already
        let ingest: Option<Arc<Vec<f32>>> = match rollback {
            Some(st) => Some(Arc::new(st.positions.data.clone())),
            None => match &self.run.placement {
                Placement::Remote { .. } => Some(Arc::new(prep.init.data.clone())),
                Placement::InProcess => None,
            },
        };
        let start_epoch = rollback.as_ref().map_or(0, |st| st.epochs_done);
        if let Some(table) = ingest {
            let _sp = trace::span(COORDINATOR, start_epoch as u64, NO_BLOCK, "ingest");
            for link in links.iter_mut() {
                let d = link.device;
                link.send_cmd(DeviceCmd::Ingest { positions: Arc::clone(&table) })
                    .map_err(dev_fault(d))?;
            }
            let by = deadline_in(deadline);
            for link in links.iter_mut() {
                let d = link.device;
                match recv_by(link, by).map_err(dev_fault(d))? {
                    DeviceReply::Ingested { .. } => {}
                    other => {
                        return Err(dev_fault(d)(Error::msg(format!(
                            "expected Ingested during barrier, got {other:?}"
                        ))))
                    }
                }
            }
        }

        // initial means table: restored verbatim on rollback/resume (it is
        // the all-gathered table epoch `epochs_done` consumed in the
        // original run), computed from the index + init positions otherwise
        // — deliberately *not* from the blocks, so the remote placement
        // (whose blocks live in worker processes) uses the exact same f64
        // accumulation as [`ClusterBlock::mean`] and stays bitwise equal
        let mut means_table: Vec<MeanEntry> = match rollback {
            Some(st) => st.means.clone(),
            None => initial_means_table(&prep.index, &prep.init.data, n, p),
        };

        // ---- epoch loop -------------------------------------------------
        let lr_sched = LrSchedule::nomad_default(n, p.epochs, p.lr_initial);
        let exag = Exaggeration { factor: p.exaggeration, epochs: p.exaggeration_epochs };
        let mut loss_history = match rollback {
            Some(st) => st.loss_history.clone(),
            None => Vec::with_capacity(p.epochs),
        };
        let mut snapshots = Vec::new();
        let mut allgather_bytes = 0u64;
        let mut wire_epoch_bytes = Vec::new();
        let mut modeled_total = 0.0f64;
        let mut device_step_secs = vec![0.0f64; links.len()];
        let mut last_work = EpochWork::default();
        let mut last_saved: Option<usize> = None;
        let mut wire_before: u64 = links.iter().map(|l| l.wire_bytes()).sum();

        for epoch in start_epoch..p.epochs {
            let lr = lr_sched.at(epoch) as f32;
            let table = Arc::new(means_table.clone());
            {
                let _sp = trace::span(COORDINATOR, epoch as u64, NO_BLOCK, "broadcast");
                for link in links.iter_mut() {
                    let d = link.device;
                    link.send_cmd(DeviceCmd::Epoch {
                        epoch,
                        lr,
                        exaggeration: exag.factor_at(epoch),
                        means: Arc::clone(&table),
                    })
                    .map_err(dev_fault(d))?;
                }
            }
            // every device computes concurrently; replies are drained in
            // link order under one shared deadline and folded in device
            // order, so the f64 accumulation (and thus the loss history)
            // is independent of completion order
            let by = deadline_in(deadline);
            let mut done: Vec<(usize, Vec<MeanEntry>, f64, f64, f64, f64)> =
                Vec::with_capacity(links.len());
            {
                let _sp = trace::span(COORDINATOR, epoch as u64, NO_BLOCK, "comm_wait");
                for link in links.iter_mut() {
                    let d = link.device;
                    match recv_by(link, by).map_err(dev_fault(d))? {
                        DeviceReply::EpochDone {
                            device,
                            means,
                            loss_sum: ls,
                            loss_weight: lw,
                            step_secs,
                            flops,
                        } => {
                            done.push((device, means, ls, lw, step_secs, flops));
                        }
                        other => {
                            return Err(dev_fault(d)(Error::msg(format!(
                                "expected EpochDone, got {other:?}"
                            ))))
                        }
                    }
                }
            }
            let _fold_span = trace::span(COORDINATOR, epoch as u64, NO_BLOCK, "fold");
            done.sort_by_key(|d| d.0);
            let mut loss_sum = 0.0;
            let mut loss_w = 0.0;
            let mut max_dev_flops = 0.0f64;
            let mut total_flops = 0.0f64;
            let mut max_dev_secs = 0.0f64;
            let mut fresh: Vec<MeanEntry> = Vec::with_capacity(means_table.len());
            for (device, means, ls, lw, step_secs, flops) in done {
                loss_sum += ls;
                loss_w += lw;
                max_dev_flops = max_dev_flops.max(flops);
                total_flops += flops;
                max_dev_secs = max_dev_secs.max(step_secs);
                device_step_secs[device] += step_secs;
                fresh.extend(means);
            }
            // all-gather: rebuild the table (weights honour the approx mode)
            fresh.sort_by_key(|e| e.cluster_id);
            if p.approx == ApproxMode::None {
                for e in fresh.iter_mut() {
                    e.weight = 0.0;
                }
            }
            means_table = fresh;
            let bytes = means_table.len() as u64 * MEAN_ENTRY_BYTES * links.len() as u64;
            allgather_bytes += bytes;
            let work = EpochWork {
                max_dev_flops,
                total_flops,
                max_dev_secs,
                allgather_bytes: bytes,
                n_devices: links.len(),
            };
            last_work = work;
            modeled_total += comm_model::epoch_time(&self.hw, &work);
            loss_history.push(epoch_mean_loss(loss_sum, loss_w));
            drop(_fold_span);

            if let Some(every) = self.run.snapshot_every {
                if (epoch + 1) % every == 0 && epoch + 1 < p.epochs {
                    let _sp = trace::span(COORDINATOR, epoch as u64, NO_BLOCK, "snapshot");
                    let positions = collect_positions(links, n, deadline)
                        .map_err(|(device, err)| SessionErr::Fault { device, err })?;
                    snapshots.push(Snapshot {
                        epoch: epoch + 1,
                        wall_secs: t_train.secs(),
                        modeled_secs: modeled_total,
                        positions,
                    });
                }
            }
            // periodic checkpoint: collected positions + the freshly
            // all-gathered means table + the loss history — exactly the
            // leader state epoch `epoch + 1` starts from
            if let Some((store, cfg)) = sink.as_mut() {
                if cfg.every > 0 && (epoch + 1) % cfg.every == 0 {
                    let _sp = trace::span(COORDINATOR, epoch as u64, NO_BLOCK, "checkpoint");
                    let positions = collect_positions(links, n, deadline)
                        .map_err(|(device, err)| SessionErr::Fault { device, err })?;
                    let st = CheckpointState {
                        epochs_done: epoch + 1,
                        positions,
                        means: means_table.clone(),
                        loss_history: loss_history.clone(),
                        fingerprint: fp,
                    };
                    store
                        .save(
                            &st,
                            &SaveOpts {
                                retain: cfg.retain,
                                artifact: cfg.artifact,
                                labels: cfg.labels.as_deref(),
                                dataset: &cfg.dataset,
                                seed: p.seed,
                            },
                        )
                        .map_err(SessionErr::Fatal)?;
                    last_saved = Some(epoch + 1);
                    if self.run.verbose {
                        eprintln!(
                            "[nomad] checkpoint @ epoch {} -> {}",
                            epoch + 1,
                            store.dir().display()
                        );
                    }
                }
            }
            // measured wire traffic this epoch, all links, both directions
            // (snapshot/checkpoint exports land in the epoch they follow)
            let wire_now: u64 = links.iter().map(|l| l.wire_bytes()).sum();
            let wire_delta = wire_now - wire_before;
            wire_epoch_bytes.push(wire_delta);
            wire_before = wire_now;

            // buffer a per-epoch telemetry entry for run.json; pure output
            // — the values above were already computed, nothing reads back
            if let Some((store, _)) = sink.as_mut() {
                store.record_epoch_telemetry(epoch_telemetry_json(
                    epoch,
                    *loss_history.last().unwrap(),
                    lr as f64,
                    wire_delta,
                    max_dev_secs,
                    modeled_total,
                    t_train.secs(),
                ));
            }
            // epoch barrier: spill this thread's span buffer to the sink
            trace::flush_thread();

            if self.run.verbose && (epoch % 25 == 0 || epoch + 1 == p.epochs) {
                eprintln!(
                    "[nomad] epoch {epoch:4} lr {lr:9.2} loss {:.5}",
                    loss_history.last().unwrap()
                );
            }
        }

        let positions = collect_positions(links, n, deadline)
            .map_err(|(device, err)| SessionErr::Fault { device, err })?;

        // final checkpoint, unless the loop already wrote (or the store
        // already holds) one for the last epoch
        if let Some((store, cfg)) = sink.as_mut() {
            if last_saved != Some(p.epochs) && !store.checkpoints().contains(&p.epochs) {
                let st = CheckpointState {
                    epochs_done: p.epochs,
                    positions: positions.clone(),
                    means: means_table.clone(),
                    loss_history: loss_history.clone(),
                    fingerprint: fp,
                };
                store
                    .save(
                        &st,
                        &SaveOpts {
                            retain: cfg.retain,
                            artifact: cfg.artifact,
                            labels: cfg.labels.as_deref(),
                            dataset: &cfg.dataset,
                            seed: p.seed,
                        },
                    )
                    .map_err(SessionErr::Fatal)?;
            }
        }

        Ok(SessionOut {
            start_epoch,
            positions,
            means_table,
            loss_history,
            snapshots,
            device_step_secs,
            modeled_total,
            last_work,
            allgather_bytes,
            wire_epoch_bytes,
        })
    }
}

/// Everything one successfully-completed session hands back to the
/// supervision loop in [`NomadCoordinator::run_epochs`].
struct SessionOut {
    start_epoch: usize,
    positions: Matrix,
    means_table: Vec<MeanEntry>,
    loss_history: Vec<f64>,
    snapshots: Vec<Snapshot>,
    device_step_secs: Vec<f64>,
    modeled_total: f64,
    last_work: EpochWork,
    allgather_bytes: u64,
    wire_epoch_bytes: Vec<u64>,
}

/// How a session attempt failed: a fault on a specific device link (the
/// supervisor may roll back and replay), or a fatal error no recovery can
/// fix (e.g. the checkpoint store refusing writes).
enum SessionErr {
    Fault { device: usize, err: Error },
    Fatal(Error),
}

/// Attribute a link error to its device for the recovery supervisor.
fn dev_fault(device: usize) -> impl Fn(Error) -> SessionErr {
    move |err| SessionErr::Fault { device, err }
}

/// Blocking receive when no deadline applies (in-process), bounded
/// otherwise.
fn recv_by(link: &mut DeviceLink, by: Option<Instant>) -> Result<DeviceReply> {
    match by {
        Some(t) => link.recv_reply_by(t),
        None => link.recv_reply(),
    }
}

/// Weight-normalized epoch loss.  The old `loss_sum / loss_w.max(1.0)`
/// silently divided by 1.0 whenever the total valid weight fell in (0, 1),
/// misreporting tiny shards; and turned an empty epoch into `loss_sum`
/// verbatim.  Exact division when any weight exists, an honest NaN-free
/// 0.0 when none does.
pub fn epoch_mean_loss(loss_sum: f64, loss_w: f64) -> f64 {
    if loss_w > 0.0 {
        loss_sum / loss_w
    } else {
        0.0
    }
}

/// Factory for the `BackendKind::Xla` device backend.
#[cfg(feature = "xla")]
fn xla_step_factory() -> Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> {
    Box::new(|| match crate::runtime::XlaStepBackend::from_env() {
        Ok(b) => Box::new(b) as Box<dyn StepBackend>,
        Err(e) => {
            eprintln!("[nomad] XLA backend unavailable ({e}); using native");
            Box::new(crate::embed::native::NativeStepBackend::default()) as Box<dyn StepBackend>
        }
    })
}

/// Without the `xla` cargo feature the PJRT runtime is not compiled in;
/// `BackendKind::Xla` degrades to the native backend with a notice.
#[cfg(not(feature = "xla"))]
fn xla_step_factory() -> Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> {
    Box::new(|| {
        eprintln!("[nomad] built without the `xla` feature; BackendKind::Xla uses native");
        Box::new(crate::embed::native::NativeStepBackend::default()) as Box<dyn StepBackend>
    })
}

/// Index + edges + init bundle reused across runs.
pub struct Prepared {
    pub index: ClusterIndex,
    pub weights: EdgeWeights,
    pub init: Matrix,
    pub index_secs: f64,
}

/// The pre-epoch-0 means table, computed from the index + init positions
/// with exactly [`ClusterBlock::mean`]'s f64 accumulation (member order =
/// local row order) and [`ClusterBlock::mean_weight`]'s expression — so
/// the coordinator never needs the blocks themselves, which under
/// [`Placement::Remote`] live in worker processes.
fn initial_means_table(
    index: &ClusterIndex,
    init: &[f32],
    n: usize,
    p: &NomadParams,
) -> Vec<MeanEntry> {
    index
        .clusters
        .iter()
        .enumerate()
        .map(|(c, members)| {
            let mut m = [0.0f64; 2];
            for &g in members {
                let g = g as usize;
                m[0] += init[g * 2] as f64;
                m[1] += init[g * 2 + 1] as f64;
            }
            let inv = 1.0 / members.len().max(1) as f64;
            MeanEntry {
                cluster_id: c as u32,
                mean: [(m[0] * inv) as f32, (m[1] * inv) as f32],
                weight: match p.approx {
                    ApproxMode::AllNonSelf => {
                        (p.m_noise * members.len() as f64 / n.max(1) as f64) as f32
                    }
                    ApproxMode::None => 0.0,
                },
            }
        })
        .collect()
}

/// Refuse a shard set that was cut from a different run than the one this
/// coordinator is about to drive — a mismatched worker would train a
/// silently-wrong embedding.
fn validate_manifest(
    m: &ShardManifest,
    sizes: &[usize],
    n: usize,
    p: &NomadParams,
    idx: &IndexParams,
) -> Result<()> {
    ensure!(m.n == n, "shard set holds {} points, this run has {n}", m.n);
    ensure!(m.seed == p.seed, "shard set seed {} != run seed {}", m.seed, p.seed);
    ensure!(
        m.weight_model == p.weight_model,
        "shard set weight model {:?} != run's {:?}",
        m.weight_model,
        p.weight_model
    );
    let same_index = m.index.n_clusters == idx.n_clusters
        && m.index.k == idx.k
        && m.index.max_iters == idx.max_iters
        && m.index.tol_frac == idx.tol_frac
        && m.index.max_cluster_size == idx.max_cluster_size;
    ensure!(same_index, "shard set index params {:?} != run's {:?}", m.index, idx);
    ensure!(
        m.sizes() == sizes,
        "shard set cluster sizes differ from this run's index (stale shard dir?)"
    );
    Ok(())
}

/// Dial a worker for every logical device, handshake, and send its cluster
/// assignment; returns the links once every worker acknowledged.
///
/// On the first establishment device `d` dials endpoint `d` (wrapped in a
/// fault injector when [`RecoveryCfg::fault_plans`] says so).  On recovery
/// attempts it walks the endpoint list starting from its home slot, so a
/// dead worker's logical device rotates onto the next surviving endpoint —
/// which simply serves one more session with the dead device's original
/// assignment, keeping every RNG stream (and therefore the embedding)
/// bitwise identical.  Errors come back attributed to the device that
/// could not be placed.
fn connect_remote(
    endpoints: &[String],
    shards: &[Vec<usize>],
    n_active: usize,
    n: usize,
    p: &NomadParams,
    rec: &RecoveryCfg,
    first_attempt: bool,
    verbose: bool,
) -> std::result::Result<Vec<DeviceLink>, (usize, Error)> {
    let mut links = Vec::with_capacity(shards.len());
    for (d, clusters) in shards.iter().enumerate() {
        let plan = if first_attempt { rec.fault_plans.get(d) } else { None };
        let tries = if first_attempt { 1 } else { endpoints.len() };
        let mut last: Option<Error> = None;
        let mut placed = None;
        for i in 0..tries {
            let spec = &endpoints[(d + i) % endpoints.len()];
            match establish_link(d, spec, plan, clusters, n_active, n, p, rec, verbose) {
                Ok(link) => {
                    placed = Some(link);
                    break;
                }
                Err(e) => last = Some(e),
            }
        }
        match placed {
            Some(link) => links.push(link),
            None => {
                let e = last.expect("at least one endpoint was tried");
                let err = Error::msg(format!("device {d}: no endpoint accepted its assignment: {e}"));
                return Err((d, err));
            }
        }
    }
    Ok(links)
}

/// One dial + handshake + assignment exchange under the recovery deadlines.
#[allow(clippy::too_many_arguments)]
fn establish_link(
    device: usize,
    spec: &str,
    plan: Option<&FaultPlan>,
    clusters: &[usize],
    n_active: usize,
    n: usize,
    p: &NomadParams,
    rec: &RecoveryCfg,
    verbose: bool,
) -> Result<DeviceLink> {
    let ep = Endpoint::parse(spec)?;
    let mut transport = connect_with(&ep, rec.connect_patience, plan)?;
    transport.set_timeouts(rec.io_timeout, rec.io_timeout)?;
    coordinator_handshake(&mut *transport)?;
    transport.send(WireMsg::Assign(Assignment {
        device,
        n_active,
        n_total: n,
        negs: p.negs,
        seed: p.seed,
        m_noise: p.m_noise,
        clusters: clusters.iter().map(|&c| c as u32).collect(),
    }))?;
    match transport.recv()? {
        WireMsg::Assigned { device: got, n_blocks, n_points } => {
            ensure!(got == device, "worker at {ep} answered as device {got}, expected {device}");
            ensure!(
                n_blocks == clusters.len(),
                "worker at {ep} loaded {n_blocks} blocks, assigned {}",
                clusters.len()
            );
            if verbose {
                eprintln!(
                    "[nomad] worker {ep}: device {device}, {n_blocks} blocks, \
                     {n_points} points"
                );
            }
        }
        other => crate::bail!("worker at {ep}: expected Assigned, got {other:?}"),
    }
    Ok(DeviceLink { device, transport, join: None, io_timeout: rec.io_timeout })
}

/// Export and stitch the full positions matrix, one deadline-bounded reply
/// per link; errors are attributed to the device they surfaced on.
fn collect_positions(
    links: &mut [DeviceLink],
    n: usize,
    deadline: Option<Duration>,
) -> std::result::Result<Matrix, (usize, Error)> {
    for link in links.iter_mut() {
        let d = link.device;
        link.send_cmd(DeviceCmd::Export).map_err(|e| (d, e))?;
    }
    let by = deadline_in(deadline);
    let mut m = Matrix::zeros(n, 2);
    for link in links.iter_mut() {
        let d = link.device;
        match recv_by(link, by).map_err(|e| (d, e))? {
            DeviceReply::Exported { positions, .. } => {
                for (g, pos) in positions {
                    let g = g as usize;
                    m.data[g * 2] = pos[0];
                    m.data[g * 2 + 1] = pos[1];
                }
            }
            other => return Err((d, Error::msg(format!("expected Exported, got {other:?}")))),
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::data::gaussian_mixture;

    fn tiny_params(epochs: usize) -> NomadParams {
        NomadParams { epochs, k: 5, negs: 4, ..Default::default() }
    }

    #[test]
    fn fit_runs_and_improves_loss() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(400, 16, 4, 10.0, 0.2, 0.5, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(30),
            RunConfig {
                n_devices: 2,
                index: IndexParams { n_clusters: 4, k: 5, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.positions.rows, 400);
        assert!(run.loss_history.len() == 30);
        let first = run.loss_history[..3].iter().sum::<f64>() / 3.0;
        let last = run.loss_history[27..].iter().sum::<f64>() / 3.0;
        assert!(last < first, "loss should fall: {first} -> {last}");
        // comm: only means cross devices
        assert_eq!(run.comm.positive_phase_bytes_total, 0);
        assert!(run.comm.allgather_bytes_total > 0);
    }

    #[test]
    fn device_count_does_not_change_sharded_results_structure() {
        let mut rng = Rng::new(1);
        let ds = gaussian_mixture(300, 8, 3, 10.0, 0.0, 0.3, &mut rng);
        for n_dev in [1, 3] {
            let coord = NomadCoordinator::new(
                tiny_params(10),
                RunConfig {
                    n_devices: n_dev,
                    index: IndexParams { n_clusters: 3, k: 4, ..Default::default() },
                    ..Default::default()
                },
            );
            let run = coord.fit(&ds, &NativeBackend::default());
            // every point moved from origin (all rows written back)
            let moved = (0..300)
                .filter(|&i| run.positions.row(i).iter().any(|v| *v != 0.0))
                .count();
            assert!(moved > 290, "{moved} rows written");
        }
    }

    #[test]
    fn snapshots_collected() {
        let mut rng = Rng::new(2);
        let ds = gaussian_mixture(200, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(20),
            RunConfig {
                n_devices: 2,
                snapshot_every: Some(5),
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.snapshots.len(), 3); // epochs 5, 10, 15 (20 = final)
        assert!(run.snapshots.windows(2).all(|w| w[0].wall_secs <= w[1].wall_secs));
    }

    #[test]
    fn epoch_mean_loss_divides_exactly_and_handles_empty() {
        // weights in (0, 1) must divide, not fall through a max(1.0) clamp
        assert_eq!(epoch_mean_loss(0.5, 0.25), 2.0);
        assert_eq!(epoch_mean_loss(-3.0, 0.5), -6.0);
        assert_eq!(epoch_mean_loss(4.0, 2.0), 2.0);
        // zero total weight: honest NaN-free zero, not loss_sum verbatim
        let z = epoch_mean_loss(7.0, 0.0);
        assert_eq!(z, 0.0);
        assert!(epoch_mean_loss(0.0, 0.0).is_finite());
    }

    #[test]
    fn more_devices_than_clusters_trains_fine() {
        // 8 spawned devices over ~2 clusters: the empty shards must neither
        // stall the epoch barrier nor hold a slice of the thread budget
        let mut rng = Rng::new(9);
        let ds = gaussian_mixture(240, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(12),
            RunConfig {
                n_devices: 8,
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.positions.rows, 240);
        assert_eq!(run.loss_history.len(), 12);
        assert!(run.loss_history.iter().all(|l| l.is_finite()));
        // every real row was stepped and written back by some device
        let moved = (0..240)
            .filter(|&i| run.positions.row(i).iter().any(|v| *v != 0.0))
            .count();
        assert!(moved > 230, "{moved} rows written");
    }

    #[test]
    fn initial_means_table_matches_block_means_bitwise() {
        // the coordinator computes the pre-epoch-0 table from index + init
        // (remote workers hold the blocks); it must equal the block-derived
        // table bit for bit, or remote runs would diverge at epoch 0
        let mut rng = Rng::new(4);
        let ds = gaussian_mixture(300, 8, 3, 9.0, 0.1, 0.4, &mut rng);
        let params = tiny_params(1);
        let coord = NomadCoordinator::new(
            params.clone(),
            RunConfig {
                index: IndexParams { n_clusters: 3, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        let n = ds.n();
        let from_index = initial_means_table(&prep.index, &prep.init.data, n, &params);
        let mut from_blocks: Vec<MeanEntry> = (0..prep.index.n_clusters())
            .map(|c| {
                let b = ClusterBlock::build(
                    &prep.index,
                    &prep.weights,
                    c,
                    &prep.init.data,
                    n,
                    params.m_noise,
                    params.negs,
                );
                MeanEntry {
                    cluster_id: b.cluster_id,
                    mean: b.mean(),
                    weight: b.mean_weight(n, params.m_noise),
                }
            })
            .collect();
        from_blocks.sort_by_key(|e| e.cluster_id);
        assert_eq!(from_index.len(), from_blocks.len());
        for (a, b) in from_index.iter().zip(&from_blocks) {
            assert_eq!(a.cluster_id, b.cluster_id);
            assert_eq!(a.mean[0].to_bits(), b.mean[0].to_bits());
            assert_eq!(a.mean[1].to_bits(), b.mean[1].to_bits());
            assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        }
    }

    #[test]
    fn exact_mode_disables_mean_negatives() {
        let mut rng = Rng::new(3);
        let ds = gaussian_mixture(200, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let mut params = tiny_params(5);
        params.approx = ApproxMode::None;
        let coord = NomadCoordinator::new(
            params,
            RunConfig {
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert!(run.loss_history.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn remote_with_no_endpoints_fails_fast() {
        // a misconfigured placement is a config error, not a fault to
        // retry: the supervisor must refuse before dialing anything
        let mut rng = Rng::new(5);
        let ds = gaussian_mixture(120, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(2),
            RunConfig {
                placement: Placement::Remote {
                    endpoints: vec![],
                    shards: PathBuf::from("/nonexistent-shard-dir"),
                },
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        let e = coord.fit_resumable(ds.n(), &prep, None).unwrap_err().to_string();
        assert!(e.contains("endpoint"), "{e}");
    }
}
