//! The NOMAD Projection leader (Layer 3's core).
//!
//! `NomadCoordinator::fit` runs the full pipeline of the paper:
//!
//! 1. build the K-Means ANN index (LSH init -> EM -> within-cluster exact
//!    kNN) — §3.2;
//! 2. compute the inverse-rank edge distribution p(j|i) — Eq 6;
//! 3. PCA-initialize the 2-d positions — §3.4;
//! 4. cut clusters into padded [`ClusterBlock`]s and shard them across
//!    devices (Fig 2) — in-process threads, or `nomad worker` processes
//!    dialed over TCP/Unix sockets ([`Placement`]);
//! 5. epoch-synchronous SGD with lr = n/10 linearly annealed to 0, where
//!    each epoch all-gathers only the cluster-mean table — §3.3/§3.4;
//! 6. collect positions, loss curve, snapshots, and communication stats.
//!
//! The epoch loop is placement-blind: it speaks [`DeviceCmd`]/
//! [`DeviceReply`] over a [`DeviceLink`] whichever transport backs it, and
//! every RNG stream is forked from `(device seed, epoch, block)` — so a
//! multi-process run is **bitwise identical** to the in-process run with
//! the same seeds (`tests/multiprocess.rs`, CI worker-smoke).

use crate::ann::backend::AnnBackend;
use crate::ann::graph::{edge_weights, EdgeWeights};
use crate::ann::{ClusterIndex, IndexParams};
use crate::checkpoint::{params_fingerprint, CheckpointState, RunStore, SaveOpts};
use crate::data::shard::ShardManifest;
use crate::data::Dataset;
use crate::distributed::comm_model::{self, CommStats, EpochWork, HwProfile};
use crate::distributed::device::{spawn_device, DeviceCmd, DeviceLink, DeviceReply};
use crate::distributed::proto::{Assignment, WireMsg};
use crate::distributed::sharder::{active_shards, shard_clusters};
use crate::distributed::transport::{connect, coordinator_handshake, Endpoint};
use crate::distributed::{MeanEntry, MEAN_ENTRY_BYTES};
use crate::embed::sgd::{Exaggeration, LrSchedule};
use crate::embed::{ApproxMode, ClusterBlock, NomadParams, StepBackend};
use crate::ensure;
use crate::linalg::{pca::pca_init, Matrix};
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Which step/ANN execution engine devices use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// pure Rust (always available)
    Native,
    /// AOT XLA artifacts via PJRT; falls back to native per-block when no
    /// artifact bucket matches
    Xla,
}

/// Where the simulated devices live.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Placement {
    /// one thread per device inside this process (the default; `n_devices`
    /// from [`RunConfig`] decides how many)
    #[default]
    InProcess,
    /// one `nomad worker` OS process per device: `endpoints` are dialed in
    /// device order (`host:port` or `unix:/path`), and workers page their
    /// assigned clusters from the shard set at `shards` (written by
    /// `nomad shard`); `RunConfig::n_devices` is ignored — the endpoint
    /// count is the device count
    Remote { endpoints: Vec<String>, shards: PathBuf },
}

/// Run-level configuration (owned by the launcher/CLI, not the paper).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub n_devices: usize,
    pub backend: BackendKind,
    /// collect a positions snapshot every `k` epochs (for quality-vs-time
    /// curves); None disables
    pub snapshot_every: Option<usize>,
    /// index build parameters
    pub index: IndexParams,
    /// thread devices or worker processes
    pub placement: Placement,
    /// print progress lines
    pub verbose: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            n_devices: 1,
            backend: BackendKind::Native,
            snapshot_every: None,
            index: IndexParams::default(),
            placement: Placement::InProcess,
            verbose: false,
        }
    }
}

/// Checkpointing policy for a resumable run (DESIGN.md §11).  Owned by
/// the launcher (CLI flags) and handed to
/// [`NomadCoordinator::fit_resumable`]/[`resume_from`](NomadCoordinator::resume_from)
/// together with the [`RunStore`] to write into.
#[derive(Clone, Debug)]
pub struct CheckpointCfg {
    /// write a checkpoint every `every` epochs (the final epoch is always
    /// checkpointed too); 0 disables periodic writes entirely
    pub every: usize,
    /// keep only the newest `retain` checkpoints; 0 keeps all
    pub retain: usize,
    /// materialize a `MapArtifact` per checkpoint so
    /// `nomad serve --watch` can preview the run live
    pub artifact: bool,
    /// labels for the artifact preview
    pub labels: Option<Vec<u32>>,
    /// dataset name recorded in artifact provenance
    pub dataset: String,
}

impl Default for CheckpointCfg {
    fn default() -> Self {
        CheckpointCfg {
            every: 25,
            retain: 3,
            artifact: true,
            labels: None,
            dataset: String::new(),
        }
    }
}

/// A positions snapshot taken during training.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub epoch: usize,
    pub wall_secs: f64,
    pub modeled_secs: f64,
    pub positions: Matrix,
}

/// Everything `fit` produces.
pub struct NomadRun {
    pub positions: Matrix,
    pub loss_history: Vec<f64>,
    /// the final all-gathered means table (for determinism checks and warm
    /// restarts)
    pub final_means: Vec<MeanEntry>,
    pub snapshots: Vec<Snapshot>,
    pub comm: CommStats,
    pub index_secs: f64,
    pub train_secs: f64,
    pub modeled_train_secs: f64,
    pub n_clusters: usize,
    pub device_step_secs: Vec<f64>,
    /// epoch-work description of the final epoch (for cost-model
    /// extrapolations in the scaling benches)
    pub last_epoch_work: EpochWork,
}

/// The leader. Construct with [`NomadCoordinator::new`], then [`fit`].
pub struct NomadCoordinator {
    pub params: NomadParams,
    pub run: RunConfig,
    pub hw: HwProfile,
}

impl NomadCoordinator {
    pub fn new(params: NomadParams, run: RunConfig) -> Self {
        NomadCoordinator { params, run, hw: HwProfile::h100() }
    }

    /// Build the index + edges + init for `x` (steps 1–3).  Exposed
    /// separately so benches can reuse an index across configurations.
    pub fn prepare(&self, x: &Matrix, ann: &dyn AnnBackend) -> Prepared {
        let mut rng = Rng::new(self.params.seed);
        let t0 = Instant::now();
        let index = ClusterIndex::build(x, &self.run.index, ann, &mut rng);
        debug_assert!(index.edges_respect_clusters());
        let weights = edge_weights(&index, self.params.weight_model);
        let init = if self.params.pca_init {
            pca_init(x, 2, &mut rng, self.params.init_std)
        } else {
            let mut m = Matrix::zeros(x.rows, 2);
            for v in m.data.iter_mut() {
                *v = rng.normal() * self.params.init_std;
            }
            m
        };
        Prepared { index, weights, init, index_secs: t0.elapsed().as_secs_f64() }
    }

    /// Full training run on a dataset.
    pub fn fit(&self, ds: &Dataset, ann: &dyn AnnBackend) -> NomadRun {
        let prep = self.prepare(&ds.x, ann);
        self.fit_prepared(ds.n(), &prep)
    }

    /// Train from a prebuilt index/init (steps 4–6).  Panics on transport
    /// failure under [`Placement::Remote`] — fallible callers (and every
    /// remote driver) should prefer
    /// [`fit_resumable`](NomadCoordinator::fit_resumable) with `sink: None`.
    pub fn fit_prepared(&self, n: usize, prep: &Prepared) -> NomadRun {
        self.run_epochs(n, prep, None, None)
            .expect("in-process fit without a checkpoint sink has no fallible IO")
    }

    /// Train like [`fit_prepared`](NomadCoordinator::fit_prepared), writing
    /// a checkpoint into `sink`'s [`RunStore`] every
    /// [`CheckpointCfg::every`] epochs (and at the final epoch), so the run
    /// can be killed and resumed at any time (DESIGN.md §11).
    pub fn fit_resumable(
        &self,
        n: usize,
        prep: &Prepared,
        sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        self.run_epochs(n, prep, None, sink)
    }

    /// Resume training from a checkpoint.  Requires the *same* dataset,
    /// params, and index config as the original run (enforced via the
    /// params fingerprint) and a `prep` rebuilt from them; produces final
    /// positions and loss history **bitwise identical** to the
    /// uninterrupted run, because every RNG stream is forked from
    /// `(device, epoch, block)` and the checkpoint restores exactly the
    /// leader state epoch `epochs_done` starts from.
    pub fn resume_from(
        &self,
        n: usize,
        prep: &Prepared,
        state: CheckpointState,
        sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        let fp = params_fingerprint(n, &self.params, &self.run.index);
        ensure!(
            state.fingerprint == fp,
            "checkpoint fingerprint {:08x} does not match this run's params ({fp:08x}) — \
             resuming under different parameters would silently diverge",
            state.fingerprint
        );
        self.run_epochs(n, prep, Some(state), sink)
    }

    /// The epoch engine behind `fit_prepared`/`fit_resumable`/`resume_from`.
    fn run_epochs(
        &self,
        n: usize,
        prep: &Prepared,
        resume: Option<CheckpointState>,
        mut sink: Option<(&mut RunStore, &CheckpointCfg)>,
    ) -> Result<NomadRun> {
        let p = &self.params;
        let index = &prep.index;
        let n_clusters = index.n_clusters();

        // ---- sharding (Fig 2) -------------------------------------------
        let sizes: Vec<usize> = index.clusters.iter().map(|c| c.len()).collect();
        let n_devices = match &self.run.placement {
            Placement::InProcess => self.run.n_devices,
            Placement::Remote { endpoints, .. } => endpoints.len(),
        };
        let shards = shard_clusters(&sizes, n_devices);
        // thread budgets divide across the shards that own blocks: when
        // n_devices > n_clusters the empty shards must not hold a share
        let n_active = active_shards(&shards).max(1);

        // fingerprint + resume-state validation (DESIGN.md §11)
        let fp = params_fingerprint(n, p, &self.run.index);
        if let Some(st) = &resume {
            ensure!(st.fingerprint == fp, "checkpoint fingerprint mismatch");
            ensure!(
                st.positions.rows == n && st.positions.cols == 2,
                "checkpoint positions are {}x{}, run has {n} points",
                st.positions.rows,
                st.positions.cols
            );
            ensure!(
                st.means.len() == n_clusters,
                "checkpoint means table has {} clusters, index has {n_clusters}",
                st.means.len()
            );
            ensure!(
                st.epochs_done <= p.epochs,
                "checkpoint is at epoch {} but the run only has {} epochs",
                st.epochs_done,
                p.epochs
            );
            ensure!(
                st.loss_history.len() == st.epochs_done,
                "checkpoint loss history is inconsistent"
            );
        }

        // initial means table: restored verbatim on resume (it is the
        // all-gathered table epoch `epochs_done` consumed in the original
        // run), computed from the index + init positions otherwise —
        // deliberately *not* from the blocks, so the remote placement
        // (whose blocks live in worker processes) uses the exact same f64
        // accumulation as [`ClusterBlock::mean`] and stays bitwise equal
        let mut means_table: Vec<MeanEntry> = match &resume {
            Some(st) => st.means.clone(),
            None => initial_means_table(index, &prep.init.data, n, p),
        };

        // ---- devices: spawn threads, or dial worker processes -----------
        let mut links: Vec<DeviceLink> = match &self.run.placement {
            Placement::InProcess => {
                let blocks: Vec<ClusterBlock> = (0..n_clusters)
                    .map(|c| {
                        ClusterBlock::build(
                            index,
                            &prep.weights,
                            c,
                            &prep.init.data,
                            n,
                            p.m_noise,
                            p.negs,
                        )
                    })
                    .collect();
                let mut block_by_id: Vec<Option<ClusterBlock>> =
                    blocks.into_iter().map(Some).collect();
                let backend_kind = self.run.backend;
                let mut links = Vec::with_capacity(shards.len());
                for (d, shard) in shards.iter().enumerate() {
                    let my_blocks: Vec<ClusterBlock> = shard
                        .iter()
                        .map(|&c| block_by_id[c].take().expect("cluster sharded once"))
                        .collect();
                    let make: Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> =
                        match backend_kind {
                            BackendKind::Native => Box::new(|| {
                                Box::new(crate::embed::native::NativeStepBackend::default())
                                    as Box<dyn StepBackend>
                            }),
                            BackendKind::Xla => xla_step_factory(),
                        };
                    links.push(spawn_device(d, my_blocks, n, p.m_noise, p.seed, n_active, make));
                }
                links
            }
            Placement::Remote { endpoints, shards: shard_dir } => {
                let manifest = ShardManifest::load(shard_dir)?;
                validate_manifest(&manifest, &sizes, n, p, &self.run.index)?;
                connect_remote(endpoints, &shards, n_active, n, p, self.run.verbose)?
            }
        };

        // ---- ingest barrier ---------------------------------------------
        // resumed runs load the checkpoint positions; fresh *remote* runs
        // load the init positions (worker blocks start zeroed — positions
        // always travel over the wire, never through the shard files);
        // fresh in-process runs built their blocks from init already
        let ingest: Option<Arc<Vec<f32>>> = match &resume {
            Some(st) => Some(Arc::new(st.positions.data.clone())),
            None => match &self.run.placement {
                Placement::Remote { .. } => Some(Arc::new(prep.init.data.clone())),
                Placement::InProcess => None,
            },
        };
        if let Some(table) = ingest {
            for link in links.iter_mut() {
                link.send_cmd(DeviceCmd::Ingest { positions: Arc::clone(&table) })?;
            }
            for link in links.iter_mut() {
                match link.recv_reply()? {
                    DeviceReply::Ingested { .. } => {}
                    other => crate::bail!("expected Ingested during barrier, got {other:?}"),
                }
            }
        }
        let start_epoch = match &resume {
            Some(st) => st.epochs_done,
            None => 0,
        };

        // ---- epoch loop ---------------------------------------------------
        let lr_sched = LrSchedule::nomad_default(n, p.epochs, p.lr_initial);
        let exag = Exaggeration { factor: p.exaggeration, epochs: p.exaggeration_epochs };
        let mut loss_history = match resume {
            Some(st) => st.loss_history,
            None => Vec::with_capacity(p.epochs),
        };
        let mut snapshots = Vec::new();
        let mut comm = CommStats::default();
        let mut modeled_total = 0.0f64;
        let mut device_step_secs = vec![0.0f64; links.len()];
        let mut last_work = EpochWork::default();
        let mut last_saved: Option<usize> = None;
        let mut wire_before: u64 = links.iter().map(|l| l.wire_bytes()).sum();
        let t_train = Instant::now();

        for epoch in start_epoch..p.epochs {
            let lr = lr_sched.at(epoch) as f32;
            let table = Arc::new(means_table.clone());
            for link in links.iter_mut() {
                link.send_cmd(DeviceCmd::Epoch {
                    epoch,
                    lr,
                    exaggeration: exag.factor_at(epoch),
                    means: Arc::clone(&table),
                })?;
            }
            // every device computes concurrently; replies are drained in
            // link order and folded in device order, so the f64
            // accumulation (and thus the loss history) is independent of
            // completion order
            let mut done: Vec<(usize, Vec<MeanEntry>, f64, f64, f64, f64)> =
                Vec::with_capacity(links.len());
            for link in links.iter_mut() {
                match link.recv_reply()? {
                    DeviceReply::EpochDone {
                        device,
                        means,
                        loss_sum: ls,
                        loss_weight: lw,
                        step_secs,
                        flops,
                    } => {
                        done.push((device, means, ls, lw, step_secs, flops));
                    }
                    other => crate::bail!("expected EpochDone, got {other:?}"),
                }
            }
            done.sort_by_key(|d| d.0);
            let mut loss_sum = 0.0;
            let mut loss_w = 0.0;
            let mut max_dev_flops = 0.0f64;
            let mut total_flops = 0.0f64;
            let mut max_dev_secs = 0.0f64;
            let mut fresh: Vec<MeanEntry> = Vec::with_capacity(means_table.len());
            for (device, means, ls, lw, step_secs, flops) in done {
                loss_sum += ls;
                loss_w += lw;
                max_dev_flops = max_dev_flops.max(flops);
                total_flops += flops;
                max_dev_secs = max_dev_secs.max(step_secs);
                device_step_secs[device] += step_secs;
                fresh.extend(means);
            }
            // all-gather: rebuild the table (weights honour the approx mode)
            fresh.sort_by_key(|e| e.cluster_id);
            if p.approx == ApproxMode::None {
                for e in fresh.iter_mut() {
                    e.weight = 0.0;
                }
            }
            means_table = fresh;
            let bytes = means_table.len() as u64 * MEAN_ENTRY_BYTES * links.len() as u64;
            comm.allgather_bytes_total += bytes;
            let work = EpochWork {
                max_dev_flops,
                total_flops,
                max_dev_secs,
                allgather_bytes: bytes,
                n_devices: links.len(),
            };
            last_work = work;
            modeled_total += comm_model::epoch_time(&self.hw, &work);
            loss_history.push(epoch_mean_loss(loss_sum, loss_w));

            if let Some(every) = self.run.snapshot_every {
                if (epoch + 1) % every == 0 && epoch + 1 < p.epochs {
                    let positions = collect_positions(&mut links, n)?;
                    snapshots.push(Snapshot {
                        epoch: epoch + 1,
                        wall_secs: t_train.elapsed().as_secs_f64(),
                        modeled_secs: modeled_total,
                        positions,
                    });
                }
            }
            // periodic checkpoint: collected positions + the freshly
            // all-gathered means table + the loss history — exactly the
            // leader state epoch `epoch + 1` starts from
            if let Some((store, cfg)) = sink.as_mut() {
                if cfg.every > 0 && (epoch + 1) % cfg.every == 0 {
                    let positions = collect_positions(&mut links, n)?;
                    let st = CheckpointState {
                        epochs_done: epoch + 1,
                        positions,
                        means: means_table.clone(),
                        loss_history: loss_history.clone(),
                        fingerprint: fp,
                    };
                    store.save(
                        &st,
                        &SaveOpts {
                            retain: cfg.retain,
                            artifact: cfg.artifact,
                            labels: cfg.labels.as_deref(),
                            dataset: &cfg.dataset,
                            seed: p.seed,
                        },
                    )?;
                    last_saved = Some(epoch + 1);
                    if self.run.verbose {
                        eprintln!(
                            "[nomad] checkpoint @ epoch {} -> {}",
                            epoch + 1,
                            store.dir().display()
                        );
                    }
                }
            }
            // measured wire traffic this epoch, all links, both directions
            // (snapshot/checkpoint exports land in the epoch they follow)
            let wire_now: u64 = links.iter().map(|l| l.wire_bytes()).sum();
            comm.wire_epoch_bytes.push(wire_now - wire_before);
            wire_before = wire_now;

            if self.run.verbose && (epoch % 25 == 0 || epoch + 1 == p.epochs) {
                eprintln!(
                    "[nomad] epoch {epoch:4} lr {lr:9.2} loss {:.5}",
                    loss_history.last().unwrap()
                );
            }
        }

        let positions = collect_positions(&mut links, n)?;

        // final checkpoint, unless the loop already wrote (or the store
        // already holds) one for the last epoch
        if let Some((store, cfg)) = sink.as_mut() {
            if last_saved != Some(p.epochs) && !store.checkpoints().contains(&p.epochs) {
                let st = CheckpointState {
                    epochs_done: p.epochs,
                    positions: positions.clone(),
                    means: means_table.clone(),
                    loss_history: loss_history.clone(),
                    fingerprint: fp,
                };
                store.save(
                    &st,
                    &SaveOpts {
                        retain: cfg.retain,
                        artifact: cfg.artifact,
                        labels: cfg.labels.as_deref(),
                        dataset: &cfg.dataset,
                        seed: p.seed,
                    },
                )?;
            }
        }

        for link in links.iter_mut() {
            link.stop();
        }
        comm.wire_bytes_total = links.iter().map(|l| l.wire_bytes()).sum();

        let train_secs = t_train.elapsed().as_secs_f64();
        comm.epochs = p.epochs - start_epoch;
        comm.modeled_secs_total = modeled_total;
        comm.measured_secs_total = train_secs;

        Ok(NomadRun {
            positions,
            loss_history,
            final_means: means_table,
            snapshots,
            comm,
            index_secs: prep.index_secs,
            train_secs,
            modeled_train_secs: modeled_total,
            n_clusters,
            device_step_secs,
            last_epoch_work: last_work,
        })
    }
}

/// Weight-normalized epoch loss.  The old `loss_sum / loss_w.max(1.0)`
/// silently divided by 1.0 whenever the total valid weight fell in (0, 1),
/// misreporting tiny shards; and turned an empty epoch into `loss_sum`
/// verbatim.  Exact division when any weight exists, an honest NaN-free
/// 0.0 when none does.
pub fn epoch_mean_loss(loss_sum: f64, loss_w: f64) -> f64 {
    if loss_w > 0.0 {
        loss_sum / loss_w
    } else {
        0.0
    }
}

/// Factory for the `BackendKind::Xla` device backend.
#[cfg(feature = "xla")]
fn xla_step_factory() -> Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> {
    Box::new(|| match crate::runtime::XlaStepBackend::from_env() {
        Ok(b) => Box::new(b) as Box<dyn StepBackend>,
        Err(e) => {
            eprintln!("[nomad] XLA backend unavailable ({e}); using native");
            Box::new(crate::embed::native::NativeStepBackend::default()) as Box<dyn StepBackend>
        }
    })
}

/// Without the `xla` cargo feature the PJRT runtime is not compiled in;
/// `BackendKind::Xla` degrades to the native backend with a notice.
#[cfg(not(feature = "xla"))]
fn xla_step_factory() -> Box<dyn FnOnce() -> Box<dyn StepBackend> + Send> {
    Box::new(|| {
        eprintln!("[nomad] built without the `xla` feature; BackendKind::Xla uses native");
        Box::new(crate::embed::native::NativeStepBackend::default()) as Box<dyn StepBackend>
    })
}

/// Index + edges + init bundle reused across runs.
pub struct Prepared {
    pub index: ClusterIndex,
    pub weights: EdgeWeights,
    pub init: Matrix,
    pub index_secs: f64,
}

/// The pre-epoch-0 means table, computed from the index + init positions
/// with exactly [`ClusterBlock::mean`]'s f64 accumulation (member order =
/// local row order) and [`ClusterBlock::mean_weight`]'s expression — so
/// the coordinator never needs the blocks themselves, which under
/// [`Placement::Remote`] live in worker processes.
fn initial_means_table(
    index: &ClusterIndex,
    init: &[f32],
    n: usize,
    p: &NomadParams,
) -> Vec<MeanEntry> {
    index
        .clusters
        .iter()
        .enumerate()
        .map(|(c, members)| {
            let mut m = [0.0f64; 2];
            for &g in members {
                let g = g as usize;
                m[0] += init[g * 2] as f64;
                m[1] += init[g * 2 + 1] as f64;
            }
            let inv = 1.0 / members.len().max(1) as f64;
            MeanEntry {
                cluster_id: c as u32,
                mean: [(m[0] * inv) as f32, (m[1] * inv) as f32],
                weight: match p.approx {
                    ApproxMode::AllNonSelf => {
                        (p.m_noise * members.len() as f64 / n.max(1) as f64) as f32
                    }
                    ApproxMode::None => 0.0,
                },
            }
        })
        .collect()
}

/// Refuse a shard set that was cut from a different run than the one this
/// coordinator is about to drive — a mismatched worker would train a
/// silently-wrong embedding.
fn validate_manifest(
    m: &ShardManifest,
    sizes: &[usize],
    n: usize,
    p: &NomadParams,
    idx: &IndexParams,
) -> Result<()> {
    ensure!(m.n == n, "shard set holds {} points, this run has {n}", m.n);
    ensure!(m.seed == p.seed, "shard set seed {} != run seed {}", m.seed, p.seed);
    ensure!(
        m.weight_model == p.weight_model,
        "shard set weight model {:?} != run's {:?}",
        m.weight_model,
        p.weight_model
    );
    let same_index = m.index.n_clusters == idx.n_clusters
        && m.index.k == idx.k
        && m.index.max_iters == idx.max_iters
        && m.index.tol_frac == idx.tol_frac
        && m.index.max_cluster_size == idx.max_cluster_size;
    ensure!(same_index, "shard set index params {:?} != run's {:?}", m.index, idx);
    ensure!(
        m.sizes() == sizes,
        "shard set cluster sizes differ from this run's index (stale shard dir?)"
    );
    Ok(())
}

/// Dial each worker endpoint in device order, handshake, and send its
/// cluster assignment; returns the links once every worker acknowledged.
fn connect_remote(
    endpoints: &[String],
    shards: &[Vec<usize>],
    n_active: usize,
    n: usize,
    p: &NomadParams,
    verbose: bool,
) -> Result<Vec<DeviceLink>> {
    ensure!(!endpoints.is_empty(), "remote placement needs at least one worker endpoint");
    let mut links = Vec::with_capacity(endpoints.len());
    for (d, spec) in endpoints.iter().enumerate() {
        let ep = Endpoint::parse(spec)?;
        let mut transport = connect(&ep, Duration::from_secs(10))?;
        coordinator_handshake(&mut *transport)?;
        transport.send(WireMsg::Assign(Assignment {
            device: d,
            n_active,
            n_total: n,
            negs: p.negs,
            seed: p.seed,
            m_noise: p.m_noise,
            clusters: shards[d].iter().map(|&c| c as u32).collect(),
        }))?;
        match transport.recv()? {
            WireMsg::Assigned { device, n_blocks, n_points } => {
                ensure!(device == d, "worker at {ep} answered as device {device}, expected {d}");
                ensure!(
                    n_blocks == shards[d].len(),
                    "worker at {ep} loaded {n_blocks} blocks, assigned {}",
                    shards[d].len()
                );
                if verbose {
                    eprintln!(
                        "[nomad] worker {ep}: device {device}, {n_blocks} blocks, \
                         {n_points} points"
                    );
                }
            }
            other => crate::bail!("worker at {ep}: expected Assigned, got {other:?}"),
        }
        links.push(DeviceLink { device: d, transport, join: None });
    }
    Ok(links)
}

fn collect_positions(links: &mut [DeviceLink], n: usize) -> Result<Matrix> {
    for link in links.iter_mut() {
        link.send_cmd(DeviceCmd::Export)?;
    }
    let mut m = Matrix::zeros(n, 2);
    for link in links.iter_mut() {
        match link.recv_reply()? {
            DeviceReply::Exported { positions, .. } => {
                for (g, p) in positions {
                    let g = g as usize;
                    m.data[g * 2] = p[0];
                    m.data[g * 2 + 1] = p[1];
                }
            }
            other => crate::bail!("expected Exported, got {other:?}"),
        }
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::data::gaussian_mixture;

    fn tiny_params(epochs: usize) -> NomadParams {
        NomadParams { epochs, k: 5, negs: 4, ..Default::default() }
    }

    #[test]
    fn fit_runs_and_improves_loss() {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(400, 16, 4, 10.0, 0.2, 0.5, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(30),
            RunConfig {
                n_devices: 2,
                index: IndexParams { n_clusters: 4, k: 5, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.positions.rows, 400);
        assert!(run.loss_history.len() == 30);
        let first = run.loss_history[..3].iter().sum::<f64>() / 3.0;
        let last = run.loss_history[27..].iter().sum::<f64>() / 3.0;
        assert!(last < first, "loss should fall: {first} -> {last}");
        // comm: only means cross devices
        assert_eq!(run.comm.positive_phase_bytes_total, 0);
        assert!(run.comm.allgather_bytes_total > 0);
    }

    #[test]
    fn device_count_does_not_change_sharded_results_structure() {
        let mut rng = Rng::new(1);
        let ds = gaussian_mixture(300, 8, 3, 10.0, 0.0, 0.3, &mut rng);
        for n_dev in [1, 3] {
            let coord = NomadCoordinator::new(
                tiny_params(10),
                RunConfig {
                    n_devices: n_dev,
                    index: IndexParams { n_clusters: 3, k: 4, ..Default::default() },
                    ..Default::default()
                },
            );
            let run = coord.fit(&ds, &NativeBackend::default());
            // every point moved from origin (all rows written back)
            let moved = (0..300)
                .filter(|&i| run.positions.row(i).iter().any(|v| *v != 0.0))
                .count();
            assert!(moved > 290, "{moved} rows written");
        }
    }

    #[test]
    fn snapshots_collected() {
        let mut rng = Rng::new(2);
        let ds = gaussian_mixture(200, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(20),
            RunConfig {
                n_devices: 2,
                snapshot_every: Some(5),
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.snapshots.len(), 3); // epochs 5, 10, 15 (20 = final)
        assert!(run.snapshots.windows(2).all(|w| w[0].wall_secs <= w[1].wall_secs));
    }

    #[test]
    fn epoch_mean_loss_divides_exactly_and_handles_empty() {
        // weights in (0, 1) must divide, not fall through a max(1.0) clamp
        assert_eq!(epoch_mean_loss(0.5, 0.25), 2.0);
        assert_eq!(epoch_mean_loss(-3.0, 0.5), -6.0);
        assert_eq!(epoch_mean_loss(4.0, 2.0), 2.0);
        // zero total weight: honest NaN-free zero, not loss_sum verbatim
        let z = epoch_mean_loss(7.0, 0.0);
        assert_eq!(z, 0.0);
        assert!(epoch_mean_loss(0.0, 0.0).is_finite());
    }

    #[test]
    fn more_devices_than_clusters_trains_fine() {
        // 8 spawned devices over ~2 clusters: the empty shards must neither
        // stall the epoch barrier nor hold a slice of the thread budget
        let mut rng = Rng::new(9);
        let ds = gaussian_mixture(240, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let coord = NomadCoordinator::new(
            tiny_params(12),
            RunConfig {
                n_devices: 8,
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert_eq!(run.positions.rows, 240);
        assert_eq!(run.loss_history.len(), 12);
        assert!(run.loss_history.iter().all(|l| l.is_finite()));
        // every real row was stepped and written back by some device
        let moved = (0..240)
            .filter(|&i| run.positions.row(i).iter().any(|v| *v != 0.0))
            .count();
        assert!(moved > 230, "{moved} rows written");
    }

    #[test]
    fn initial_means_table_matches_block_means_bitwise() {
        // the coordinator computes the pre-epoch-0 table from index + init
        // (remote workers hold the blocks); it must equal the block-derived
        // table bit for bit, or remote runs would diverge at epoch 0
        let mut rng = Rng::new(4);
        let ds = gaussian_mixture(300, 8, 3, 9.0, 0.1, 0.4, &mut rng);
        let params = tiny_params(1);
        let coord = NomadCoordinator::new(
            params.clone(),
            RunConfig {
                index: IndexParams { n_clusters: 3, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let prep = coord.prepare(&ds.x, &NativeBackend::default());
        let n = ds.n();
        let from_index = initial_means_table(&prep.index, &prep.init.data, n, &params);
        let mut from_blocks: Vec<MeanEntry> = (0..prep.index.n_clusters())
            .map(|c| {
                let b = ClusterBlock::build(
                    &prep.index,
                    &prep.weights,
                    c,
                    &prep.init.data,
                    n,
                    params.m_noise,
                    params.negs,
                );
                MeanEntry {
                    cluster_id: b.cluster_id,
                    mean: b.mean(),
                    weight: b.mean_weight(n, params.m_noise),
                }
            })
            .collect();
        from_blocks.sort_by_key(|e| e.cluster_id);
        assert_eq!(from_index.len(), from_blocks.len());
        for (a, b) in from_index.iter().zip(&from_blocks) {
            assert_eq!(a.cluster_id, b.cluster_id);
            assert_eq!(a.mean[0].to_bits(), b.mean[0].to_bits());
            assert_eq!(a.mean[1].to_bits(), b.mean[1].to_bits());
            assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        }
    }

    #[test]
    fn exact_mode_disables_mean_negatives() {
        let mut rng = Rng::new(3);
        let ds = gaussian_mixture(200, 8, 2, 8.0, 0.0, 0.3, &mut rng);
        let mut params = tiny_params(5);
        params.approx = ApproxMode::None;
        let coord = NomadCoordinator::new(
            params,
            RunConfig {
                index: IndexParams { n_clusters: 2, k: 4, ..Default::default() },
                ..Default::default()
            },
        );
        let run = coord.fit(&ds, &NativeBackend::default());
        assert!(run.loss_history.iter().all(|l| l.is_finite()));
    }
}
