//! Minimal PNG encoder (8-bit RGB, one IDAT), written entirely from
//! scratch for the offline environment: the zlib stream uses *stored*
//! (uncompressed) deflate blocks with an Adler-32 trailer, and chunk CRCs
//! come from a bitwise CRC-32 — no `flate2`/`crc32fast`/image crates.
//! Stored blocks trade file size for zero dependencies; every PNG reader
//! accepts them (BTYPE=00 is mandatory in the deflate spec).

use crate::ensure;
use crate::util::error::Result;
use std::path::Path;

/// Write an RGB8 buffer (row-major, 3 bytes/pixel) as a PNG file.
pub fn write_rgb(path: &Path, width: usize, height: usize, pixels: &[u8]) -> Result<()> {
    ensure!(pixels.len() == width * height * 3, "pixel buffer size");
    let mut out: Vec<u8> = Vec::with_capacity(pixels.len() + pixels.len() / 64 + 1024);
    out.extend_from_slice(&[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);

    // IHDR
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // 8-bit, truecolor, deflate, adaptive, no interlace
    chunk(&mut out, b"IHDR", &ihdr);

    // IDAT: filter byte 0 (None) per scanline, zlib-wrapped
    let mut raw = Vec::with_capacity(height * (1 + width * 3));
    for row in 0..height {
        raw.push(0u8);
        raw.extend_from_slice(&pixels[row * width * 3..(row + 1) * width * 3]);
    }
    chunk(&mut out, b"IDAT", &zlib_stored(&raw));

    chunk(&mut out, b"IEND", &[]);
    std::fs::write(path, out)?;
    Ok(())
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], data: &[u8]) {
    out.extend_from_slice(&(data.len() as u32).to_be_bytes());
    let start = out.len();
    out.extend_from_slice(tag);
    out.extend_from_slice(data);
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_be_bytes());
}

/// Wrap `raw` in a zlib stream of stored deflate blocks (RFC 1950/1951).
fn zlib_stored(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() + raw.len() / 65535 * 5 + 16);
    // CMF/FLG: deflate, 32K window, FCHECK chosen so 0x7801 % 31 == 0.
    out.push(0x78);
    out.push(0x01);
    if raw.is_empty() {
        // a single final stored block of length 0
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xFF, 0xFF]);
    } else {
        let mut blocks = raw.chunks(65535).peekable();
        while let Some(b) = blocks.next() {
            let bfinal = blocks.peek().is_none() as u8;
            out.push(bfinal); // BFINAL + BTYPE=00 (stored)
            let len = b.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(b);
        }
    }
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

/// Adler-32 checksum (RFC 1950). 5552 is the largest block size for which
/// the u32 accumulators cannot overflow before the modulo.
fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    for block in data.chunks(5552) {
        for &byte in block {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Bitwise CRC-32 (IEEE, reflected, poly 0xEDB88320), as PNG requires.
pub(crate) fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Inflate a stream of stored deflate blocks (test-only decoder).
    fn inflate_stored(zlib: &[u8]) -> Vec<u8> {
        assert!(zlib.len() >= 6, "zlib too short");
        assert_eq!(zlib[0], 0x78);
        assert_eq!((((zlib[0] as u32) << 8) | zlib[1] as u32) % 31, 0, "FCHECK");
        let mut i = 2;
        let mut out = Vec::new();
        loop {
            let hdr = zlib[i];
            assert_eq!(hdr & 0b110, 0, "stored blocks only");
            let len = u16::from_le_bytes([zlib[i + 1], zlib[i + 2]]) as usize;
            let nlen = u16::from_le_bytes([zlib[i + 3], zlib[i + 4]]);
            assert_eq!(!(len as u16), nlen, "LEN/NLEN mismatch");
            out.extend_from_slice(&zlib[i + 5..i + 5 + len]);
            i += 5 + len;
            if hdr & 1 == 1 {
                break;
            }
        }
        let adler = u32::from_be_bytes([zlib[i], zlib[i + 1], zlib[i + 2], zlib[i + 3]]);
        assert_eq!(adler, adler32(&out), "adler32 trailer");
        out
    }

    #[test]
    fn crc32_known_vectors() {
        // standard check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn adler32_known_vectors() {
        // RFC 1950 check value for "Wikipedia"
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
        assert_eq!(adler32(b""), 1);
    }

    #[test]
    fn zlib_stored_roundtrips() {
        for n in [0usize, 1, 100, 65535, 65536, 200_000] {
            let data: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
            assert_eq!(inflate_stored(&zlib_stored(&data)), data, "n={n}");
        }
    }

    #[test]
    fn writes_valid_signature_and_chunks() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.png");
        let pixels = vec![255u8; 4 * 3 * 3];
        write_rgb(&p, 4, 3, &pixels).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], &[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);
        // IHDR directly after signature with width 4, height 3
        assert_eq!(&bytes[12..16], b"IHDR");
        assert_eq!(u32::from_be_bytes(bytes[16..20].try_into().unwrap()), 4);
        assert_eq!(u32::from_be_bytes(bytes[20..24].try_into().unwrap()), 3);
        assert!(bytes.windows(4).any(|w| w == b"IDAT"));
        assert!(bytes.ends_with(&{
            let mut e = Vec::new();
            e.extend_from_slice(b"IEND");
            e.extend_from_slice(&crc32(b"IEND").to_be_bytes());
            e
        }));
    }

    #[test]
    fn idat_payload_decodes_to_scanlines() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.png");
        let (w, h) = (5usize, 2usize);
        let pixels: Vec<u8> = (0..w * h * 3).map(|i| i as u8).collect();
        write_rgb(&p, w, h, &pixels).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let idat_at = bytes.windows(4).position(|win| win == b"IDAT").unwrap();
        let len = u32::from_be_bytes(bytes[idat_at - 4..idat_at].try_into().unwrap()) as usize;
        let raw = inflate_stored(&bytes[idat_at + 4..idat_at + 4 + len]);
        assert_eq!(raw.len(), h * (1 + w * 3));
        for row in 0..h {
            let at = row * (1 + w * 3);
            assert_eq!(raw[at], 0, "filter byte");
            assert_eq!(&raw[at + 1..at + 1 + w * 3], &pixels[row * w * 3..(row + 1) * w * 3]);
        }
    }

    #[test]
    fn rejects_bad_buffer() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(write_rgb(&dir.join("bad.png"), 4, 4, &[0u8; 5]).is_err());
    }
}
