//! Minimal PNG encoder (8-bit RGB, one IDAT), written entirely from
//! scratch for the offline environment — no `flate2`/`crc32fast`/image
//! crates.  The zlib stream uses **fixed-Huffman** deflate blocks with a
//! greedy hash-chain LZ77 (RFC 1951 §3.2.6) so tiles served over the wire
//! are actually compressed; the original *stored*-block path is kept as
//! the test oracle (both paths must inflate to identical bytes), and the
//! test-only inflater decodes both block types.  Chunk CRCs come from a
//! bitwise CRC-32, the zlib trailer from Adler-32.

use crate::ensure;
use crate::util::error::Result;
use std::path::Path;

/// Encode an RGB8 buffer (row-major, 3 bytes/pixel) as PNG file bytes.
/// Deterministic: equal input produces bitwise-equal output (the serving
/// layer's tile-reproducibility contract depends on this).
pub fn encode_rgb(width: usize, height: usize, pixels: &[u8]) -> Result<Vec<u8>> {
    ensure!(pixels.len() == width * height * 3, "pixel buffer size");
    let mut out: Vec<u8> = Vec::with_capacity(pixels.len() / 4 + 1024);
    out.extend_from_slice(&[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);

    // IHDR
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // 8-bit, truecolor, deflate, adaptive, no interlace
    chunk(&mut out, b"IHDR", &ihdr);

    // IDAT: filter byte 0 (None) per scanline, zlib-wrapped
    let mut raw = Vec::with_capacity(height * (1 + width * 3));
    for row in 0..height {
        raw.push(0u8);
        raw.extend_from_slice(&pixels[row * width * 3..(row + 1) * width * 3]);
    }
    chunk(&mut out, b"IDAT", &zlib_fixed(&raw));

    chunk(&mut out, b"IEND", &[]);
    Ok(out)
}

/// Write an RGB8 buffer (row-major, 3 bytes/pixel) as a PNG file.
pub fn write_rgb(path: &Path, width: usize, height: usize, pixels: &[u8]) -> Result<()> {
    let bytes = encode_rgb(width, height, pixels)?;
    std::fs::write(path, bytes)?;
    Ok(())
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], data: &[u8]) {
    out.extend_from_slice(&(data.len() as u32).to_be_bytes());
    let start = out.len();
    out.extend_from_slice(tag);
    out.extend_from_slice(data);
    let crc = crc32(&out[start..]);
    out.extend_from_slice(&crc.to_be_bytes());
}

// ---- deflate tables (RFC 1951 §3.2.5), shared by the encoder and the
// test-only inflater ------------------------------------------------------

/// Length-symbol base lengths for symbols 257..=285.
const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99, 115,
    131, 163, 195, 227, 258,
];
/// Extra bits per length symbol.
const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];
/// Distance-symbol base distances for symbols 0..=29.
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025, 1537,
    2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
/// Extra bits per distance symbol.
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12, 12,
    13, 13,
];

// ---- fixed-Huffman deflate ----------------------------------------------

/// LSB-first bit accumulator (deflate's bit order).  Huffman codes go in
/// MSB-first via [`BitWriter::huff`]; everything else LSB-first.
struct BitWriter {
    out: Vec<u8>,
    buf: u32,
    count: u32,
}

impl BitWriter {
    fn new(capacity: usize) -> BitWriter {
        BitWriter { out: Vec::with_capacity(capacity), buf: 0, count: 0 }
    }

    fn bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 16 && value < (1 << n));
        self.buf |= value << self.count;
        self.count += n;
        while self.count >= 8 {
            self.out.push(self.buf as u8);
            self.buf >>= 8;
            self.count -= 8;
        }
    }

    /// Emit a Huffman code: the code's MSB enters the stream first.
    fn huff(&mut self, code: u32, len: u32) {
        let mut rev = 0u32;
        for i in 0..len {
            rev |= ((code >> i) & 1) << (len - 1 - i);
        }
        self.bits(rev, len);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.count > 0 {
            self.out.push(self.buf as u8);
        }
        self.out
    }
}

/// Fixed-Huffman code for a literal/length symbol (RFC 1951 §3.2.6).
fn fixed_lit_code(sym: u32) -> (u32, u32) {
    match sym {
        0..=143 => (0x30 + sym, 8),
        144..=255 => (0x190 + (sym - 144), 9),
        256..=279 => (sym - 256, 7),
        _ => (0xC0 + (sym - 280), 8),
    }
}

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const WINDOW: usize = 32 * 1024;
const HASH_BITS: u32 = 15;
const MAX_CHAIN: usize = 32;
const NO_POS: u32 = u32::MAX;

#[inline]
fn hash3(b: &[u8]) -> usize {
    let v = ((b[0] as u32) << 16) ^ ((b[1] as u32) << 8) ^ (b[2] as u32);
    (v.wrapping_mul(2_654_435_761) >> (32 - HASH_BITS)) as usize
}

/// Deflate `raw` as one final fixed-Huffman block with a greedy
/// hash-chain LZ77 parse.  Pure function of the input — bitwise
/// deterministic.
fn deflate_fixed(raw: &[u8]) -> Vec<u8> {
    let mut bw = BitWriter::new(raw.len() / 3 + 64);
    bw.bits(1, 1); // BFINAL
    bw.bits(0b01, 2); // BTYPE = fixed Huffman

    let mut head = vec![NO_POS; 1 << HASH_BITS];
    let mut prev = vec![NO_POS; raw.len()];
    let insert = |head: &mut [u32], prev: &mut [u32], at: usize| {
        if at + MIN_MATCH <= raw.len() {
            let h = hash3(&raw[at..]);
            prev[at] = head[h];
            head[h] = at as u32;
        }
    };

    let mut i = 0usize;
    while i < raw.len() {
        let (mlen, mdist) = best_match(raw, i, &head, &prev);
        if mlen >= MIN_MATCH {
            // length symbol: largest base <= mlen
            let ls = LEN_BASE.iter().rposition(|&b| (b as usize) <= mlen).unwrap();
            let (code, bits) = fixed_lit_code(257 + ls as u32);
            bw.huff(code, bits);
            bw.bits((mlen - LEN_BASE[ls] as usize) as u32, LEN_EXTRA[ls] as u32);
            let ds = DIST_BASE.iter().rposition(|&b| (b as usize) <= mdist).unwrap();
            bw.huff(ds as u32, 5);
            bw.bits((mdist - DIST_BASE[ds] as usize) as u32, DIST_EXTRA[ds] as u32);
            for p in i..i + mlen {
                insert(&mut head, &mut prev, p);
            }
            i += mlen;
        } else {
            let (code, bits) = fixed_lit_code(raw[i] as u32);
            bw.huff(code, bits);
            insert(&mut head, &mut prev, i);
            i += 1;
        }
    }
    let (code, bits) = fixed_lit_code(256); // end of block
    bw.huff(code, bits);
    bw.finish()
}

/// Longest match for position `i` over the hash chain (greedy; ties keep
/// the nearest, i.e. first-found, candidate).
fn best_match(raw: &[u8], i: usize, head: &[u32], prev: &[u32]) -> (usize, usize) {
    if i + MIN_MATCH > raw.len() {
        return (0, 0);
    }
    let max_len = MAX_MATCH.min(raw.len() - i);
    let mut best_len = 0usize;
    let mut best_dist = 0usize;
    let mut cand = head[hash3(&raw[i..])];
    let mut depth = 0;
    while cand != NO_POS && depth < MAX_CHAIN {
        let c = cand as usize;
        let dist = i - c;
        if dist > WINDOW {
            break; // chain only gets older
        }
        let mut l = 0usize;
        while l < max_len && raw[c + l] == raw[i + l] {
            l += 1;
        }
        if l > best_len {
            best_len = l;
            best_dist = dist;
            if l == max_len {
                break;
            }
        }
        cand = prev[c];
        depth += 1;
    }
    if best_len >= MIN_MATCH {
        (best_len, best_dist)
    } else {
        (0, 0)
    }
}

/// Wrap `raw` in a zlib stream of one fixed-Huffman deflate block
/// (RFC 1950/1951).
fn zlib_fixed(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() / 3 + 16);
    // CMF/FLG: deflate, 32K window, FCHECK chosen so 0x7801 % 31 == 0.
    out.push(0x78);
    out.push(0x01);
    out.extend_from_slice(&deflate_fixed(raw));
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

/// Wrap `raw` in a zlib stream of stored deflate blocks (RFC 1950/1951).
/// Kept as the oracle path: `inflate(zlib_stored(x)) ==
/// inflate(zlib_fixed(x)) == x` is the encoder's correctness gauge.
#[cfg_attr(not(test), allow(dead_code))]
fn zlib_stored(raw: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(raw.len() + raw.len() / 65535 * 5 + 16);
    // CMF/FLG: deflate, 32K window, FCHECK chosen so 0x7801 % 31 == 0.
    out.push(0x78);
    out.push(0x01);
    if raw.is_empty() {
        // a single final stored block of length 0
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xFF, 0xFF]);
    } else {
        let mut blocks = raw.chunks(65535).peekable();
        while let Some(b) = blocks.next() {
            let bfinal = blocks.peek().is_none() as u8;
            out.push(bfinal); // BFINAL + BTYPE=00 (stored)
            let len = b.len() as u16;
            out.extend_from_slice(&len.to_le_bytes());
            out.extend_from_slice(&(!len).to_le_bytes());
            out.extend_from_slice(b);
        }
    }
    out.extend_from_slice(&adler32(raw).to_be_bytes());
    out
}

/// Adler-32 checksum (RFC 1950). 5552 is the largest block size for which
/// the u32 accumulators cannot overflow before the modulo.
fn adler32(data: &[u8]) -> u32 {
    const MOD: u32 = 65521;
    let mut a: u32 = 1;
    let mut b: u32 = 0;
    for block in data.chunks(5552) {
        for &byte in block {
            a += byte as u32;
            b += a;
        }
        a %= MOD;
        b %= MOD;
    }
    (b << 16) | a
}

/// Incremental CRC-32 (same polynomial as [`crc32`]) for callers that
/// checksum discontiguous byte ranges — e.g. the wire protocol's frame
/// checksum covers two header fields plus the payload without
/// concatenating them.
#[derive(Clone)]
pub struct Crc32(u32);

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut crc = self.0;
        for &b in data {
            crc ^= b as u32;
            for _ in 0..8 {
                let mask = (crc & 1).wrapping_neg();
                crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
            }
        }
        self.0 = crc;
    }

    pub fn finish(&self) -> u32 {
        !self.0
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// Bitwise CRC-32 (IEEE, reflected, poly 0xEDB88320), as PNG requires.
/// Public: the checkpoint run store (DESIGN.md §11) and the golden-run
/// regression test reuse it to guard persisted state files.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// LSB-first bit reader; Huffman codes read MSB-first via `huff_bits`.
    struct BitReader<'a> {
        b: &'a [u8],
        byte: usize,
        bit: u32,
    }

    impl<'a> BitReader<'a> {
        fn new(b: &'a [u8]) -> BitReader<'a> {
            BitReader { b, byte: 0, bit: 0 }
        }

        fn bit(&mut self) -> u32 {
            let v = (self.b[self.byte] >> self.bit) & 1;
            self.bit += 1;
            if self.bit == 8 {
                self.bit = 0;
                self.byte += 1;
            }
            v as u32
        }

        fn bits(&mut self, n: u32) -> u32 {
            let mut v = 0;
            for i in 0..n {
                v |= self.bit() << i;
            }
            v
        }

        fn huff_bits(&mut self, n: u32) -> u32 {
            let mut v = 0;
            for _ in 0..n {
                v = (v << 1) | self.bit();
            }
            v
        }

        fn align(&mut self) {
            if self.bit != 0 {
                self.bit = 0;
                self.byte += 1;
            }
        }
    }

    /// Decode one fixed-Huffman literal/length symbol (inverse of
    /// `fixed_lit_code`).
    fn decode_fixed_lit(r: &mut BitReader) -> u32 {
        let mut code = r.huff_bits(7);
        if code <= 0x17 {
            return 256 + code; // 7-bit codes: 256..=279
        }
        code = (code << 1) | r.bit(); // 8 bits
        if (0x30..=0xBF).contains(&code) {
            return code - 0x30; // literals 0..=143
        }
        if (0xC0..=0xC7).contains(&code) {
            return 280 + (code - 0xC0); // 280..=287
        }
        code = (code << 1) | r.bit(); // 9 bits
        assert!((0x190..=0x1FF).contains(&code), "invalid fixed code {code:#x}");
        144 + (code - 0x190) // literals 144..=255
    }

    /// Inflate a zlib stream of stored and/or fixed-Huffman blocks — the
    /// test-only decoder that closes the loop on the from-scratch encoder.
    fn inflate(zlib: &[u8]) -> Vec<u8> {
        assert!(zlib.len() >= 6, "zlib too short");
        assert_eq!(zlib[0], 0x78);
        assert_eq!((((zlib[0] as u32) << 8) | zlib[1] as u32) % 31, 0, "FCHECK");
        let mut r = BitReader::new(&zlib[2..zlib.len() - 4]);
        let mut out = Vec::new();
        loop {
            let bfinal = r.bit();
            let btype = r.bits(2);
            match btype {
                0 => {
                    r.align();
                    let len = (r.b[r.byte] as usize) | ((r.b[r.byte + 1] as usize) << 8);
                    let nlen = (r.b[r.byte + 2] as u16) | ((r.b[r.byte + 3] as u16) << 8);
                    assert_eq!(!(len as u16), nlen, "LEN/NLEN mismatch");
                    r.byte += 4;
                    out.extend_from_slice(&r.b[r.byte..r.byte + len]);
                    r.byte += len;
                }
                1 => loop {
                    let sym = decode_fixed_lit(&mut r);
                    match sym {
                        0..=255 => out.push(sym as u8),
                        256 => break,
                        257..=285 => {
                            let ls = (sym - 257) as usize;
                            let len =
                                LEN_BASE[ls] as usize + r.bits(LEN_EXTRA[ls] as u32) as usize;
                            let ds = r.huff_bits(5) as usize;
                            assert!(ds < 30, "bad distance symbol {ds}");
                            let dist =
                                DIST_BASE[ds] as usize + r.bits(DIST_EXTRA[ds] as u32) as usize;
                            assert!(dist <= out.len(), "distance before stream start");
                            let from = out.len() - dist;
                            for k in 0..len {
                                let byte = out[from + k];
                                out.push(byte); // overlap-safe byte copy
                            }
                        }
                        _ => panic!("invalid symbol {sym}"),
                    }
                },
                _ => panic!("unsupported BTYPE {btype}"),
            }
            if bfinal == 1 {
                break;
            }
        }
        let at = zlib.len() - 4;
        let adler = u32::from_be_bytes([zlib[at], zlib[at + 1], zlib[at + 2], zlib[at + 3]]);
        assert_eq!(adler, adler32(&out), "adler32 trailer");
        out
    }

    #[test]
    fn crc32_known_vectors() {
        // standard check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn adler32_known_vectors() {
        // RFC 1950 check value for "Wikipedia"
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
        assert_eq!(adler32(b""), 1);
    }

    #[test]
    fn fixed_deflate_known_vector() {
        // `zlib.compress(b"abc")` emits exactly this fixed-Huffman block
        // body: header bits, three 8-bit literal codes, 7-bit end-of-block.
        assert_eq!(deflate_fixed(b"abc"), vec![0x4B, 0x4C, 0x4A, 0x06, 0x00]);
    }

    #[test]
    fn zlib_stored_roundtrips() {
        for n in [0usize, 1, 100, 65535, 65536, 200_000] {
            let data: Vec<u8> = (0..n).map(|i| (i * 31 % 251) as u8).collect();
            assert_eq!(inflate(&zlib_stored(&data)), data, "n={n}");
        }
    }

    #[test]
    fn zlib_fixed_roundtrips_and_matches_stored_oracle() {
        let mut cases: Vec<Vec<u8>> = vec![
            vec![],
            b"a".to_vec(),
            b"abc".to_vec(),
            vec![0u8; 100_000],                          // long match chains
            (0..66_000).map(|i| (i % 256) as u8).collect(), // period > window hash variety
        ];
        // pseudo-random incompressible-ish data
        let mut x = 12345u64;
        cases.push(
            (0..50_000)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    (x >> 33) as u8
                })
                .collect(),
        );
        // repeated text: matches at many distances
        cases.push(b"the quick brown fox ".repeat(4_000));
        for (i, data) in cases.iter().enumerate() {
            let fixed = zlib_fixed(data);
            let stored = zlib_stored(data);
            assert_eq!(inflate(&fixed), *data, "case {i}: fixed roundtrip");
            assert_eq!(inflate(&fixed), inflate(&stored), "case {i}: oracle agreement");
        }
    }

    #[test]
    fn fixed_compresses_redundant_data() {
        let data = vec![7u8; 64 * 1024];
        let fixed = zlib_fixed(&data);
        let stored = zlib_stored(&data);
        assert!(
            fixed.len() * 10 < stored.len(),
            "fixed {} vs stored {}",
            fixed.len(),
            stored.len()
        );
    }

    #[test]
    fn encode_is_deterministic() {
        let pixels: Vec<u8> = (0..32 * 32 * 3).map(|i| (i % 251) as u8).collect();
        let a = encode_rgb(32, 32, &pixels).unwrap();
        let b = encode_rgb(32, 32, &pixels).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn writes_valid_signature_and_chunks() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.png");
        let pixels = vec![255u8; 4 * 3 * 3];
        write_rgb(&p, 4, 3, &pixels).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], &[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);
        // IHDR directly after signature with width 4, height 3
        assert_eq!(&bytes[12..16], b"IHDR");
        assert_eq!(u32::from_be_bytes(bytes[16..20].try_into().unwrap()), 4);
        assert_eq!(u32::from_be_bytes(bytes[20..24].try_into().unwrap()), 3);
        assert!(bytes.windows(4).any(|w| w == b"IDAT"));
        assert!(bytes.ends_with(&{
            let mut e = Vec::new();
            e.extend_from_slice(b"IEND");
            e.extend_from_slice(&crc32(b"IEND").to_be_bytes());
            e
        }));
    }

    #[test]
    fn idat_payload_decodes_to_scanlines() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("d.png");
        let (w, h) = (5usize, 2usize);
        let pixels: Vec<u8> = (0..w * h * 3).map(|i| i as u8).collect();
        write_rgb(&p, w, h, &pixels).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        let idat_at = bytes.windows(4).position(|win| win == b"IDAT").unwrap();
        let len = u32::from_be_bytes(bytes[idat_at - 4..idat_at].try_into().unwrap()) as usize;
        let raw = inflate(&bytes[idat_at + 4..idat_at + 4 + len]);
        assert_eq!(raw.len(), h * (1 + w * 3));
        for row in 0..h {
            let at = row * (1 + w * 3);
            assert_eq!(raw[at], 0, "filter byte");
            assert_eq!(&raw[at + 1..at + 1 + w * 3], &pixels[row * w * 3..(row + 1) * w * 3]);
        }
    }

    #[test]
    fn rejects_bad_buffer() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(write_rgb(&dir.join("bad.png"), 4, 4, &[0u8; 5]).is_err());
        assert!(encode_rgb(4, 4, &[0u8; 5]).is_err());
    }
}
