//! Minimal PNG encoder (8-bit RGB, one IDAT, zlib via flate2).
//!
//! Written from scratch for the offline environment; enough of the spec to
//! emit standards-compliant truecolor images for the map renders.

use anyhow::Result;
use flate2::write::ZlibEncoder;
use flate2::Compression;
use std::io::Write;
use std::path::Path;

/// Write an RGB8 buffer (row-major, 3 bytes/pixel) as a PNG file.
pub fn write_rgb(path: &Path, width: usize, height: usize, pixels: &[u8]) -> Result<()> {
    anyhow::ensure!(pixels.len() == width * height * 3, "pixel buffer size");
    let mut out: Vec<u8> = Vec::with_capacity(pixels.len() / 2 + 1024);
    out.extend_from_slice(&[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);

    // IHDR
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // 8-bit, truecolor, deflate, adaptive, no interlace
    chunk(&mut out, b"IHDR", &ihdr);

    // IDAT: filter byte 0 (None) per scanline, zlib-compressed
    let mut raw = Vec::with_capacity(height * (1 + width * 3));
    for row in 0..height {
        raw.push(0u8);
        raw.extend_from_slice(&pixels[row * width * 3..(row + 1) * width * 3]);
    }
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(&raw)?;
    let compressed = enc.finish()?;
    chunk(&mut out, b"IDAT", &compressed);

    chunk(&mut out, b"IEND", &[]);
    std::fs::write(path, out)?;
    Ok(())
}

fn chunk(out: &mut Vec<u8>, tag: &[u8; 4], data: &[u8]) {
    out.extend_from_slice(&(data.len() as u32).to_be_bytes());
    let start = out.len();
    out.extend_from_slice(tag);
    out.extend_from_slice(data);
    let crc = crc32fast::hash(&out[start..]);
    out.extend_from_slice(&crc.to_be_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_signature_and_chunks() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.png");
        let pixels = vec![255u8; 4 * 3 * 3];
        write_rgb(&p, 4, 3, &pixels).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], &[0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1a, b'\n']);
        // IHDR directly after signature with width 4, height 3
        assert_eq!(&bytes[12..16], b"IHDR");
        assert_eq!(u32::from_be_bytes(bytes[16..20].try_into().unwrap()), 4);
        assert_eq!(u32::from_be_bytes(bytes[20..24].try_into().unwrap()), 3);
        assert!(bytes.windows(4).any(|w| w == b"IDAT"));
        assert!(bytes.ends_with(&{
            let mut e = Vec::new();
            e.extend_from_slice(b"IEND");
            e.extend_from_slice(&crc32fast::hash(b"IEND").to_be_bytes());
            e
        }));
    }

    #[test]
    fn rejects_bad_buffer() {
        let dir = std::env::temp_dir().join("nomad_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        assert!(write_rgb(&dir.join("bad.png"), 4, 4, &[0u8; 5]).is_err());
    }
}
