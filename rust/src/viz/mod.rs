//! Density-map rendering (paper Fig 1 / Fig 4).
//!
//! Renders an embedding as a log-density heat map — "bright regions
//! indicate regions of high data density" — with optional per-label hue,
//! plus the multiscale zoom crops of Fig 4.  The PNG encoder is written
//! entirely from scratch (stored-deflate zlib + bitwise CRC-32; the offline
//! build has no image or compression crates).

pub mod png;

use crate::linalg::Matrix;

/// A rendered RGB8 raster.
pub struct Raster {
    pub width: usize,
    pub height: usize,
    /// RGB, row-major, 3 bytes per pixel
    pub pixels: Vec<u8>,
}

/// Viewport into embedding space.
#[derive(Clone, Copy, Debug)]
pub struct View {
    pub cx: f32,
    pub cy: f32,
    pub half_w: f32,
    pub half_h: f32,
}

impl View {
    /// Bounding view of the finite points with 5% margin.  Rows with a
    /// non-finite coordinate are ignored; an empty (or all-non-finite)
    /// matrix yields the unit view centered on the origin rather than an
    /// infinite/NaN viewport — the tile pyramid derives its root extent
    /// from this, so it must always be a usable rectangle.
    pub fn fit(y: &Matrix) -> View {
        let mut min = [f32::INFINITY; 2];
        let mut max = [f32::NEG_INFINITY; 2];
        for i in 0..y.rows {
            let r = y.row(i);
            if !r[0].is_finite() || !r[1].is_finite() {
                continue;
            }
            for d in 0..2 {
                min[d] = min[d].min(r[d]);
                max[d] = max[d].max(r[d]);
            }
        }
        if min[0] > max[0] || min[1] > max[1] {
            return View { cx: 0.0, cy: 0.0, half_w: 1.0, half_h: 1.0 };
        }
        let cx = (min[0] + max[0]) / 2.0;
        let cy = (min[1] + max[1]) / 2.0;
        let half = ((max[0] - min[0]).max(max[1] - min[1]) / 2.0).max(1e-6) * 1.05;
        View { cx, cy, half_w: half, half_h: half }
    }

    /// Zoom by `factor` around (cx, cy) — Fig 4's 20x / 5x magnifications.
    pub fn zoom(&self, cx: f32, cy: f32, factor: f32) -> View {
        View { cx, cy, half_w: self.half_w / factor, half_h: self.half_h / factor }
    }
}

/// Render a log-density map.  When `labels` is given, pixels are tinted by
/// the majority label's hue (like the paper's language-colored Fig 1).
pub fn density_map(
    y: &Matrix,
    labels: Option<&[u32]>,
    view: &View,
    width: usize,
    height: usize,
) -> Raster {
    let mut counts = vec![0.0f32; width * height];
    let mut hue_acc: Vec<[f32; 3]> = vec![[0.0; 3]; width * height];

    for i in 0..y.rows {
        let px = (y.row(i)[0] - (view.cx - view.half_w)) / (2.0 * view.half_w) * width as f32;
        let py = (y.row(i)[1] - (view.cy - view.half_h)) / (2.0 * view.half_h) * height as f32;
        if px < 0.0 || py < 0.0 || px >= width as f32 || py >= height as f32 {
            continue;
        }
        let (ix, iy) = (px as usize, py as usize);
        let idx = iy * width + ix;
        counts[idx] += 1.0;
        if let Some(ls) = labels {
            let rgb = label_color(ls[i]);
            for c in 0..3 {
                hue_acc[idx][c] += rgb[c];
            }
        }
    }

    let max_count = counts.iter().cloned().fold(0.0f32, f32::max).max(1.0);
    let log_max = (1.0 + max_count).ln();
    let mut pixels = vec![0u8; width * height * 3];
    for p in 0..width * height {
        let c = counts[p];
        if c == 0.0 {
            continue;
        }
        let lum = ((1.0 + c).ln() / log_max).clamp(0.0, 1.0);
        let rgb = if labels.is_some() {
            let inv = 1.0 / c;
            let base = [hue_acc[p][0] * inv, hue_acc[p][1] * inv, hue_acc[p][2] * inv];
            // brighten with density
            [
                (base[0] * (0.35 + 0.65 * lum)),
                (base[1] * (0.35 + 0.65 * lum)),
                (base[2] * (0.35 + 0.65 * lum)),
            ]
        } else {
            inferno(lum)
        };
        for ch in 0..3 {
            pixels[p * 3 + ch] = (rgb[ch] * 255.0).clamp(0.0, 255.0) as u8;
        }
    }
    Raster { width, height, pixels }
}

/// Stable distinguishable color per label (golden-angle hue walk).
fn label_color(label: u32) -> [f32; 3] {
    let h = (label as f32 * 0.618_034) % 1.0;
    hsv_to_rgb(h, 0.75, 1.0)
}

fn hsv_to_rgb(h: f32, s: f32, v: f32) -> [f32; 3] {
    let i = (h * 6.0).floor();
    let f = h * 6.0 - i;
    let p = v * (1.0 - s);
    let q = v * (1.0 - f * s);
    let t = v * (1.0 - (1.0 - f) * s);
    match (i as i32) % 6 {
        0 => [v, t, p],
        1 => [q, v, p],
        2 => [p, v, t],
        3 => [p, q, v],
        4 => [t, p, v],
        _ => [v, p, q],
    }
}

/// A compact inferno-like colormap (dark purple -> orange -> bright yellow).
fn inferno(t: f32) -> [f32; 3] {
    let stops: [[f32; 3]; 5] = [
        [0.0, 0.0, 0.02],
        [0.23, 0.04, 0.33],
        [0.7, 0.21, 0.33],
        [0.97, 0.55, 0.04],
        [0.99, 1.0, 0.75],
    ];
    let x = t.clamp(0.0, 1.0) * (stops.len() - 1) as f32;
    let i = (x as usize).min(stops.len() - 2);
    let f = x - i as f32;
    [
        stops[i][0] * (1.0 - f) + stops[i + 1][0] * f,
        stops[i][1] * (1.0 - f) + stops[i + 1][1] * f,
        stops[i][2] * (1.0 - f) + stops[i + 1][2] * f,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_fit_covers_points() {
        let y = Matrix::from_vec(3, 2, vec![-1.0, -2.0, 5.0, 4.0, 0.0, 0.0]);
        let v = View::fit(&y);
        assert!(v.half_w >= 3.0);
        assert!((v.cx - 2.0).abs() < 1e-6);
        assert!((v.cy - 1.0).abs() < 1e-6);
    }

    #[test]
    fn density_concentrates_where_points_are() {
        let mut data = Vec::new();
        for _ in 0..100 {
            data.push(0.0);
            data.push(0.0);
        }
        data.push(10.0);
        data.push(10.0);
        let y = Matrix::from_vec(101, 2, data);
        let v = View::fit(&y);
        let r = density_map(&y, None, &v, 64, 64);
        // the dense corner should be brighter than the lone point's pixel
        let bright: u32 = r.pixels.iter().map(|&b| b as u32).sum();
        assert!(bright > 0);
        let max_px = r
            .pixels
            .chunks(3)
            .map(|c| c.iter().map(|&b| b as u32).sum::<u32>())
            .max()
            .unwrap();
        assert!(max_px > 300, "hot pixel {max_px}");
    }

    #[test]
    fn fit_guards_empty_and_non_finite_input() {
        // empty matrix: unit view, not an infinite viewport
        let v = View::fit(&Matrix::zeros(0, 2));
        assert_eq!((v.cx, v.cy, v.half_w, v.half_h), (0.0, 0.0, 1.0, 1.0));

        // all-NaN matrix: same guard
        let y = Matrix::from_vec(2, 2, vec![f32::NAN; 4]);
        let v = View::fit(&y);
        assert_eq!((v.cx, v.cy, v.half_w, v.half_h), (0.0, 0.0, 1.0, 1.0));

        // mixed: non-finite rows are ignored, finite rows fit as usual
        let y = Matrix::from_vec(
            3,
            2,
            vec![f32::NAN, 0.0, -1.0, -1.0, 1.0, f32::INFINITY],
        );
        let v = View::fit(&y);
        assert!(v.cx.is_finite() && v.cy.is_finite());
        assert_eq!((v.cx, v.cy), (-1.0, -1.0));
        assert!(v.half_w > 0.0 && v.half_w.is_finite());
    }

    #[test]
    fn zoom_shrinks_view() {
        let v = View { cx: 0.0, cy: 0.0, half_w: 10.0, half_h: 10.0 };
        let z = v.zoom(1.0, 2.0, 20.0);
        assert!((z.half_w - 0.5).abs() < 1e-6);
        assert_eq!((z.cx, z.cy), (1.0, 2.0));
    }

    #[test]
    fn labels_tint_pixels() {
        let y = Matrix::from_vec(2, 2, vec![-1.0, 0.0, 1.0, 0.0]);
        let labels = [0u32, 7u32];
        let v = View::fit(&y);
        let r = density_map(&y, Some(&labels), &v, 32, 32);
        let nonzero: Vec<&[u8]> = r.pixels.chunks(3).filter(|c| c.iter().any(|&b| b > 0)).collect();
        assert_eq!(nonzero.len(), 2);
        assert_ne!(nonzero[0], nonzero[1], "different labels, different colors");
    }
}
