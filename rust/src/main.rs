//! `nomad` — the NOMAD Projection command-line launcher.
//!
//! Subcommands:
//!   embed    embed a dataset (synthetic generator or .npy file) and write
//!            positions (.npy) + an optional density map (.png) + a map
//!            artifact directory for the serving layer; with
//!            --checkpoint-dir the run is durable and resumable
//!   resume   continue a killed/finished run from its run store
//!            (bitwise identical to the uninterrupted run — DESIGN.md §11)
//!   shard    cut a dataset's cluster topology into an mmap-able shard set
//!            (`shards.json` + `shards.bin`) for `nomad worker` processes
//!            (DESIGN.md §12)
//!   worker   serve one device as an OS process: load assigned clusters
//!            from a shard set, train under a remote coordinator
//!            (`nomad embed --workers ...`), exit on its Stop
//!   serve    serve a map artifact over HTTP: LOD tiles, kNN point
//!            queries, and cache/latency stats (DESIGN.md §10); with
//!            --watch <run_dir> it follows a training run live,
//!            hot-swapping to each new checkpoint
//!   index    build and report on the K-Means ANN index only
//!   metrics  score an embedding (.npy) against its source data (.npy)
//!   info     print artifact-manifest and environment diagnostics
//!
//! Examples:
//!   nomad embed --data wikipedia --n 20000 --devices 8 --out out/wiki
//!   nomad embed --npy vectors.npy --epochs 200 --xla --out out/run1
//!   nomad embed --data pubmed --n 50000 --epochs 200 \
//!       --checkpoint-dir out/pm_run --checkpoint-every 20 --out out/pm
//!   nomad resume --run out/pm_run --out out/pm
//!   nomad shard --data arxiv --n 20000 --clusters 64 --out out/shards
//!   nomad worker --shards out/shards --listen 127.0.0.1:7701
//!   nomad embed --data arxiv --n 20000 --shards out/shards \
//!       --workers 127.0.0.1:7701,127.0.0.1:7702 --out out/dist
//!   nomad serve --artifact out/wiki_artifact --addr 127.0.0.1:8080
//!   nomad serve --watch out/pm_run --addr 127.0.0.1:8080
//!   nomad metrics --npy vectors.npy --embedding out/run1_positions.npy
//!   nomad info
//!
//! `--threads N` (or the NOMAD_THREADS env var) bounds the worker threads
//! used by the parallel kernels; the default is the machine's parallelism.
//! `--quantize-build` routes the within-cluster kNN build through the int8
//! screen-and-rerank scan (DESIGN.md §16); the exact f32 rerank keeps the
//! resulting index bitwise identical to the unquantized build.

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::{edge_weights, mutuality};
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::bail;
use nomad::checkpoint::{self, params_fingerprint, DatasetSpec, RunStore};
use nomad::cli::Args;
use nomad::coordinator::{
    BackendKind, CheckpointCfg, NomadCoordinator, NomadRun, Placement, RecoveryCfg, RunConfig,
};
use nomad::data::{self, shard, Dataset};
use nomad::distributed::transport::Endpoint;
use nomad::distributed::worker::{self, WorkerCfg};
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::linalg::Matrix;
use nomad::obs;
use nomad::serve::{self, MapArtifact, Provenance, ServeConfig, TileConfig};
use nomad::util::error::{Context, Result};
use nomad::util::npy::NpyF32;
use nomad::util::rng::Rng;
use nomad::viz::{density_map, png, View};
use std::path::Path;
use std::time::Duration;

fn main() -> Result<()> {
    let args = Args::from_env();
    args.apply_thread_flag();
    match args.positional.first().map(|s| s.as_str()) {
        Some("embed") => cmd_embed(&args),
        Some("resume") => cmd_resume(&args),
        Some("shard") => cmd_shard(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve") => cmd_serve(&args),
        Some("index") => cmd_index(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: nomad <embed|resume|shard|worker|serve|index|metrics|info> [flags]  \
                 (see --help in source)"
            );
            Ok(())
        }
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    if let Some(path) = args.get("npy") {
        load_npy_dataset(path)
    } else {
        let spec = DatasetSpec {
            kind: "synthetic".to_string(),
            source: args.str("data", "arxiv").to_string(),
            n: args.usize("n", 10_000),
            seed: args.u64("seed", 0),
        };
        dataset_from_spec(&spec)
    }
}

fn load_npy_dataset(path: &str) -> Result<Dataset> {
    let t = NpyF32::load(Path::new(path))?;
    if t.shape.len() != 2 {
        bail!("expected 2-d array, got shape {:?}", t.shape);
    }
    let (n, d) = (t.shape[0], t.shape[1]);
    Ok(Dataset {
        x: Matrix::from_vec(n, d, t.data),
        labels: vec![vec![0; n]],
        name: path.to_string(),
    })
}

/// Rebuild the dataset a run store recorded (`nomad resume`'s input path).
fn dataset_from_spec(spec: &DatasetSpec) -> Result<Dataset> {
    if spec.kind == "npy" {
        return load_npy_dataset(&spec.source);
    }
    let mut rng = Rng::new(spec.seed);
    let n = spec.n;
    Ok(match spec.source.as_str() {
        "arxiv" => data::text_corpus_like(n, &mut rng),
        "imagenet" => data::image_corpus_like(n, &mut rng),
        "pubmed" => data::pubmed_like(n, &mut rng),
        "wikipedia" => data::wikipedia_like(n, &mut rng),
        other => bail!("unknown --data '{other}' (arxiv|imagenet|pubmed|wikipedia)"),
    })
}

/// The [`DatasetSpec`] describing how `args` obtained `ds` — recorded in
/// `run.json` so `nomad resume` can rebuild the run without the original
/// command line.
fn dataset_spec(args: &Args, ds: &Dataset) -> DatasetSpec {
    if let Some(path) = args.get("npy") {
        DatasetSpec { kind: "npy".to_string(), source: path.to_string(), n: ds.n(), seed: 0 }
    } else {
        DatasetSpec {
            kind: "synthetic".to_string(),
            source: args.str("data", "arxiv").to_string(),
            n: ds.n(),
            seed: args.u64("seed", 0),
        }
    }
}

/// The native distance backend for this invocation. `--quantize-build`
/// turns on the int8 screen-and-rerank kNN build (`linalg::quant`,
/// DESIGN.md §16); its exact f32 rerank keeps the index bitwise identical
/// to the unquantized build, so the flag is safe on every subcommand.
fn native_backend(args: &Args) -> NativeBackend {
    NativeBackend::quantized(args.bool("quantize-build"))
}

fn index_params(args: &Args) -> IndexParams {
    IndexParams {
        n_clusters: args.usize("clusters", 64),
        k: args.usize("k", 15),
        max_cluster_size: args.usize("max-cluster", 8192),
        ..Default::default()
    }
}

fn dataset_labels(ds: &Dataset) -> Option<Vec<u32>> {
    if ds.labels[0].iter().any(|&l| l != 0) {
        Some(ds.fine_labels().to_vec())
    } else {
        None
    }
}

fn checkpoint_cfg(args: &Args, ds: &Dataset) -> CheckpointCfg {
    // --no-artifact also skips per-checkpoint artifact materialization
    // (it exists for `serve --watch`; a run that suppresses artifacts
    // should not pay quadtree+npy writes on the training path)
    CheckpointCfg {
        every: args.usize("checkpoint-every", 25),
        retain: args.usize("checkpoint-retain", 3),
        artifact: !args.bool("no-artifact"),
        labels: dataset_labels(ds),
        dataset: ds.name.clone(),
    }
}

fn cmd_embed(args: &Args) -> Result<()> {
    // telemetry: the metrics registry is on by default (--no-telemetry
    // turns it off); span tracing is on only when a trace file is wanted.
    // Either way fitted positions are bitwise identical — telemetry flows
    // out of training, never back in (tests/obs_determinism.rs).
    if args.bool("no-telemetry") {
        obs::metrics::set_enabled(false);
    }
    let trace_out = args.get("trace-out").map(|p| Path::new(p).to_path_buf());
    if trace_out.is_some() {
        obs::trace::set_enabled(true);
    }
    let ds = load_dataset(args)?;
    println!("dataset: {} ({} x {})", ds.name, ds.n(), ds.dim());
    let params = NomadParams {
        epochs: args.usize("epochs", 200),
        k: args.usize("k", 15),
        negs: args.usize("negs", 8),
        pca_init: !args.bool("random-init"),
        seed: args.u64("seed", 42),
        ..Default::default()
    };
    // --workers ep1,ep2 promotes the devices to `nomad worker` processes;
    // each endpoint is one device, paging its clusters from --shards
    let placement = match args.get("workers") {
        Some(list) => {
            let dir = args
                .get("shards")
                .context("--workers requires --shards <dir> (written by `nomad shard`)")?;
            let endpoints: Vec<String> = list
                .split(',')
                .map(|e| e.trim().to_string())
                .filter(|e| !e.is_empty())
                .collect();
            if endpoints.is_empty() {
                bail!("--workers needs at least one endpoint (host:port or unix:/path)");
            }
            Placement::Remote { endpoints, shards: Path::new(dir).to_path_buf() }
        }
        None => Placement::InProcess,
    };
    let run_cfg = RunConfig {
        n_devices: args.usize("devices", 1),
        backend: if args.bool("xla") { BackendKind::Xla } else { BackendKind::Native },
        index: index_params(args),
        placement,
        verbose: !args.bool("quiet"),
        recovery: RecoveryCfg {
            max_recoveries: args.usize("max-recoveries", 3),
            ..Default::default()
        },
        ..Default::default()
    };
    let coord = NomadCoordinator::new(params, run_cfg);

    let run = match args.get("checkpoint-dir") {
        None => {
            if args.bool("resume") {
                bail!("--resume requires --checkpoint-dir (or use `nomad resume --run <dir>`)");
            }
            match &coord.run.placement {
                // worker sockets can fail mid-run: take the fallible path
                Placement::Remote { .. } => {
                    let prep = coord.prepare(&ds.x, &native_backend(args));
                    coord.fit_resumable(ds.n(), &prep, None)?
                }
                Placement::InProcess => coord.fit(&ds, &native_backend(args)),
            }
        }
        Some(dir) => {
            let dir = Path::new(dir);
            let fp = params_fingerprint(ds.n(), &coord.params, &coord.run.index);
            let cfg = checkpoint_cfg(args, &ds);
            let spec = dataset_spec(args, &ds);
            // open/validate (or create) the store *before* the expensive
            // index build, so a bad --checkpoint-dir fails instantly
            if args.bool("resume") {
                let mut store = RunStore::open(dir)?;
                if store.fingerprint() != fp {
                    bail!(
                        "run store at {} was written under different params \
                         (fingerprint {:08x} != {fp:08x})",
                        dir.display(),
                        store.fingerprint()
                    );
                }
                // the fingerprint covers params, not data: also refuse a
                // dataset spec that differs from the one the store recorded
                let (_, _, _, _, stored_spec) = checkpoint::parse_run_info(store.run_info())?;
                if spec != stored_spec {
                    bail!(
                        "run store at {} was trained on {:?}, not {:?} — resuming \
                         on different data would silently diverge",
                        dir.display(),
                        stored_spec,
                        spec
                    );
                }
                // tolerate a torn newest checkpoint (killed mid-write):
                // fall back to the newest one that reads clean
                let state = store.load_latest_valid()?;
                println!(
                    "resuming from checkpoint @ epoch {} / {}",
                    state.epochs_done, coord.params.epochs
                );
                let prep = coord.prepare(&ds.x, &native_backend(args));
                coord.resume_from(ds.n(), &prep, state, Some((&mut store, &cfg)))?
            } else {
                let info = checkpoint::run_info_json(
                    ds.n(),
                    coord.run.n_devices,
                    &coord.params,
                    &coord.run.index,
                    &spec,
                );
                let mut store = RunStore::create(dir, fp, info)?;
                println!(
                    "run store: {} (checkpoint every {} epochs, retain {})",
                    dir.display(),
                    cfg.every,
                    cfg.retain
                );
                let prep = coord.prepare(&ds.x, &native_backend(args));
                coord.fit_resumable(ds.n(), &prep, Some((&mut store, &cfg)))?
            }
        }
    };
    if let Some(path) = &trace_out {
        obs::trace::set_enabled(false);
        let spans = obs::trace::take_all();
        obs::export::write_chrome_trace(path, &spans)?;
        println!(
            "trace: {} ({} spans — load in chrome://tracing or Perfetto)",
            path.display(),
            spans.len()
        );
    }
    write_outputs(args, &ds, &coord, &run)
}

/// `nomad resume --run <dir>` — rebuild a run from its store alone and
/// continue from a checkpoint (latest, or `--from-epoch E`).
fn cmd_resume(args: &Args) -> Result<()> {
    let dir_s = args
        .get("run")
        .context("--run <run_dir> required (written by `nomad embed --checkpoint-dir`)")?;
    let dir = Path::new(dir_s);
    let mut store = RunStore::open(dir)?;
    let (n, n_devices, params, index, spec) = checkpoint::parse_run_info(store.run_info())
        .context("run.json is missing the run description")?;
    let ds = dataset_from_spec(&spec)?;
    if ds.n() != n {
        bail!("dataset rebuilt from the run spec has {} points, the run recorded {n}", ds.n());
    }
    println!("run store: {} | dataset {} ({} x {})", dir.display(), ds.name, ds.n(), ds.dim());

    let run_cfg = RunConfig {
        n_devices,
        backend: BackendKind::Native,
        index,
        verbose: !args.bool("quiet"),
        ..Default::default()
    };
    let coord = NomadCoordinator::new(params, run_cfg);
    let fp = params_fingerprint(ds.n(), &coord.params, &coord.run.index);
    if fp != store.fingerprint() {
        bail!(
            "run.json run description does not match its own fingerprint \
             ({fp:08x} != {:08x}) — store is corrupt or hand-edited",
            store.fingerprint()
        );
    }
    let state = match args.try_parse::<usize>("from-epoch")? {
        Some(e) => store.load(e)?,
        // the newest checkpoint that reads clean (a kill can tear the last)
        None => store.load_latest_valid()?,
    };
    println!("resuming from checkpoint @ epoch {} / {}", state.epochs_done, coord.params.epochs);

    let cfg = checkpoint_cfg(args, &ds);
    let prep = coord.prepare(&ds.x, &native_backend(args));
    let run = coord.resume_from(ds.n(), &prep, state, Some((&mut store, &cfg)))?;
    write_outputs(args, &ds, &coord, &run)
}

/// `nomad shard --out <dir>` — build the index for a dataset and cut it
/// into the mmap shard set `nomad worker` processes page from.  Uses the
/// same RNG stream prefix as the coordinator's `prepare` (a fresh
/// `Rng::new(seed)` feeding the index build), so the shard topology is
/// identical to what `nomad embed` with the same flags builds in-process.
fn cmd_shard(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let out = args.get("out").context("--out <dir> required")?;
    let seed = args.u64("seed", 42); // same default as `embed`'s run seed
    let idxp = index_params(args);
    let weight_model = NomadParams::default().weight_model;
    println!("dataset: {} ({} x {})", ds.name, ds.n(), ds.dim());

    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let index = ClusterIndex::build(&ds.x, &idxp, &native_backend(args), &mut rng);
    let weights = edge_weights(&index, weight_model);
    let spec = dataset_spec(args, &ds);
    let manifest = shard::write_shards(
        Path::new(out),
        &index,
        &weights,
        ds.dim(),
        seed,
        weight_model,
        &idxp,
        &spec,
    )?;
    let bytes: u64 = manifest.clusters.iter().map(|c| c.len).sum();
    println!(
        "shard set: {out}/ ({} clusters, {} points, {} bytes) in {:.2}s",
        manifest.clusters.len(),
        manifest.n,
        bytes,
        t0.elapsed().as_secs_f64()
    );
    println!("serve it:  nomad worker --shards {out} --listen 127.0.0.1:7701");
    Ok(())
}

/// `nomad worker --shards <dir> --listen <addr>` — one device as an OS
/// process.  Binds, waits for the coordinator, trains its assigned
/// clusters, exits when the coordinator sends Stop (or hangs up).
/// `--handshake-timeout-ms` bounds half-open connections,
/// `--session-timeout-ms` bounds an idle session (0 = wait forever),
/// `--max-sessions N` exits after serving N coordinator sessions,
/// `--metrics-listen <addr>` exposes the process's Prometheus metrics, and
/// `--no-telemetry` turns the registry off (the CI zero-perturbation gate
/// A/Bs this across a real multiprocess run).
fn cmd_worker(args: &Args) -> Result<()> {
    if args.bool("no-telemetry") {
        obs::metrics::set_enabled(false);
    }
    let listen = args
        .get("listen")
        .context("--listen <host:port | unix:/path.sock> required")?;
    let dir = args
        .get("shards")
        .context("--shards <dir> required (written by `nomad shard`)")?;
    let ep = Endpoint::parse(listen)?;
    let cfg = WorkerCfg {
        verbose: !args.bool("quiet"),
        handshake_timeout: Duration::from_millis(args.u64("handshake-timeout-ms", 10_000).max(1)),
        session_timeout: match args.u64("session-timeout-ms", 600_000) {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        },
        max_sessions: args.try_parse::<usize>("max-sessions")?,
        faults: Vec::new(),
    };
    if let Some(addr) = args.get("metrics-listen") {
        let bound = obs::export::spawn_metrics_listener(addr)?;
        eprintln!("worker: metrics on http://{bound}/");
    }
    worker::run_worker(&ep, Path::new(dir), &cfg)
}

/// Shared output path of `embed` and `resume`: positions `.npy`, density
/// map `.png`, serving artifact, quality metrics.
fn write_outputs(
    args: &Args,
    ds: &Dataset,
    coord: &NomadCoordinator,
    run: &NomadRun,
) -> Result<()> {
    println!(
        "done: {} clusters | index {:.2}s | train {:.2}s ({:.3}s modeled) | final loss {:.5}",
        run.n_clusters,
        run.index_secs,
        run.train_secs,
        run.modeled_train_secs,
        run.loss_history.last().unwrap_or(&f64::NAN)
    );

    let out = args.str("out", "out/nomad");
    if let Some(dir) = Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let pos_path = format!("{out}_positions.npy");
    NpyF32::new(vec![ds.n(), 2], run.positions.data.clone()).save(Path::new(&pos_path))?;
    println!("positions: {pos_path}");

    let labels = dataset_labels(ds);
    if !args.bool("no-png") {
        let view = View::fit(&run.positions);
        let r = density_map(&run.positions, labels.as_deref(), &view, 900, 900);
        let png_path = format!("{out}_map.png");
        png::write_rgb(Path::new(&png_path), r.width, r.height, &r.pixels)?;
        println!("map: {png_path}");
    }
    // persist the serving-layer artifact (positions + labels + bounds +
    // provenance) so `nomad serve` can pick the run up standalone
    if !args.bool("no-artifact") {
        let art = MapArtifact::from_run(
            run.positions.clone(),
            labels,
            Provenance {
                dataset: ds.name.clone(),
                seed: coord.params.seed,
                epochs: coord.params.epochs,
                final_loss: *run.loss_history.last().unwrap_or(&f64::NAN),
            },
        )?;
        let art_dir = format!("{out}_artifact");
        art.save(Path::new(&art_dir))?;
        println!("artifact: {art_dir}/ (serve: nomad serve --artifact {art_dir})");
    }
    if !args.bool("no-metrics") {
        let (np, rta) = evaluate(ds, &run.positions, &EvalCfg::default());
        println!("NP@10 = {:.1}%  RTA = {:.1}%", np * 100.0, rta * 100.0);
    }
    Ok(())
}

/// `nomad serve` — the map serving subsystem's CLI face.  Either a static
/// `--artifact <dir>`, or `--watch <run_dir>` to follow a training run's
/// checkpoints live.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = ServeConfig {
        addr: args.str("addr", "127.0.0.1:8080").to_string(),
        workers: args.usize("workers", 8),
        backlog: args.usize("backlog", 64),
        cache_entries: args.usize("cache", 2048),
        tile: TileConfig {
            tile_px: args.usize("tile-px", 256),
            max_points: args.usize("max-tile-points", 50_000),
            seed: args.u64("tile-seed", 0),
            max_zoom: args.usize("max-zoom", 20) as u32,
        },
    };

    if let Some(run_dir) = args.get("watch") {
        let dir = Path::new(run_dir);
        let poll = Duration::from_millis(args.u64("watch-poll-ms", 500).max(1));
        // the store exists from the first epoch of `nomad embed
        // --checkpoint-dir`; wait (with a notice) for its first artifact
        let mut waiting = false;
        loop {
            let store = RunStore::open(dir)?; // not a run store -> hard error
            let ready = store
                .checkpoints()
                .iter()
                .any(|&e| store.artifact_dir(e).join("manifest.json").exists());
            if ready {
                break;
            }
            if !waiting {
                println!("waiting for the first checkpoint artifact in {}...", dir.display());
                waiting = true;
            }
            std::thread::sleep(poll);
        }
        let handle = serve::http::start_watching(dir, &cfg, poll)?;
        println!(
            "watching {} on http://{} (generation = checkpoint epoch, poll {:?})",
            dir.display(),
            handle.addr,
            poll
        );
        println!(
            "  GET /tiles/{{z}}/{{x}}/{{y}}.png  |  GET /query?x=&y=&k=  |  GET /stats  |  \
             GET /metrics"
        );
        handle.wait();
        return Ok(());
    }

    let dir = args
        .get("artifact")
        .context("--artifact <dir> (written by `nomad embed`) or --watch <run_dir> required")?;
    let art = MapArtifact::load(Path::new(dir))?;
    let n = art.positions.rows;
    let handle = serve::http::start(art, &cfg)?;
    println!("serving {} points ({}) on http://{}", n, dir, handle.addr);
    println!(
        "  GET /tiles/{{z}}/{{x}}/{{y}}.png  |  GET /query?x=&y=&k=  |  GET /stats  |  \
         GET /metrics"
    );
    handle.wait();
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let mut rng = Rng::new(args.u64("seed", 0));
    let t0 = std::time::Instant::now();
    let idx = ClusterIndex::build(&ds.x, &index_params(args), &native_backend(args), &mut rng);
    let secs = t0.elapsed().as_secs_f64();
    let sizes: Vec<usize> = idx.clusters.iter().map(|c| c.len()).collect();
    println!(
        "index: {} clusters over {} points in {:.2}s",
        idx.n_clusters(),
        idx.n(),
        secs
    );
    println!(
        "cluster sizes: min {} / median {} / max {}",
        sizes.iter().min().unwrap(),
        {
            let mut s = sizes.clone();
            s.sort_unstable();
            s[s.len() / 2]
        },
        sizes.iter().max().unwrap()
    );
    println!("kNN edge mutuality: {:.1}%", mutuality(&idx) * 100.0);
    println!(
        "invariant (edges stay in clusters): {}",
        if idx.edges_respect_clusters() { "OK" } else { "VIOLATED" }
    );
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let emb_path = args.get("embedding").context("--embedding <positions.npy> required")?;
    let e = NpyF32::load(Path::new(emb_path))?;
    if e.shape != vec![ds.n(), 2] {
        bail!("embedding shape {:?} != [{}, 2]", e.shape, ds.n());
    }
    let y = Matrix::from_vec(ds.n(), 2, e.data);
    let cfg = EvalCfg {
        np_k: args.usize("np-k", 10),
        np_sample: args.usize("np-sample", 400),
        triplets: args.usize("triplets", 10_000),
        seed: args.u64("seed", 7),
    };
    let (np, rta) = evaluate(&ds, &y, &cfg);
    println!("NP@{} = {:.2}%  RTA = {:.2}%", cfg.np_k, np * 100.0, rta * 100.0);
    Ok(())
}

fn cmd_info() -> Result<()> {
    #[cfg(feature = "xla")]
    {
        let dir = nomad::runtime::artifacts_dir();
        println!("artifacts dir: {}", dir.display());
        match nomad::runtime::Manifest::load(&dir) {
            Ok(m) => {
                println!("manifest: {} artifacts", m.artifacts.len());
                for a in &m.artifacts {
                    println!("  {} ({}: {:?})", a.name, a.func, a.params);
                }
            }
            Err(e) => println!("manifest unavailable: {e} (run `make artifacts`)"),
        }
        match xla::PjRtClient::cpu() {
            Ok(c) => println!("PJRT: {} / {} device(s)", c.platform_name(), c.device_count()),
            Err(e) => println!("PJRT unavailable: {e}"),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("xla feature: disabled (pure-std offline build; --xla falls back to native)");
    println!("threads: {}", nomad::util::parallel::num_threads());
    Ok(())
}
