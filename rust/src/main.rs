//! `nomad` — the NOMAD Projection command-line launcher.
//!
//! Subcommands:
//!   embed    embed a dataset (synthetic generator or .npy file) and write
//!            positions (.npy) + an optional density map (.png) + a map
//!            artifact directory for the serving layer
//!   serve    serve a map artifact over HTTP: LOD tiles, kNN point
//!            queries, and cache/latency stats (DESIGN.md §10)
//!   index    build and report on the K-Means ANN index only
//!   metrics  score an embedding (.npy) against its source data (.npy)
//!   info     print artifact-manifest and environment diagnostics
//!
//! Examples:
//!   nomad embed --data wikipedia --n 20000 --devices 8 --out out/wiki
//!   nomad embed --npy vectors.npy --epochs 200 --xla --out out/run1
//!   nomad embed --data pubmed --n 50000 --threads 8 --out out/pm
//!   nomad serve --artifact out/wiki_artifact --addr 127.0.0.1:8080
//!   nomad metrics --npy vectors.npy --embedding out/run1_positions.npy
//!   nomad info
//!
//! `--threads N` (or the NOMAD_THREADS env var) bounds the worker threads
//! used by the parallel kernels; the default is the machine's parallelism.

use nomad::ann::backend::NativeBackend;
use nomad::ann::graph::mutuality;
use nomad::ann::{ClusterIndex, IndexParams};
use nomad::cli::Args;
use nomad::coordinator::{BackendKind, NomadCoordinator, RunConfig};
use nomad::data::{self, Dataset};
use nomad::embed::NomadParams;
use nomad::harness::{evaluate, EvalCfg};
use nomad::linalg::Matrix;
use nomad::serve::{self, MapArtifact, Provenance, ServeConfig, TileConfig};
use nomad::util::error::{Context, Result};
use nomad::util::npy::NpyF32;
use nomad::util::rng::Rng;
use nomad::viz::{density_map, png, View};
use nomad::bail;
use std::path::Path;

fn main() -> Result<()> {
    let args = Args::from_env();
    args.apply_thread_flag();
    match args.positional.first().map(|s| s.as_str()) {
        Some("embed") => cmd_embed(&args),
        Some("serve") => cmd_serve(&args),
        Some("index") => cmd_index(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: nomad <embed|serve|index|metrics|info> [flags]  (see --help in source)"
            );
            Ok(())
        }
    }
}

fn load_dataset(args: &Args) -> Result<Dataset> {
    if let Some(path) = args.get("npy") {
        let t = NpyF32::load(Path::new(path))?;
        if t.shape.len() != 2 {
            bail!("expected 2-d array, got shape {:?}", t.shape);
        }
        let (n, d) = (t.shape[0], t.shape[1]);
        Ok(Dataset {
            x: Matrix::from_vec(n, d, t.data),
            labels: vec![vec![0; n]],
            name: path.to_string(),
        })
    } else {
        let n = args.usize("n", 10_000);
        let mut rng = Rng::new(args.u64("seed", 0));
        let name = args.str("data", "arxiv");
        Ok(match name {
            "arxiv" => data::text_corpus_like(n, &mut rng),
            "imagenet" => data::image_corpus_like(n, &mut rng),
            "pubmed" => data::pubmed_like(n, &mut rng),
            "wikipedia" => data::wikipedia_like(n, &mut rng),
            other => bail!("unknown --data '{other}' (arxiv|imagenet|pubmed|wikipedia)"),
        })
    }
}

fn index_params(args: &Args) -> IndexParams {
    IndexParams {
        n_clusters: args.usize("clusters", 64),
        k: args.usize("k", 15),
        max_cluster_size: args.usize("max-cluster", 8192),
        ..Default::default()
    }
}

fn cmd_embed(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    println!("dataset: {} ({} x {})", ds.name, ds.n(), ds.dim());
    let params = NomadParams {
        epochs: args.usize("epochs", 200),
        k: args.usize("k", 15),
        negs: args.usize("negs", 8),
        pca_init: !args.bool("random-init"),
        seed: args.u64("seed", 42),
        ..Default::default()
    };
    let run_cfg = RunConfig {
        n_devices: args.usize("devices", 1),
        backend: if args.bool("xla") { BackendKind::Xla } else { BackendKind::Native },
        index: index_params(args),
        verbose: !args.bool("quiet"),
        ..Default::default()
    };
    let coord = NomadCoordinator::new(params, run_cfg);
    let run = coord.fit(&ds, &NativeBackend::default());
    println!(
        "done: {} clusters | index {:.2}s | train {:.2}s ({:.3}s modeled) | final loss {:.5}",
        run.n_clusters,
        run.index_secs,
        run.train_secs,
        run.modeled_train_secs,
        run.loss_history.last().unwrap_or(&f64::NAN)
    );

    let out = args.str("out", "out/nomad");
    if let Some(dir) = Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let pos_path = format!("{out}_positions.npy");
    NpyF32::new(vec![ds.n(), 2], run.positions.data.clone()).save(Path::new(&pos_path))?;
    println!("positions: {pos_path}");

    let labels: Option<Vec<u32>> = if ds.labels[0].iter().any(|&l| l != 0) {
        Some(ds.fine_labels().to_vec())
    } else {
        None
    };
    if !args.bool("no-png") {
        let view = View::fit(&run.positions);
        let r = density_map(&run.positions, labels.as_deref(), &view, 900, 900);
        let png_path = format!("{out}_map.png");
        png::write_rgb(Path::new(&png_path), r.width, r.height, &r.pixels)?;
        println!("map: {png_path}");
    }
    // persist the serving-layer artifact (positions + labels + bounds +
    // provenance) so `nomad serve` can pick the run up standalone
    if !args.bool("no-artifact") {
        let art = MapArtifact::from_run(
            run.positions.clone(),
            labels.clone(),
            Provenance {
                dataset: ds.name.clone(),
                seed: coord.params.seed,
                epochs: coord.params.epochs,
                final_loss: *run.loss_history.last().unwrap_or(&f64::NAN),
            },
        )?;
        let art_dir = format!("{out}_artifact");
        art.save(Path::new(&art_dir))?;
        println!("artifact: {art_dir}/ (serve: nomad serve --artifact {art_dir})");
    }
    if !args.bool("no-metrics") {
        let (np, rta) = evaluate(&ds, &run.positions, &EvalCfg::default());
        println!("NP@10 = {:.1}%  RTA = {:.1}%", np * 100.0, rta * 100.0);
    }
    Ok(())
}

/// `nomad serve --artifact <dir>` — the map serving subsystem's CLI face.
fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifact")
        .context("--artifact <dir> required (written by `nomad embed`)")?;
    let art = MapArtifact::load(Path::new(dir))?;
    let n = art.positions.rows;
    let cfg = ServeConfig {
        addr: args.str("addr", "127.0.0.1:8080").to_string(),
        workers: args.usize("workers", 8),
        backlog: args.usize("backlog", 64),
        cache_entries: args.usize("cache", 2048),
        tile: TileConfig {
            tile_px: args.usize("tile-px", 256),
            max_points: args.usize("max-tile-points", 50_000),
            seed: args.u64("tile-seed", 0),
            max_zoom: args.usize("max-zoom", 20) as u32,
        },
    };
    let handle = serve::http::start(art, &cfg)?;
    println!(
        "serving {} points ({}) on http://{}",
        n,
        args.str("artifact", "?"),
        handle.addr
    );
    println!("  GET /tiles/{{z}}/{{x}}/{{y}}.png  |  GET /query?x=&y=&k=  |  GET /stats");
    handle.wait();
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let mut rng = Rng::new(args.u64("seed", 0));
    let t0 = std::time::Instant::now();
    let idx = ClusterIndex::build(&ds.x, &index_params(args), &NativeBackend::default(), &mut rng);
    let secs = t0.elapsed().as_secs_f64();
    let sizes: Vec<usize> = idx.clusters.iter().map(|c| c.len()).collect();
    println!(
        "index: {} clusters over {} points in {:.2}s",
        idx.n_clusters(),
        idx.n(),
        secs
    );
    println!(
        "cluster sizes: min {} / median {} / max {}",
        sizes.iter().min().unwrap(),
        {
            let mut s = sizes.clone();
            s.sort_unstable();
            s[s.len() / 2]
        },
        sizes.iter().max().unwrap()
    );
    println!("kNN edge mutuality: {:.1}%", mutuality(&idx) * 100.0);
    println!(
        "invariant (edges stay in clusters): {}",
        if idx.edges_respect_clusters() { "OK" } else { "VIOLATED" }
    );
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let ds = load_dataset(args)?;
    let emb_path = args.get("embedding").context("--embedding <positions.npy> required")?;
    let e = NpyF32::load(Path::new(emb_path))?;
    if e.shape != vec![ds.n(), 2] {
        bail!("embedding shape {:?} != [{}, 2]", e.shape, ds.n());
    }
    let y = Matrix::from_vec(ds.n(), 2, e.data);
    let cfg = EvalCfg {
        np_k: args.usize("np-k", 10),
        np_sample: args.usize("np-sample", 400),
        triplets: args.usize("triplets", 10_000),
        seed: args.u64("seed", 7),
    };
    let (np, rta) = evaluate(&ds, &y, &cfg);
    println!("NP@{} = {:.2}%  RTA = {:.2}%", cfg.np_k, np * 100.0, rta * 100.0);
    Ok(())
}

fn cmd_info() -> Result<()> {
    #[cfg(feature = "xla")]
    {
        let dir = nomad::runtime::artifacts_dir();
        println!("artifacts dir: {}", dir.display());
        match nomad::runtime::Manifest::load(&dir) {
            Ok(m) => {
                println!("manifest: {} artifacts", m.artifacts.len());
                for a in &m.artifacts {
                    println!("  {} ({}: {:?})", a.name, a.func, a.params);
                }
            }
            Err(e) => println!("manifest unavailable: {e} (run `make artifacts`)"),
        }
        match xla::PjRtClient::cpu() {
            Ok(c) => println!("PJRT: {} / {} device(s)", c.platform_name(), c.device_count()),
            Err(e) => println!("PJRT unavailable: {e}"),
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("xla feature: disabled (pure-std offline build; --xla falls back to native)");
    println!("threads: {}", nomad::util::parallel::num_threads());
    Ok(())
}
