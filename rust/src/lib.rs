//! # NOMAD Projection
//!
//! A production-grade reproduction of *NOMAD Projection* (Duderstadt,
//! Nussbaum, van der Maaten, 2025): distributed nonlinear dimensionality
//! reduction that approximates an upper bound on the InfoNC-t-SNE loss so
//! the computation factorizes across devices.
//!
//! Architecture (see DESIGN.md):
//! * **Layer 3 (this crate)** — the coordinator: K-Means ANN index, cluster
//!   sharding, simulated multi-device runtime with all-gathered cluster
//!   means, SGD schedule, metrics, benches.
//! * **Layer 2 (python/compile)** — JAX shard-step graph, AOT-lowered to
//!   HLO text artifacts loaded at runtime via PJRT (`runtime` — manifest
//!   parsing is always built; the PJRT executor sits behind the
//!   off-by-default `xla` cargo feature, so the default build is pure std
//!   and works fully offline).
//! * **Layer 1 (python/compile/kernels)** — Pallas force/assignment/kNN
//!   kernels, interpret-mode for CPU execution.
pub mod bench;
pub mod cli;
pub mod harness;
pub mod util;
pub mod linalg;
pub mod data;
pub mod ann;
pub mod baselines;
pub mod metrics;
pub mod obs;
pub mod viz;
pub mod checkpoint;
pub mod coordinator;
pub mod distributed;
pub mod embed;
pub mod serve;
pub mod runtime;
