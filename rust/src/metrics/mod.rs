//! Evaluation metrics (paper §4).
//!
//! * **Neighborhood preservation at k (NP@k)** — mean overlap between the
//!   k-neighborhoods in the ambient and embedded spaces; the paper's local
//!   structure measure (Table 1 reports NP@10).
//! * **Random triplet accuracy (RTA)** — probability that a random triplet
//!   keeps its pairwise-distance ordering after embedding; the paper's
//!   global structure measure (after Wang et al. 2021).
//!
//! Ground-truth ambient kNN is exact brute force (O(n²d), parallel); for
//! large n both metrics are estimated on a uniform sample of query points,
//! exactly as the referenced papers do.

use crate::ann::knn::exact_global;
use crate::linalg::{d2, Matrix};
use crate::util::parallel::{num_threads, par_map};
use crate::util::rng::Rng;

/// NP@k between the high-dim data `x` and the embedding `y`, estimated on
/// `sample` query points (all points when `sample >= n`).
pub fn neighborhood_preservation(
    x: &Matrix,
    y: &Matrix,
    k: usize,
    sample: usize,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(x.rows, y.rows);
    let n = x.rows;
    if n <= k + 1 {
        return 1.0;
    }
    let queries: Vec<usize> = if sample >= n {
        (0..n).collect()
    } else {
        rng.sample_distinct(n, sample)
    };
    let threads = num_threads();
    let overlaps: Vec<f64> = par_map(queries.len(), threads, |qi| {
        let q = queries[qi];
        let hi = knn_of(x, q, k);
        let lo = knn_of(y, q, k);
        let hi_set: std::collections::HashSet<u32> = hi.into_iter().collect();
        let inter = lo.iter().filter(|j| hi_set.contains(j)).count();
        inter as f64 / k as f64
    });
    overlaps.iter().sum::<f64>() / overlaps.len().max(1) as f64
}

/// Exact k nearest neighbors of one query point (excluding self).
fn knn_of(m: &Matrix, q: usize, k: usize) -> Vec<u32> {
    let mut best: Vec<(f32, u32)> = Vec::with_capacity(k + 1);
    let row = m.row(q);
    for j in 0..m.rows {
        if j == q {
            continue;
        }
        let dist = d2(row, m.row(j));
        if best.len() < k {
            best.push((dist, j as u32));
            if best.len() == k {
                best.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            }
        } else if dist < best[0].0 {
            best[0] = (dist, j as u32);
            let mut p = 0;
            while p + 1 < k && best[p].0 < best[p + 1].0 {
                best.swap(p, p + 1);
                p += 1;
            }
        }
    }
    best.into_iter().map(|(_, j)| j).collect()
}

/// Random triplet accuracy on `triplets` sampled triplets.
pub fn random_triplet_accuracy(
    x: &Matrix,
    y: &Matrix,
    triplets: usize,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(x.rows, y.rows);
    let n = x.rows;
    if n < 3 {
        return 1.0;
    }
    // pre-sample to keep rng sequential, evaluate in parallel
    let samples: Vec<[usize; 3]> = (0..triplets)
        .map(|_| {
            let a = rng.below(n);
            let mut b = rng.below(n);
            while b == a {
                b = rng.below(n);
            }
            let mut c = rng.below(n);
            while c == a || c == b {
                c = rng.below(n);
            }
            [a, b, c]
        })
        .collect();
    let threads = num_threads();
    let hits: Vec<u32> = par_map(samples.len(), threads, |t| {
        let [a, b, c] = samples[t];
        let hi = d2(x.row(a), x.row(b)) < d2(x.row(a), x.row(c));
        let lo = d2(y.row(a), y.row(b)) < d2(y.row(a), y.row(c));
        (hi == lo) as u32
    });
    hits.iter().sum::<u32>() as f64 / hits.len().max(1) as f64
}

/// Exact global kNN indices (ground truth helper re-export).
pub fn exact_knn_indices(x: &Matrix, k: usize) -> Vec<u32> {
    exact_global(x, k)
}

/// kNN-classification label agreement in the embedding: the fraction of
/// points whose embedded nearest neighbor shares their generator label.
/// A cheap supervised sanity check for the synthetic corpora.
pub fn label_knn_agreement(y: &Matrix, labels: &[u32], sample: usize, rng: &mut Rng) -> f64 {
    let n = y.rows;
    let queries: Vec<usize> =
        if sample >= n { (0..n).collect() } else { rng.sample_distinct(n, sample) };
    let threads = num_threads();
    let hits: Vec<u32> = par_map(queries.len(), threads, |qi| {
        let q = queries[qi];
        let nn = knn_of(y, q, 1)[0] as usize;
        (labels[nn] == labels[q]) as u32
    });
    hits.iter().sum::<u32>() as f64 / hits.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[test]
    fn identity_embedding_is_perfect() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 200, 2);
        let np = neighborhood_preservation(&x, &x, 10, 200, &mut rng);
        assert!((np - 1.0).abs() < 1e-12);
        let rta = random_triplet_accuracy(&x, &x, 2000, &mut rng);
        assert!((rta - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scaling_and_rotation_preserve_metrics() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 150, 2);
        // rotate by 30 degrees and scale by 5
        let (s, c) = (0.5f32, 3f32.sqrt() / 2.0);
        let mut y = Matrix::zeros(150, 2);
        for i in 0..150 {
            let (a, b) = (x.row(i)[0], x.row(i)[1]);
            y.row_mut(i)[0] = 5.0 * (c * a - s * b);
            y.row_mut(i)[1] = 5.0 * (s * a + c * b);
        }
        let np = neighborhood_preservation(&x, &y, 10, 150, &mut rng);
        assert!(np > 0.999, "np {np}");
        let rta = random_triplet_accuracy(&x, &y, 2000, &mut rng);
        assert!(rta > 0.999, "rta {rta}");
    }

    #[test]
    fn random_embedding_scores_low() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 300, 8);
        let y = randm(&mut rng, 300, 2);
        let np = neighborhood_preservation(&x, &y, 10, 300, &mut rng);
        assert!(np < 0.15, "np of random embedding {np}");
        let rta = random_triplet_accuracy(&x, &y, 4000, &mut rng);
        assert!((rta - 0.5).abs() < 0.08, "rta of random embedding {rta}");
    }

    #[test]
    fn sampled_estimate_tracks_full() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 400, 4);
        let mut y = Matrix::zeros(400, 2);
        for i in 0..400 {
            y.row_mut(i)[0] = x.row(i)[0];
            y.row_mut(i)[1] = x.row(i)[1];
        }
        let full = neighborhood_preservation(&x, &y, 10, 400, &mut rng);
        let est = neighborhood_preservation(&x, &y, 10, 150, &mut rng);
        assert!((full - est).abs() < 0.1, "full {full} est {est}");
    }

    #[test]
    fn label_agreement_for_separated_blobs() {
        let mut rng = Rng::new(4);
        let ds = crate::data::gaussian_mixture(300, 2, 3, 30.0, 0.0, 0.0, &mut rng);
        let agree = label_knn_agreement(&ds.x, &ds.labels[0], 300, &mut rng);
        assert!(agree > 0.99, "agreement {agree}");
    }
}
