//! Evaluation metrics (paper §4).
//!
//! * **Neighborhood preservation at k (NP@k)** — mean overlap between the
//!   k-neighborhoods in the ambient and embedded spaces; the paper's local
//!   structure measure (Table 1 reports NP@10).
//! * **Random triplet accuracy (RTA)** — probability that a random triplet
//!   keeps its pairwise-distance ordering after embedding; the paper's
//!   global structure measure (after Wang et al. 2021).
//!
//! Ground-truth ambient kNN is exact brute force on the tiled norm-trick
//! distance engine (`crate::linalg::distance`, DESIGN.md §8): the sampled
//! query rows are gathered into one batch and answered against the full
//! corpus in a single tiled pass per space.  For large n both metrics are
//! estimated on a uniform sample of query points, exactly as the
//! referenced papers do.

use crate::ann::knn::exact_global;
use crate::linalg::distance::knn_for_queries;
use crate::linalg::{d2, Matrix};
use crate::util::parallel::{num_threads, par_map};
use crate::util::rng::Rng;

/// NP@k between the high-dim data `x` and the embedding `y`, estimated on
/// `sample` query points (all points when `sample >= n`).
pub fn neighborhood_preservation(
    x: &Matrix,
    y: &Matrix,
    k: usize,
    sample: usize,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(x.rows, y.rows);
    let n = x.rows;
    if n <= k + 1 {
        return 1.0;
    }
    let queries: Vec<usize> = if sample >= n {
        (0..n).collect()
    } else {
        rng.sample_distinct(n, sample)
    };
    let qids: Vec<u32> = queries.iter().map(|&q| q as u32).collect();
    let threads = num_threads();
    // full-sample queries are the identity — skip the gather copy
    let (hi, lo) = if queries.len() == n {
        (
            knn_for_queries(x, &qids, x, k, threads),
            knn_for_queries(y, &qids, y, k, threads),
        )
    } else {
        let xq = x.gather(&queries);
        let yq = y.gather(&queries);
        (
            knn_for_queries(&xq, &qids, x, k, threads),
            knn_for_queries(&yq, &qids, y, k, threads),
        )
    };
    let mut total = 0.0f64;
    for qi in 0..queries.len() {
        let hi_set: std::collections::HashSet<u32> = hi[qi * k..(qi + 1) * k]
            .iter()
            .copied()
            .filter(|&j| j != u32::MAX)
            .collect();
        let inter = lo[qi * k..(qi + 1) * k]
            .iter()
            .filter(|j| hi_set.contains(j))
            .count();
        total += inter as f64 / k as f64;
    }
    total / queries.len().max(1) as f64
}

/// Random triplet accuracy on `triplets` sampled triplets.
pub fn random_triplet_accuracy(
    x: &Matrix,
    y: &Matrix,
    triplets: usize,
    rng: &mut Rng,
) -> f64 {
    assert_eq!(x.rows, y.rows);
    let n = x.rows;
    if n < 3 {
        return 1.0;
    }
    // pre-sample to keep rng sequential, evaluate in parallel
    let samples: Vec<[usize; 3]> = (0..triplets)
        .map(|_| {
            let a = rng.below(n);
            let mut b = rng.below(n);
            while b == a {
                b = rng.below(n);
            }
            let mut c = rng.below(n);
            while c == a || c == b {
                c = rng.below(n);
            }
            [a, b, c]
        })
        .collect();
    let threads = num_threads();
    let hits: Vec<u32> = par_map(samples.len(), threads, |t| {
        let [a, b, c] = samples[t];
        let hi = d2(x.row(a), x.row(b)) < d2(x.row(a), x.row(c));
        let lo = d2(y.row(a), y.row(b)) < d2(y.row(a), y.row(c));
        (hi == lo) as u32
    });
    hits.iter().sum::<u32>() as f64 / hits.len().max(1) as f64
}

/// Exact global kNN indices (ground truth helper re-export).
pub fn exact_knn_indices(x: &Matrix, k: usize) -> Vec<u32> {
    exact_global(x, k)
}

/// kNN-classification label agreement in the embedding: the fraction of
/// points whose embedded nearest neighbor shares their generator label.
/// A cheap supervised sanity check for the synthetic corpora.
pub fn label_knn_agreement(y: &Matrix, labels: &[u32], sample: usize, rng: &mut Rng) -> f64 {
    let n = y.rows;
    let queries: Vec<usize> =
        if sample >= n { (0..n).collect() } else { rng.sample_distinct(n, sample) };
    let qids: Vec<u32> = queries.iter().map(|&q| q as u32).collect();
    let nn = if queries.len() == n {
        knn_for_queries(y, &qids, y, 1, num_threads())
    } else {
        let yq = y.gather(&queries);
        knn_for_queries(&yq, &qids, y, 1, num_threads())
    };
    let mut hits = 0usize;
    for (qi, &q) in queries.iter().enumerate() {
        let j = nn[qi];
        if j != u32::MAX && labels[j as usize] == labels[q] {
            hits += 1;
        }
    }
    hits as f64 / queries.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randm(rng: &mut Rng, n: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    fn intm(rng: &mut Rng, n: usize, d: usize, hi: usize) -> Matrix {
        let mut m = Matrix::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.below(hi) as f32;
        }
        m
    }

    #[test]
    fn identity_embedding_is_perfect() {
        let mut rng = Rng::new(0);
        let x = randm(&mut rng, 200, 2);
        let np = neighborhood_preservation(&x, &x, 10, 200, &mut rng);
        assert!((np - 1.0).abs() < 1e-12);
        let rta = random_triplet_accuracy(&x, &x, 2000, &mut rng);
        assert!((rta - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scaling_and_rotation_preserve_metrics() {
        let mut rng = Rng::new(1);
        let x = randm(&mut rng, 150, 2);
        // rotate by 30 degrees and scale by 5
        let (s, c) = (0.5f32, 3f32.sqrt() / 2.0);
        let mut y = Matrix::zeros(150, 2);
        for i in 0..150 {
            let (a, b) = (x.row(i)[0], x.row(i)[1]);
            y.row_mut(i)[0] = 5.0 * (c * a - s * b);
            y.row_mut(i)[1] = 5.0 * (s * a + c * b);
        }
        let np = neighborhood_preservation(&x, &y, 10, 150, &mut rng);
        assert!(np > 0.999, "np {np}");
        let rta = random_triplet_accuracy(&x, &y, 2000, &mut rng);
        assert!(rta > 0.999, "rta {rta}");
    }

    #[test]
    fn random_embedding_scores_low() {
        let mut rng = Rng::new(2);
        let x = randm(&mut rng, 300, 8);
        let y = randm(&mut rng, 300, 2);
        let np = neighborhood_preservation(&x, &y, 10, 300, &mut rng);
        assert!(np < 0.15, "np of random embedding {np}");
        let rta = random_triplet_accuracy(&x, &y, 4000, &mut rng);
        assert!((rta - 0.5).abs() < 0.08, "rta of random embedding {rta}");
    }

    #[test]
    fn sampled_estimate_tracks_full() {
        let mut rng = Rng::new(3);
        let x = randm(&mut rng, 400, 4);
        let mut y = Matrix::zeros(400, 2);
        for i in 0..400 {
            y.row_mut(i)[0] = x.row(i)[0];
            y.row_mut(i)[1] = x.row(i)[1];
        }
        let full = neighborhood_preservation(&x, &y, 10, 400, &mut rng);
        let est = neighborhood_preservation(&x, &y, 10, 150, &mut rng);
        assert!((full - est).abs() < 0.1, "full {full} est {est}");
    }

    #[test]
    fn label_agreement_for_separated_blobs() {
        let mut rng = Rng::new(4);
        let ds = crate::data::gaussian_mixture(300, 2, 3, 30.0, 0.0, 0.0, &mut rng);
        let agree = label_knn_agreement(&ds.x, &ds.labels[0], 300, &mut rng);
        assert!(agree > 0.99, "agreement {agree}");
    }

    #[test]
    fn np_ground_truth_matches_naive_oracle_exactly() {
        // Integer-valued corpora: the engine's norm-trick distances are
        // exact, so its neighbor lists must equal the sort-everything
        // oracle's bitwise — including tie order — and the NP estimates
        // must agree to the last bit.
        let mut rng = Rng::new(5);
        let n = 120;
        let k = 5;
        let x = intm(&mut rng, n, 6, 5);
        let y = intm(&mut rng, n, 2, 5);
        let np = neighborhood_preservation(&x, &y, k, n, &mut rng);

        let hi = crate::ann::knn::exact_global_naive(&x, k);
        let lo = crate::ann::knn::exact_global_naive(&y, k);
        let mut total = 0.0f64;
        for i in 0..n {
            let hi_set: std::collections::HashSet<u32> =
                hi[i * k..(i + 1) * k].iter().copied().collect();
            let inter = lo[i * k..(i + 1) * k].iter().filter(|j| hi_set.contains(j)).count();
            total += inter as f64 / k as f64;
        }
        let np_naive = total / n as f64;
        assert_eq!(np, np_naive, "engine NP {np} vs naive {np_naive}");
    }
}
