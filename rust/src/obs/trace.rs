//! Lightweight span tracing keyed on (device, epoch, block, phase).
//!
//! Spans are timed off the sanctioned [`Stopwatch`] against one
//! process-wide origin and buffered in per-thread vectors; a buffer spills
//! into the global sink only when full, when its thread exits, or when the
//! owner calls [`flush_thread`] at a barrier — so the training data path
//! never contends on a shared lock.  Tracing is off by default and costs
//! one relaxed load per span site when off; clock reads happen only while
//! tracing is on, and the values flow only outward (into the trace file),
//! never back into computation.

use crate::util::clock::Stopwatch;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);

pub fn set_enabled(on: bool) {
    if on {
        origin(); // pin the time origin before the first span
    }
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// `device` value for coordinator-side phases (exported as pid 0).
pub const COORDINATOR: i64 = -1;

/// `block` value for spans covering a whole epoch phase, not one block.
pub const NO_BLOCK: i64 = -1;

/// One completed span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Device index, or [`COORDINATOR`].
    pub device: i64,
    pub epoch: u64,
    /// Block index within the device, or [`NO_BLOCK`].
    pub block: i64,
    pub phase: &'static str,
    /// Microseconds since the process trace origin.
    pub start_us: u64,
    pub dur_us: u64,
}

fn origin() -> &'static Stopwatch {
    static ORIGIN: OnceLock<Stopwatch> = OnceLock::new();
    ORIGIN.get_or_init(Stopwatch::start)
}

fn now_us() -> u64 {
    (origin().secs() * 1e6) as u64
}

/// Spill threshold for the per-thread buffer.
const FLUSH_AT: usize = 1024;

/// Thread-local span buffer; its `Drop` spills leftovers into the global
/// sink, so scoped pool threads (the block-parallel region) never lose
/// spans recorded after their last explicit flush.
struct LocalBuf(Vec<SpanRecord>);

impl Drop for LocalBuf {
    fn drop(&mut self) {
        if !self.0.is_empty() {
            sink().lock().unwrap().append(&mut self.0);
        }
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = const { RefCell::new(LocalBuf(Vec::new())) };
}

fn sink() -> &'static Mutex<Vec<SpanRecord>> {
    static SINK: OnceLock<Mutex<Vec<SpanRecord>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn record(rec: SpanRecord) {
    // A span can outlive its thread's LOCAL destructor during teardown;
    // fall back to the sink directly rather than lose (or panic on) it.
    let spill = LOCAL
        .try_with(|l| {
            let mut l = l.borrow_mut();
            l.0.push(rec.clone());
            if l.0.len() >= FLUSH_AT {
                Some(std::mem::take(&mut l.0))
            } else {
                None
            }
        })
        .unwrap_or_else(|_| Some(vec![rec]));
    if let Some(mut batch) = spill {
        sink().lock().unwrap().append(&mut batch);
    }
}

/// An in-flight span; records itself on drop.  Disarmed (free) when
/// tracing is off.
pub struct Span {
    device: i64,
    epoch: u64,
    block: i64,
    phase: &'static str,
    start_us: u64,
    armed: bool,
}

/// Open a span.  `phase` must be a static label (`"gradient"`,
/// `"comm_wait"`, ...) — the set of phases is the trace's vocabulary, not
/// a data channel.
pub fn span(device: i64, epoch: u64, block: i64, phase: &'static str) -> Span {
    if !enabled() {
        return Span { device, epoch, block, phase, start_us: 0, armed: false };
    }
    Span { device, epoch, block, phase, start_us: now_us(), armed: true }
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let end = now_us();
        record(SpanRecord {
            device: self.device,
            epoch: self.epoch,
            block: self.block,
            phase: self.phase,
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
        });
    }
}

/// Drain the calling thread's buffer into the global sink.  Call at
/// barriers (end of epoch, session teardown) — never inside a hot loop.
pub fn flush_thread() {
    let _ = LOCAL.try_with(|l| {
        let mut l = l.borrow_mut();
        if !l.0.is_empty() {
            sink().lock().unwrap().append(&mut l.0);
        }
    });
}

/// Flush the calling thread and take every recorded span, deterministically
/// ordered by (device, epoch, block, phase, start).
pub fn take_all() -> Vec<SpanRecord> {
    flush_thread();
    let mut spans = std::mem::take(&mut *sink().lock().unwrap());
    spans.sort_by(|a, b| {
        (a.device, a.epoch, a.block, a.phase, a.start_us)
            .cmp(&(b.device, b.epoch, b.block, b.phase, b.start_us))
    });
    spans
}

#[cfg(test)]
mod tests {
    use super::*;

    // one test fn: the enable flag is process-global, so splitting these
    // across #[test]s would race under the threaded test runner
    #[test]
    fn spans_flush_and_sort() {
        set_enabled(false);
        assert!(!span(0, 0, 0, "noop").armed, "disabled spans must disarm");
        set_enabled(true);
        drop(span(1, 0, NO_BLOCK, "b_phase"));
        drop(span(0, 0, 2, "a_phase"));
        std::thread::spawn(|| drop(span(0, 0, 1, "a_phase"))).join().unwrap();
        set_enabled(false);
        let spans = take_all();
        let mine: Vec<&SpanRecord> =
            spans.iter().filter(|s| s.phase == "a_phase" || s.phase == "b_phase").collect();
        assert_eq!(mine.len(), 3);
        assert_eq!((mine[0].device, mine[0].block), (0, 1));
        assert_eq!((mine[1].device, mine[1].block), (0, 2));
        assert_eq!((mine[2].device, mine[2].block), (1, NO_BLOCK));
        assert!(mine.iter().all(|s| s.start_us > 0 || s.dur_us < 1_000_000));
    }
}
