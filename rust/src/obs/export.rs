//! Exporters: Prometheus text exposition, JSON snapshots, Chrome
//! trace-event JSON, and a minimal scrape listener for worker processes.

use super::metrics::{self, Snapshot, Value};
use super::trace::{SpanRecord, COORDINATOR, NO_BLOCK};
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};
use std::io::{Read as _, Write as _};
use std::path::Path;

/// Render a snapshot in the Prometheus text exposition format (0.0.4):
/// `# HELP` / `# TYPE` per family, cumulative `_bucket{le=...}` series plus
/// `_sum` / `_count` for histograms.  Deterministic: families and series
/// come pre-sorted from the snapshot.
pub fn prometheus_text(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, fam) in &snap.families {
        out.push_str(&format!("# HELP {name} {}\n", fam.help.replace('\n', " ")));
        out.push_str(&format!("# TYPE {name} {}\n", fam.kind.name()));
        for (labels, v) in &fam.series {
            match v {
                Value::Counter(c) => {
                    out.push_str(&series_line(name, labels, &c.to_string()));
                }
                Value::Gauge(g) => {
                    out.push_str(&series_line(name, labels, &fmt_f64(*g)));
                }
                Value::Histogram { bounds, buckets, sum, .. } => {
                    let mut cum = 0u64;
                    for (i, b) in bounds.iter().enumerate() {
                        cum += buckets[i];
                        let le = with_le(labels, &fmt_f64(*b));
                        out.push_str(&series_line(&format!("{name}_bucket"), &le, &cum.to_string()));
                    }
                    // `+Inf` and `_count` come from the bucket sum, not the
                    // separate count atomic: a scrape racing `observe` could
                    // otherwise see a bucket increment the count atomic
                    // hasn't caught up with, rendering a cumulative series
                    // where `+Inf` < the last finite bucket.
                    let total: u64 = cum + buckets[bounds.len()];
                    let le = with_le(labels, "+Inf");
                    out.push_str(&series_line(&format!("{name}_bucket"), &le, &total.to_string()));
                    out.push_str(&series_line(&format!("{name}_sum"), labels, &fmt_f64(*sum)));
                    out.push_str(&series_line(&format!("{name}_count"), labels, &total.to_string()));
                }
            }
        }
    }
    out
}

fn series_line(name: &str, labels: &str, value: &str) -> String {
    if labels.is_empty() {
        format!("{name} {value}\n")
    } else {
        format!("{name}{{{labels}}} {value}\n")
    }
}

fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("le=\"{le}\"")
    } else {
        format!("{labels},le=\"{le}\"")
    }
}

fn fmt_f64(v: f64) -> String {
    // Rust's shortest round-trip float formatting is valid Prometheus
    // number syntax (integral floats render bare: 3.0 -> "3")
    format!("{v}")
}

/// A snapshot as a JSON value (the machine-readable sibling of
/// [`prometheus_text`], used by the per-epoch `run.json` telemetry and the
/// bench reports).
pub fn json_snapshot(snap: &Snapshot) -> Json {
    let mut families = Vec::new();
    for (name, fam) in &snap.families {
        let mut series = Vec::new();
        for (labels, v) in &fam.series {
            let val = match v {
                Value::Counter(c) => json::num(*c as f64),
                Value::Gauge(g) => json::num(*g),
                Value::Histogram { bounds, buckets, sum, count, max } => json::obj(vec![
                    ("bounds", json::arr(bounds.iter().map(|b| json::num(*b)).collect())),
                    ("buckets", json::arr(buckets.iter().map(|c| json::num(*c as f64)).collect())),
                    ("sum", json::num(*sum)),
                    ("count", json::num(*count as f64)),
                    ("max", json::num(*max)),
                ]),
            };
            series.push(json::obj(vec![("labels", json::s(labels)), ("value", val)]));
        }
        families.push(json::obj(vec![
            ("name", json::s(name)),
            ("kind", json::s(fam.kind.name())),
            ("help", json::s(&fam.help)),
            ("series", json::arr(series)),
        ]));
    }
    json::obj(vec![("families", json::arr(families))])
}

fn pid_of(device: i64) -> f64 {
    // coordinator (-1) renders as pid 0, device d as pid d+1
    (device + 1) as f64
}

/// Render spans as Chrome trace-event JSON (`chrome://tracing`, Perfetto):
/// one complete (`ph:"X"`) event per span, pid = device (coordinator is
/// pid 0), plus `process_name` metadata events so the flamegraph rows are
/// labeled.
pub fn chrome_trace(spans: &[SpanRecord]) -> Json {
    let mut events = Vec::new();
    let mut devices: Vec<i64> = spans.iter().map(|s| s.device).collect();
    devices.sort_unstable();
    devices.dedup();
    for d in devices {
        let label =
            if d == COORDINATOR { "coordinator".to_string() } else { format!("device {d}") };
        events.push(json::obj(vec![
            ("name", json::s("process_name")),
            ("ph", json::s("M")),
            ("pid", json::num(pid_of(d))),
            ("tid", json::num(0.0)),
            ("args", json::obj(vec![("name", json::s(&label))])),
        ]));
    }
    for s in spans {
        let mut args = vec![("epoch", json::num(s.epoch as f64))];
        if s.block != NO_BLOCK {
            args.push(("block", json::num(s.block as f64)));
        }
        events.push(json::obj(vec![
            ("name", json::s(s.phase)),
            ("ph", json::s("X")),
            ("pid", json::num(pid_of(s.device))),
            ("tid", json::num(0.0)),
            ("ts", json::num(s.start_us as f64)),
            ("dur", json::num(s.dur_us as f64)),
            ("args", json::obj(args)),
        ]));
    }
    json::obj(vec![("traceEvents", json::arr(events))])
}

/// Write spans as a Chrome trace file (`nomad embed --trace-out`).
pub fn write_chrome_trace(path: &Path, spans: &[SpanRecord]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace(spans).pretty())
        .with_context(|| format!("writing trace to {}", path.display()))
}

/// Spawn a minimal HTTP listener that answers every request with the
/// global registry's Prometheus exposition — the `nomad worker
/// --metrics-listen <addr>` surface.  Detached: runs for the life of the
/// process.  Returns the bound address (port 0 resolves).
pub fn spawn_metrics_listener(addr: &str) -> Result<std::net::SocketAddr> {
    let listener = std::net::TcpListener::bind(addr)
        .with_context(|| format!("binding metrics listener on {addr}"))?;
    let bound = listener.local_addr()?;
    let _detached = std::thread::Builder::new()
        .name("obs-metrics".to_string())
        .spawn(move || {
            for conn in listener.incoming() {
                let Ok(mut stream) = conn else { continue };
                // drain the request line politely, then answer; a scrape
                // client that pipelines gets Connection: close anyway
                let _ = stream.set_read_timeout(Some(std::time::Duration::from_secs(2)));
                let mut buf = [0u8; 1024];
                let _ = stream.read(&mut buf);
                let body = prometheus_text(&metrics::snapshot());
                let resp = format!(
                    "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4; \
                     charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = stream.write_all(resp.as_bytes());
            }
        })
        .context("spawning metrics listener thread")?;
    Ok(bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    #[test]
    fn prometheus_golden() {
        let r = Registry::new();
        r.counter("nomad_test_events_total", "Events seen.", &[("kind", "a")]).add(3);
        r.gauge("nomad_test_depth", "Queue depth.", &[]).set(2.5);
        let h = r.histogram("nomad_test_wait_seconds", "Wait time.", &[0.5, 2.0], &[]);
        // dyadic values: the CAS-accumulated sum is exact, so the golden
        // text is stable
        h.observe(0.25);
        h.observe(1.0);
        h.observe(2.0);
        h.observe(5.0);
        let text = prometheus_text(&r.snapshot());
        let expect = "\
# HELP nomad_test_depth Queue depth.
# TYPE nomad_test_depth gauge
nomad_test_depth 2.5
# HELP nomad_test_events_total Events seen.
# TYPE nomad_test_events_total counter
nomad_test_events_total{kind=\"a\"} 3
# HELP nomad_test_wait_seconds Wait time.
# TYPE nomad_test_wait_seconds histogram
nomad_test_wait_seconds_bucket{le=\"0.5\"} 1
nomad_test_wait_seconds_bucket{le=\"2\"} 3
nomad_test_wait_seconds_bucket{le=\"+Inf\"} 4
nomad_test_wait_seconds_sum 8.25
nomad_test_wait_seconds_count 4
";
        assert_eq!(text, expect);
    }

    /// A scrape can race `observe` between its bucket increment and its
    /// count increment. The exposition must stay internally consistent
    /// anyway: `+Inf` equals the bucket sum (monotone cumulative series)
    /// and `_count` equals `+Inf`, whatever the count atomic said.
    #[test]
    fn torn_histogram_snapshot_renders_monotone() {
        use crate::obs::metrics::{FamilySnap, Kind};
        use std::collections::BTreeMap;
        let torn = Value::Histogram {
            bounds: vec![1.0, 2.0],
            buckets: vec![2, 1, 1],
            sum: 5.0,
            count: 3, // lags the buckets by one observation
            max: 4.0,
        };
        let mut series = BTreeMap::new();
        series.insert(String::new(), torn);
        let mut families = BTreeMap::new();
        families.insert(
            "nomad_torn_seconds".to_string(),
            FamilySnap { help: "Torn.".to_string(), kind: Kind::Histogram, series },
        );
        let text = prometheus_text(&Snapshot { families });
        assert!(text.contains("nomad_torn_seconds_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("nomad_torn_seconds_count 4"), "{text}");
    }

    #[test]
    fn chrome_trace_golden() {
        let spans = vec![
            SpanRecord {
                device: COORDINATOR,
                epoch: 0,
                block: NO_BLOCK,
                phase: "comm_wait",
                start_us: 10,
                dur_us: 5,
            },
            SpanRecord {
                device: 0,
                epoch: 0,
                block: 2,
                phase: "gradient",
                start_us: 11,
                dur_us: 3,
            },
        ];
        let j = chrome_trace(&spans);
        let events = j.get("traceEvents").as_arr().expect("traceEvents");
        assert_eq!(events.len(), 4); // 2 metadata + 2 spans
        assert_eq!(events[0].get("ph").as_str(), Some("M"));
        let coord = &events[2];
        assert_eq!(coord.get("ph").as_str(), Some("X"));
        assert_eq!(coord.get("pid").as_f64(), Some(0.0));
        assert_eq!(coord.get("name").as_str(), Some("comm_wait"));
        assert_eq!(coord.get("ts").as_f64(), Some(10.0));
        assert_eq!(coord.get("dur").as_f64(), Some(5.0));
        let dev = &events[3];
        assert_eq!(dev.get("pid").as_f64(), Some(1.0));
        assert_eq!(dev.get("args").get("block").as_f64(), Some(2.0));
        // round-trips through the in-tree parser
        let reparsed = Json::parse(&j.pretty()).expect("trace json parses");
        assert_eq!(reparsed, j);
    }

    #[test]
    fn json_snapshot_shape() {
        let r = Registry::new();
        r.counter("c_total", "c", &[]).inc();
        let j = json_snapshot(&r.snapshot());
        let fams = j.get("families").as_arr().expect("families");
        assert_eq!(fams[0].get("name").as_str(), Some("c_total"));
        assert_eq!(fams[0].get("kind").as_str(), Some("counter"));
    }
}
