//! `obs` — zero-perturbation telemetry (DESIGN.md §15).
//!
//! Three pieces: a process-global registry of counters / gauges /
//! fixed-bucket histograms ([`metrics`]), lightweight span tracing keyed on
//! (device, epoch, block, phase) recorded off the sanctioned
//! [`crate::util::clock::Stopwatch`] ([`trace`]), and exporters for
//! Prometheus text exposition, JSON snapshots, and Chrome trace-event JSON
//! ([`export`]).
//!
//! The contract that makes this safe to leave on: telemetry records *out*
//! of the computation and never feeds a value *back in*.  No clock read,
//! counter, or span duration may influence floats that end up in
//! positions, means, or losses — CI gates that a fit with telemetry fully
//! enabled is bitwise identical to one with it disabled.  `obs` is the one
//! sanctioned telemetry sink for `distributed/` and `serve/`; the xtask
//! `obs_sink` lint rule keeps raw `Instant::now` / `SystemTime` reads out
//! of those trees so all timing flows through `util/clock.rs`.
pub mod export;
pub mod metrics;
pub mod trace;
