//! Process-global metrics registry: counters, gauges, and fixed-bucket
//! histograms with label sets.
//!
//! Hot-path recording is handle-based — a handle holds an `Arc` to its
//! atomics, so `inc`/`observe` touch no locks and no registry state.  When
//! recording is disabled ([`set_enabled`]) every record call reduces to one
//! relaxed load and a branch.  Registration (cold path) is lock-striped by
//! metric-name hash so concurrent registration from device threads does not
//! serialize on a single registry mutex.  Snapshots collate everything into
//! `BTreeMap`s keyed by name and rendered label set, so export order is
//! deterministic regardless of registration order or stripe layout.
//!
//! Metric naming convention (DESIGN.md §15): `nomad_<subsystem>_<what>`
//! with a unit suffix (`_total` for counters, `_seconds` / `_bytes` for
//! histograms and gauges), labels for low-cardinality dimensions only
//! (message type, route, fault kind — never point counts or epochs).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Global recording gate.  On by default: recording is cheap (relaxed
/// atomics) and structurally unable to perturb results.  The determinism
/// CI gate runs with this off to prove the "off" arm exists and matches.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Default duration buckets (seconds): ~0.5 ms to 10 s, roughly
/// logarithmic — shared by request latency, frame waits, and checkpoint
/// publishes so exposition stays comparable across subsystems.
pub const DURATION_BUCKETS_S: [f64; 14] = [
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    pub fn name(&self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Monotone event count.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A counter not registered anywhere — for per-instance stats (e.g.
    /// one cache's hit count) that a scrape surface mirrors explicitly.
    pub fn detached() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn value(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as `f64` bits).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn detached() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0)))
    }

    pub fn set(&self, v: f64) {
        if enabled() {
            self.0.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn add(&self, delta: f64) {
        if enabled() {
            fetch_add_f64(&self.0, delta);
        }
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistCore {
    /// Upper bounds (inclusive, `le` semantics), strictly increasing.
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `bounds.len() + 1` entries, the
    /// last being the overflow (`+Inf`) bucket.
    buckets: Vec<AtomicU64>,
    sum_bits: AtomicU64,
    count: AtomicU64,
    max_bits: AtomicU64,
}

/// Fixed-bucket histogram.  Also tracks the exact running max (the
/// `/stats` surface reports `max_ms`, which buckets alone cannot).
#[derive(Clone)]
pub struct Histogram(Arc<HistCore>);

impl Histogram {
    pub fn detached(bounds: &[f64]) -> Histogram {
        let mut b: Vec<f64> = bounds.to_vec();
        b.retain(|x| x.is_finite());
        b.sort_by(|a, x| a.total_cmp(x));
        b.dedup();
        let buckets = (0..=b.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistCore {
            bounds: b,
            buckets,
            sum_bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_bits: AtomicU64::new(0),
        }))
    }

    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let c = &*self.0;
        // first bound >= v counts it (`le` semantics); NaN overflows
        let i = if v.is_nan() {
            c.bounds.len()
        } else {
            c.bounds.partition_point(|b| *b < v)
        };
        c.buckets[i].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        fetch_add_f64(&c.sum_bits, v);
        fetch_max_f64(&c.max_bits, v);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Largest observed value (0.0 before any observation).
    pub fn max(&self) -> f64 {
        f64::from_bits(self.0.max_bits.load(Ordering::Relaxed))
    }

    /// Bucket-interpolated quantile estimate (`histogram_quantile` style):
    /// linear within the winning bucket; the overflow bucket reports the
    /// observed max.  `0.0` before any observation.
    pub fn quantile(&self, q: f64) -> f64 {
        let c = &*self.0;
        let total = c.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, b) in c.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                cum += n;
                continue;
            }
            if (cum + n) as f64 >= rank {
                if i == c.bounds.len() {
                    return self.max();
                }
                let lo = if i == 0 { 0.0 } else { c.bounds[i - 1] };
                let hi = c.bounds[i];
                let frac = ((rank - cum as f64) / n as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            cum += n;
        }
        self.max()
    }

    fn snapshot_value(&self) -> Value {
        let c = &*self.0;
        Value::Histogram {
            bounds: c.bounds.clone(),
            buckets: c.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum(),
            count: self.count(),
            max: self.max(),
        }
    }
}

fn fetch_add_f64(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn fetch_max_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        if v.is_nan() || v <= f64::from_bits(cur) {
            return;
        }
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

#[derive(Clone)]
enum Handle {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Family {
    help: &'static str,
    kind: Kind,
    bounds: Vec<f64>,
    series: HashMap<String, Handle>,
}

/// A metrics registry.  [`global`] is the process-wide default; subsystems
/// with per-instance stats (the serve layer spins up one server per test)
/// can own a private `Registry` and merge its snapshot at scrape time.
pub struct Registry {
    stripes: Vec<Mutex<HashMap<&'static str, Family>>>,
}

const STRIPES: usize = 16;

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { stripes: (0..STRIPES).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    fn stripe(&self, name: &str) -> &Mutex<HashMap<&'static str, Family>> {
        &self.stripes[(fnv1a(name.as_bytes()) as usize) % STRIPES]
    }

    pub fn counter(
        &self,
        name: &'static str,
        help: &'static str,
        labels: &[(&str, &str)],
    ) -> Counter {
        match self.register(name, help, Kind::Counter, &[], labels) {
            Handle::Counter(c) => c,
            // name already registered under a different kind: record into
            // a detached handle rather than corrupt the family or panic
            _ => Counter::detached(),
        }
    }

    pub fn gauge(&self, name: &'static str, help: &'static str, labels: &[(&str, &str)]) -> Gauge {
        match self.register(name, help, Kind::Gauge, &[], labels) {
            Handle::Gauge(g) => g,
            _ => Gauge::detached(),
        }
    }

    pub fn histogram(
        &self,
        name: &'static str,
        help: &'static str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Histogram {
        match self.register(name, help, Kind::Histogram, bounds, labels) {
            Handle::Histogram(h) => h,
            _ => Histogram::detached(bounds),
        }
    }

    fn register(
        &self,
        name: &'static str,
        help: &'static str,
        kind: Kind,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Handle {
        let key = render_labels(labels);
        let mut map = self.stripe(name).lock().unwrap();
        let fam = map.entry(name).or_insert_with(|| Family {
            help,
            kind,
            bounds: bounds.to_vec(),
            series: HashMap::new(),
        });
        if fam.kind != kind {
            return match kind {
                Kind::Counter => Handle::Counter(Counter::detached()),
                Kind::Gauge => Handle::Gauge(Gauge::detached()),
                Kind::Histogram => Handle::Histogram(Histogram::detached(bounds)),
            };
        }
        let family_bounds = fam.bounds.clone();
        fam.series
            .entry(key)
            .or_insert_with(|| match kind {
                Kind::Counter => Handle::Counter(Counter::detached()),
                Kind::Gauge => Handle::Gauge(Gauge::detached()),
                // all series of one family share the family's bounds,
                // whatever the late registrant asked for
                Kind::Histogram => Handle::Histogram(Histogram::detached(&family_bounds)),
            })
            .clone()
    }

    /// Deterministically ordered copy of every registered series.
    pub fn snapshot(&self) -> Snapshot {
        let mut families = BTreeMap::new();
        for stripe in &self.stripes {
            let map = stripe.lock().unwrap();
            for (name, fam) in map.iter() {
                let mut series = BTreeMap::new();
                for (labels, h) in &fam.series {
                    let v = match h {
                        Handle::Counter(c) => Value::Counter(c.value()),
                        Handle::Gauge(g) => Value::Gauge(g.value()),
                        Handle::Histogram(h) => h.snapshot_value(),
                    };
                    series.insert(labels.clone(), v);
                }
                families.insert(
                    name.to_string(),
                    FamilySnap { help: fam.help.to_string(), kind: fam.kind, series },
                );
            }
        }
        Snapshot { families }
    }

    /// Drop every registered family.  Existing handles keep recording into
    /// their (now unreachable) atomics.  Test helper.
    #[doc(hidden)]
    pub fn reset(&self) {
        for stripe in &self.stripes {
            stripe.lock().unwrap().clear();
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Render a label set as Prometheus series-key text, sorted by label name
/// so identical sets always collide: `kind="crash",phase="epoch"`.
pub fn render_labels(labels: &[(&str, &str)]) -> String {
    let mut pairs: Vec<(&str, &str)> = labels.to_vec();
    pairs.sort_by(|a, b| a.0.cmp(b.0));
    let mut out = String::new();
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        for ch in v.chars() {
            match ch {
                '\\' => out.push_str("\\\\"),
                '"' => out.push_str("\\\""),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out
}

/// One exported series value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(f64),
    Histogram { bounds: Vec<f64>, buckets: Vec<u64>, sum: f64, count: u64, max: f64 },
}

#[derive(Clone, Debug)]
pub struct FamilySnap {
    pub help: String,
    pub kind: Kind,
    /// Rendered label set -> value, lexicographically ordered.
    pub series: BTreeMap<String, Value>,
}

/// A point-in-time, deterministically ordered view of a registry.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub families: BTreeMap<String, FamilySnap>,
}

impl Snapshot {
    /// Merge `other` into `self` (other wins on series collisions) — how a
    /// scrape surface combines the global registry with an instance one.
    pub fn merge(mut self, other: Snapshot) -> Snapshot {
        for (name, fam) in other.families {
            match self.families.get_mut(&name) {
                None => {
                    self.families.insert(name, fam);
                }
                Some(mine) => mine.series.extend(fam.series),
            }
        }
        self
    }
}

fn global_registry() -> &'static Registry {
    static G: OnceLock<Registry> = OnceLock::new();
    G.get_or_init(Registry::new)
}

/// The process-wide registry (train, distributed, checkpoint metrics).
pub fn global() -> &'static Registry {
    global_registry()
}

pub fn counter(name: &'static str, help: &'static str, labels: &[(&str, &str)]) -> Counter {
    global().counter(name, help, labels)
}

pub fn gauge(name: &'static str, help: &'static str, labels: &[(&str, &str)]) -> Gauge {
    global().gauge(name, help, labels)
}

pub fn histogram(
    name: &'static str,
    help: &'static str,
    bounds: &[f64],
    labels: &[(&str, &str)],
) -> Histogram {
    global().histogram(name, help, bounds, labels)
}

/// Snapshot of the process-wide registry.
pub fn snapshot() -> Snapshot {
    global().snapshot()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_record() {
        let r = Registry::new();
        let c = r.counter("t_total", "help", &[("k", "a")]);
        c.inc();
        c.add(4);
        assert_eq!(c.value(), 5);
        let g = r.gauge("t_gauge", "help", &[]);
        g.set(2.5);
        g.add(0.5);
        assert_eq!(g.value(), 3.0);
        // same (name, labels) -> same underlying series
        let c2 = r.counter("t_total", "help", &[("k", "a")]);
        c2.inc();
        assert_eq!(c.value(), 6);
    }

    #[test]
    fn histogram_buckets_boundaries_and_overflow() {
        let h = Histogram::detached(&[1.0, 2.0, 4.0]);
        // exactly-on-boundary lands in that bucket (le semantics)
        for v in [0.5, 1.0, 1.5, 2.0, 4.0, 9.0, f64::NAN] {
            h.observe(v);
        }
        let Value::Histogram { buckets, count, max, .. } = h.snapshot_value() else {
            panic!("histogram snapshot")
        };
        assert_eq!(buckets, vec![2, 2, 1, 2]); // le1: .5,1; le2: 1.5,2; le4: 4; inf: 9,NaN
        assert_eq!(count, 7);
        assert_eq!(max, 9.0);
    }

    /// Property sweep over randomized bucket edges: every observation —
    /// including ones placed exactly on an edge — lands in exactly one
    /// bucket, boundary samples count into the bucket whose upper bound
    /// they equal (`le` semantics), and the cumulative `+Inf` total equals
    /// the observation counter.
    #[test]
    fn histogram_edges_property() {
        let mut rng = crate::util::rng::Rng::new(0xed6e5);
        for trial in 0..50 {
            let n_edges = 1 + rng.below(6);
            let mut edges: Vec<f64> = (0..n_edges)
                .map(|_| (rng.below(200) as f64 - 100.0) / 8.0)
                .collect();
            edges.sort_by(|a, b| a.total_cmp(b));
            edges.dedup();
            let h = Histogram::detached(&edges);
            // observe each edge exactly, plus points strictly between and
            // beyond the edges
            let mut values: Vec<f64> = edges.clone();
            for w in edges.windows(2) {
                values.push((w[0] + w[1]) / 2.0);
            }
            values.push(edges[0] - 1.0);
            values.push(edges[edges.len() - 1] + 1.0);
            for &v in &values {
                h.observe(v);
            }
            let Value::Histogram { bounds, buckets, count, .. } = h.snapshot_value() else {
                panic!("histogram snapshot")
            };
            assert_eq!(bounds, edges, "trial {trial}: bounds survive");
            let total: u64 = buckets.iter().sum();
            assert_eq!(total, values.len() as u64, "trial {trial}: one bucket per sample");
            assert_eq!(total, count, "trial {trial}: +Inf cumulative == counter");
            // per-bucket recount from `le` semantics: bucket i holds values
            // in (edge[i-1], edge[i]]; an exact-edge sample is in bucket i
            for (i, &b) in bounds.iter().enumerate() {
                let expect = values
                    .iter()
                    .filter(|&&v| v <= b && (i == 0 || v > bounds[i - 1]))
                    .count() as u64;
                assert_eq!(buckets[i], expect, "trial {trial}: bucket {i} (le {b})");
            }
            let beyond = values.iter().filter(|&&v| v > bounds[bounds.len() - 1]).count();
            assert_eq!(buckets[bounds.len()], beyond as u64, "trial {trial}: overflow");
        }
    }

    #[test]
    fn histogram_quantile_interpolates() {
        let h = Histogram::detached(&[1.0, 2.0]);
        for _ in 0..50 {
            h.observe(0.5);
        }
        for _ in 0..50 {
            h.observe(1.5);
        }
        let p50 = h.quantile(0.5);
        assert!((0.0..=1.0).contains(&p50), "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!((1.0..=2.0).contains(&p99), "p99 {p99}");
        h.observe(10.0); // overflow bucket
        assert_eq!(h.quantile(1.0), 10.0);
        assert_eq!(Histogram::detached(&[1.0]).quantile(0.5), 0.0);
    }

    #[test]
    fn snapshot_order_is_deterministic() {
        let r = Registry::new();
        r.counter("z_total", "z", &[("b", "2")]).inc();
        r.counter("a_total", "a", &[]).inc();
        r.counter("z_total", "z", &[("b", "1")]).inc();
        let names: Vec<String> = r.snapshot().families.keys().cloned().collect();
        assert_eq!(names, vec!["a_total".to_string(), "z_total".to_string()]);
        let z = &r.snapshot().families["z_total"];
        let keys: Vec<String> = z.series.keys().cloned().collect();
        assert_eq!(keys, vec!["b=\"1\"".to_string(), "b=\"2\"".to_string()]);
    }

    #[test]
    fn label_rendering_sorts_and_escapes() {
        assert_eq!(render_labels(&[("b", "x"), ("a", "q\"\\")]), "a=\"q\\\"\\\\\",b=\"x\"");
        assert_eq!(render_labels(&[]), "");
    }

    #[test]
    fn kind_clash_yields_detached_handle() {
        let r = Registry::new();
        let _c = r.counter("clash", "h", &[]);
        let g = r.gauge("clash", "h", &[]);
        g.set(7.0); // must not corrupt the counter family
        let snap = r.snapshot();
        assert!(matches!(snap.families["clash"].series[""], Value::Counter(0)));
    }
}
