//! The NOMAD Projection embedding core.
//!
//! The unit of computation is a [`ClusterBlock`]: one K-Means cluster,
//! padded to a shape bucket, carrying its positive kNN edges (weights from
//! the inverse-rank model), its per-epoch exact-negative samples, the CSR
//! transposes of both edge lists ([`EdgeTranspose`], consumed by the gather
//! force engine — DESIGN.md §9), and a scalar negative weight.  Remote
//! clusters appear only through their all-gathered means (paper Eq 3–5).
//! A device owns a set of blocks; an epoch applies one NOMAD gradient step
//! per block.
//!
//! The step itself runs through a [`StepBackend`]: the native Rust
//! implementation ([`native`]) or the AOT-compiled XLA artifact
//! (`crate::runtime::XlaStepBackend`), which must agree numerically.

pub mod block;
pub mod native;
pub mod sgd;

pub use block::{BlockParts, ClusterBlock, EdgeTranspose};

use crate::util::rng::Rng;

/// Which partition cells are approximated by their means (the R̃ choice).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ApproxMode {
    /// Approximate every cluster except the block's own (the NOMAD default:
    /// matches the per-cluster compute model on any device count).
    AllNonSelf,
    /// No mean approximation at all: exact negative samples only — this is
    /// plain InfoNC-t-SNE and serves as the exactness baseline/ablation.
    None,
}

/// Hyperparameters of the NOMAD optimizer (paper §3.3–3.4).
#[derive(Clone, Debug)]
pub struct NomadParams {
    /// neighbors per point (k of the kNN graph)
    pub k: usize,
    /// exact negative samples per head per step
    pub negs: usize,
    /// |M|: the nominal InfoNC-t-SNE noise-sample count the weights encode
    pub m_noise: f64,
    /// optimization epochs
    pub epochs: usize,
    /// initial learning rate; None -> n/10 (paper §3.4)
    pub lr_initial: Option<f64>,
    /// p(j|i) model (paper Eq 6 by default)
    pub weight_model: crate::ann::graph::WeightModel,
    /// R̃ selection
    pub approx: ApproxMode,
    /// early-exaggeration factor applied to attractive weights for the
    /// first `exaggeration_epochs` (off by default; ablation knob)
    pub exaggeration: f32,
    pub exaggeration_epochs: usize,
    /// PCA init when true, else random gaussian init
    pub pca_init: bool,
    /// initialization scale (std of the first PCA component)
    pub init_std: f32,
    pub seed: u64,
}

impl Default for NomadParams {
    fn default() -> Self {
        NomadParams {
            k: 15,
            negs: 8,
            m_noise: 50.0,
            epochs: 200,
            lr_initial: None,
            weight_model: crate::ann::graph::WeightModel::InverseRankPaper,
            approx: ApproxMode::AllNonSelf,
            exaggeration: 1.0,
            exaggeration_epochs: 0,
            pca_init: true,
            init_std: 1.0,
            seed: 42,
        }
    }
}

/// One cluster-step request: everything the backend needs besides the block.
///
/// The remote-means table is **SoA** (`mean_x`/`mean_y`/`mean_w`, one entry
/// per remote cluster, zero-weight entries already dropped by the device
/// worker) so the native engine's O(R) mean pass runs as an unrolled 4-lane
/// microkernel; the XLA path re-interleaves into its r×2 artifact layout.
pub struct StepInputs<'a> {
    /// all-gathered remote-cluster mean x coordinates
    pub mean_x: &'a [f32],
    /// all-gathered remote-cluster mean y coordinates
    pub mean_y: &'a [f32],
    /// per-mean weights |M| * p(m in r)
    pub mean_w: &'a [f32],
    /// learning rate for this epoch
    pub lr: f32,
    /// worker threads the backend may use *inside* this step (the head loop
    /// of the native gradient); 0 means "decide yourself" (the env/machine
    /// default).  The device worker budgets this against its block-level
    /// parallelism so the two layers don't oversubscribe the cores.
    pub threads: usize,
}

/// A pluggable executor for the per-block NOMAD step.
pub trait StepBackend {
    /// Apply one gradient step in place on `block.pos`; returns the block
    /// mean loss (over valid heads).
    fn step(&self, block: &mut ClusterBlock, inputs: &StepInputs, rng: &mut Rng) -> f64;

    /// Human-readable backend name for logs/benches.
    fn name(&self) -> &'static str;

    /// Thread-safe view of this backend, if it has one.  Backends that
    /// return `Some` are stepped concurrently across a device's blocks
    /// ([`crate::util::parallel::par_map_mut`]); backends that are not
    /// `Sync` — e.g. the XLA backend, which wraps a single PJRT client per
    /// device thread — return `None` and step their blocks serially.
    fn as_sync(&self) -> Option<&dyn SyncStepBackend> {
        None
    }
}

/// Marker for step backends that are safe to share across the intra-device
/// worker threads (`step` takes `&self`, so `Sync` is all that's needed).
pub trait SyncStepBackend: StepBackend + Sync {}
