//! SGD schedule (paper §3.4): initial learning rate n/10 (a factor of 10
//! below the Belkina et al. t-SNE convention), linearly annealed to 0.

/// Linear-decay learning-rate schedule.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    pub initial: f64,
    pub epochs: usize,
}

impl LrSchedule {
    /// The paper's default: lr0 = n/10 unless overridden.
    pub fn nomad_default(n: usize, epochs: usize, lr_initial: Option<f64>) -> LrSchedule {
        LrSchedule {
            initial: lr_initial.unwrap_or(n as f64 / 10.0),
            epochs: epochs.max(1),
        }
    }

    /// Learning rate for `epoch` in [0, epochs): linear anneal to 0
    /// (reaching exactly 0 only past the final epoch).
    pub fn at(&self, epoch: usize) -> f64 {
        let e = epoch.min(self.epochs) as f64;
        self.initial * (1.0 - e / self.epochs as f64)
    }
}

/// Early-exaggeration window: multiplies attractive edge weights during the
/// first `epochs` epochs (ablation knob; off when factor == 1).
#[derive(Clone, Copy, Debug)]
pub struct Exaggeration {
    pub factor: f32,
    pub epochs: usize,
}

impl Exaggeration {
    pub fn factor_at(&self, epoch: usize) -> f32 {
        if epoch < self.epochs {
            self.factor
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_n_over_10() {
        let s = LrSchedule::nomad_default(5000, 100, None);
        assert_eq!(s.initial, 500.0);
        let s2 = LrSchedule::nomad_default(5000, 100, Some(3.0));
        assert_eq!(s2.initial, 3.0);
    }

    #[test]
    fn linear_anneal() {
        let s = LrSchedule { initial: 100.0, epochs: 10 };
        assert_eq!(s.at(0), 100.0);
        assert_eq!(s.at(5), 50.0);
        assert_eq!(s.at(10), 0.0);
        assert_eq!(s.at(99), 0.0);
    }

    #[test]
    fn exaggeration_window() {
        let e = Exaggeration { factor: 4.0, epochs: 3 };
        assert_eq!(e.factor_at(0), 4.0);
        assert_eq!(e.factor_at(2), 4.0);
        assert_eq!(e.factor_at(3), 1.0);
    }
}
