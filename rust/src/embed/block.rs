//! [`ClusterBlock`]: one padded K-Means cluster, the shard unit of NOMAD.
//!
//! Besides the forward edge lists, a block carries the **CSR transposes**
//! the gather force engine consumes (DESIGN.md §9): [`ClusterBlock::nbr_in`]
//! (incoming positive edges, built once) and [`ClusterBlock::neg_in`]
//! (incoming exact negatives, rebuilt by a counting sort every
//! [`ClusterBlock::resample_negatives`]).

use crate::ann::{graph::EdgeWeights, ClusterIndex, NO_NEIGHBOR};
use crate::util::rng::Rng;

/// CSR transpose of a `rows x fanout` local edge list: for each target row
/// `t`, the flat edge ids `e = head * fanout + slot` with `idx[e] == t`,
/// grouped contiguously and stored in ascending `e` order.  The fixed edge
/// order is what makes the gather engine's per-row float summation — and
/// therefore the whole step — bitwise independent of the worker count.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EdgeTranspose {
    /// row offsets into `edge`, length rows + 1
    pub ptr: Vec<u32>,
    /// flat edge ids (`head * fanout + slot`) grouped by target row
    pub edge: Vec<u32>,
}

impl EdgeTranspose {
    /// Counting-sort build, O(rows·fanout).  Edges with `keep(e) == false`
    /// (e.g. zero-weight kNN slots, whose force coefficients are exactly 0)
    /// are omitted so the gather pass never touches them.  `keep` is called
    /// twice per edge (counting pass, then fill pass) and must answer
    /// consistently — hence `Fn`, not `FnMut`.
    pub fn build(
        idx: &[i32],
        rows: usize,
        fanout: usize,
        keep: impl Fn(usize) -> bool,
    ) -> EdgeTranspose {
        let n_edges = rows * fanout;
        debug_assert_eq!(idx.len(), n_edges);
        let mut ptr = vec![0u32; rows + 1];
        for (e, &t) in idx.iter().enumerate() {
            if keep(e) {
                ptr[t as usize + 1] += 1;
            }
        }
        for t in 0..rows {
            ptr[t + 1] += ptr[t];
        }
        let mut edge = vec![0u32; ptr[rows] as usize];
        let mut cursor: Vec<u32> = ptr[..rows].to_vec();
        for (e, &t) in idx.iter().enumerate() {
            if keep(e) {
                let t = t as usize;
                edge[cursor[t] as usize] = e as u32;
                cursor[t] += 1;
            }
        }
        EdgeTranspose { ptr, edge }
    }

    /// Flat edge ids whose target is row `t`, ascending.
    #[inline]
    pub fn incoming(&self, t: usize) -> &[u32] {
        &self.edge[self.ptr[t] as usize..self.ptr[t + 1] as usize]
    }
}

/// Shape buckets for block padding.  These must match the AOT artifact
/// buckets (`python/compile/aot.py STEP_BUCKETS`); the runtime picks the
/// smallest bucket that fits, and the native backend accepts any size.
pub const STEP_BUCKETS: [usize; 5] = [512, 1024, 2048, 4096, 8192];

/// Round a cluster size up to its padding bucket.
pub fn bucket_for(n: usize) -> usize {
    for b in STEP_BUCKETS {
        if n <= b {
            return b;
        }
    }
    // beyond the largest bucket: pad to the next multiple (native path only)
    let top = STEP_BUCKETS[STEP_BUCKETS.len() - 1];
    n.div_ceil(top) * top
}

/// The unpadded training topology of one cluster: exactly what a
/// [`ClusterBlock`] is deterministically derived from (besides positions).
///
/// This is the **shard unit on disk** (`data/shard.rs`): a worker process
/// that loads a cluster's `BlockParts` from an mmap'd shard file and calls
/// [`ClusterBlock::from_parts`] builds a block identical to what the
/// coordinator's in-process path builds from the full index — the bitwise
/// equality of multi-process runs rests on this type being the complete
/// interface between the two paths.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockParts {
    /// global cluster id in the index
    pub cluster_id: u32,
    /// global point ids of the real rows
    pub global_ids: Vec<u32>,
    /// kNN fanout
    pub k: usize,
    /// local neighbor indices, n_real x k (self-loop for missing slots)
    pub nbr_idx: Vec<i32>,
    /// p(j|i) weights, n_real x k (0 for missing slots)
    pub nbr_w: Vec<f32>,
}

impl BlockParts {
    /// Extract cluster `c`'s topology from the built index + edge weights
    /// (the shard writer's path; also the first half of
    /// [`ClusterBlock::build`]).
    pub fn extract(index: &ClusterIndex, weights: &EdgeWeights, c: usize) -> BlockParts {
        let members = &index.clusters[c];
        let n_real = members.len();
        let k = index.k;

        // local index of each global member (BTreeMap: lookup-only here, and
        // determinism-critical modules ban hash collections outright)
        let mut local_of = std::collections::BTreeMap::new();
        for (l, &g) in members.iter().enumerate() {
            local_of.insert(g, l as i32);
        }

        let mut nbr_idx = vec![0i32; n_real * k];
        let mut nbr_w = vec![0.0f32; n_real * k];
        for (l, &g) in members.iter().enumerate() {
            let g = g as usize;
            for s in 0..k {
                let j = index.nbr_idx[g * k + s];
                if j == NO_NEIGHBOR {
                    nbr_idx[l * k + s] = l as i32; // self loop, weight 0
                } else {
                    let lj = *local_of
                        .get(&j)
                        .expect("kNN edge crossed cluster boundary — index invariant violated");
                    nbr_idx[l * k + s] = lj;
                    nbr_w[l * k + s] = weights.w[g * k + s];
                }
            }
        }
        BlockParts { cluster_id: c as u32, global_ids: members.clone(), k, nbr_idx, nbr_w }
    }

    /// Real row count.
    pub fn n_real(&self) -> usize {
        self.global_ids.len()
    }
}

/// One cluster of points, padded to a bucket, with local-index edges.
#[derive(Clone, Debug, PartialEq)]
pub struct ClusterBlock {
    /// global cluster id in the index
    pub cluster_id: u32,
    /// global point ids of the real rows (len = n_real)
    pub global_ids: Vec<u32>,
    /// padded row count (bucket)
    pub size: usize,
    /// real row count
    pub n_real: usize,
    /// positions, size x 2 (padded rows stay at 0 and never move)
    pub pos: Vec<f32>,
    /// local neighbor indices, size x k (self-loop for padding/missing)
    pub nbr_idx: Vec<i32>,
    /// p(j|i) weights, size x k (0 for padding/missing)
    pub nbr_w: Vec<f32>,
    /// Lazily cached early-exaggeration copy of `nbr_w`, tagged with the
    /// multiplier it was built from so an annealed/changed factor rebuilds
    /// it instead of silently reusing stale weights (device worker use).
    /// While a step is in flight the device swaps the scaled copy into
    /// `nbr_w` and parks the originals here under the same tag.
    pub nbr_w_exag: Option<(f32, Vec<f32>)>,
    /// CSR transpose of the positive edges (incoming neighbors per row,
    /// zero-weight slots omitted).  Built once — the edge topology and its
    /// zero-weight set are fixed for the life of the block (exaggeration
    /// only scales nonzero weights) — and consumed by the gather engine's
    /// attraction-reaction pass.
    pub nbr_in: EdgeTranspose,
    /// per-epoch exact-negative local indices, size x negs
    pub neg_idx: Vec<i32>,
    /// counting-sort transpose of the current `neg_idx` draw (incoming
    /// negatives per row); rebuilt by `resample_negatives` each epoch
    pub neg_in: EdgeTranspose,
    /// scalar weight |M| * p(m in this cluster) / negs
    pub neg_w: f32,
    /// 1.0 for real rows
    pub valid: Vec<f32>,
    pub k: usize,
    pub negs: usize,
}

impl ClusterBlock {
    /// Build the block for cluster `c` of the index.
    ///
    /// `n_total` is the full dataset size (for p(m in r) = |r|/n), `m_noise`
    /// the nominal |M|.  Initial positions are gathered from `init` (n x 2
    /// row-major).
    pub fn build(
        index: &ClusterIndex,
        weights: &EdgeWeights,
        c: usize,
        init: &[f32],
        n_total: usize,
        m_noise: f64,
        negs: usize,
    ) -> ClusterBlock {
        let parts = BlockParts::extract(index, weights, c);
        ClusterBlock::from_parts(parts, Some(init), n_total, m_noise, negs)
    }

    /// Build the block from its serializable topology ([`BlockParts`] —
    /// extracted live or loaded from a shard file).  With `init = None`
    /// the positions start at 0 and await a `DeviceCmd::Ingest` (the
    /// worker-process path: positions always arrive over the wire, so the
    /// worker never needs the init matrix or the corpus).
    pub fn from_parts(
        parts: BlockParts,
        init: Option<&[f32]>,
        n_total: usize,
        m_noise: f64,
        negs: usize,
    ) -> ClusterBlock {
        let BlockParts { cluster_id, global_ids, k, nbr_idx: parts_idx, nbr_w: parts_w } = parts;
        let n_real = global_ids.len();
        let size = bucket_for(n_real.max(1));

        let mut pos = vec![0.0f32; size * 2];
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut valid = vec![0.0f32; size];

        nbr_idx[..n_real * k].copy_from_slice(&parts_idx);
        nbr_w[..n_real * k].copy_from_slice(&parts_w);
        for (l, &g) in global_ids.iter().enumerate() {
            valid[l] = 1.0;
            if let Some(init) = init {
                let g = g as usize;
                pos[l * 2] = init[g * 2];
                pos[l * 2 + 1] = init[g * 2 + 1];
            }
        }
        // padded rows: self loops
        for l in n_real..size {
            for s in 0..k {
                nbr_idx[l * k + s] = l as i32;
            }
        }

        let p_cell = n_real as f64 / n_total.max(1) as f64;
        let neg_w = ((m_noise * p_cell) / negs.max(1) as f64) as f32;

        // incoming-edge CSR for the gather engine; zero-weight slots carry
        // zero force so they are dropped from the reaction lists up front
        let nbr_in = EdgeTranspose::build(&nbr_idx, size, k, |e| nbr_w[e] != 0.0);
        let neg_idx = vec![0i32; size * negs];
        let neg_in = EdgeTranspose::build(&neg_idx, size, negs, |_| true);

        ClusterBlock {
            cluster_id,
            global_ids,
            size,
            n_real,
            pos,
            nbr_idx,
            nbr_w,
            nbr_w_exag: None,
            nbr_in,
            neg_idx,
            neg_w,
            neg_in,
            valid,
            k,
            negs,
        }
    }

    /// Resample the exact negatives uniformly from this cluster's **other**
    /// real rows (padding heads self-loop so they contribute nothing), then
    /// rebuild the counting-sort transpose the gather engine reads.
    pub fn resample_negatives(&mut self, rng: &mut Rng) {
        let negs = self.negs;
        if self.n_real <= 1 {
            for l in 0..self.size {
                for s in 0..negs {
                    self.neg_idx[l * negs + s] = l as i32;
                }
            }
        } else {
            for l in 0..self.size {
                for s in 0..negs {
                    self.neg_idx[l * negs + s] = if l < self.n_real {
                        // draw from the n_real-1 non-self rows and shift past
                        // l — the old `(v + 1) % n_real` self-collision fixup
                        // gave row l+1 double probability
                        let mut v = rng.below(self.n_real - 1);
                        if v >= l {
                            v += 1;
                        }
                        v as i32
                    } else {
                        l as i32
                    };
                }
            }
        }
        self.neg_in = EdgeTranspose::build(&self.neg_idx, self.size, negs, |_| true);
    }

    /// Mean of the real rows' positions (the cluster's embedding mean,
    /// published in the all-gather).
    pub fn mean(&self) -> [f32; 2] {
        let mut m = [0.0f64; 2];
        for l in 0..self.n_real {
            m[0] += self.pos[l * 2] as f64;
            m[1] += self.pos[l * 2 + 1] as f64;
        }
        let inv = 1.0 / self.n_real.max(1) as f64;
        [(m[0] * inv) as f32, (m[1] * inv) as f32]
    }

    /// Scatter this block's positions back to the global position matrix.
    pub fn write_back(&self, global_pos: &mut [f32]) {
        for (l, &g) in self.global_ids.iter().enumerate() {
            let g = g as usize;
            global_pos[g * 2] = self.pos[l * 2];
            global_pos[g * 2 + 1] = self.pos[l * 2 + 1];
        }
    }

    /// Weight |M| * p(m in this cluster) for when OTHER blocks treat this
    /// cluster as a mean-negative.
    pub fn mean_weight(&self, n_total: usize, m_noise: f64) -> f32 {
        (m_noise * self.n_real as f64 / n_total.max(1) as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::ann::graph::{edge_weights, WeightModel};
    use crate::ann::IndexParams;
    use crate::data::gaussian_mixture;

    fn setup(n: usize) -> (ClusterIndex, EdgeWeights, Vec<f32>) {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(n, 8, 4, 8.0, 0.2, 0.5, &mut rng);
        let idx = ClusterIndex::build(
            &ds.x,
            &IndexParams { n_clusters: 4, k: 5, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        );
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        let init: Vec<f32> = (0..n * 2).map(|i| (i % 17) as f32 * 0.1).collect();
        (idx, ew, init)
    }

    #[test]
    fn block_roundtrips_positions() {
        let (idx, ew, init) = setup(300);
        let mut global = init.clone();
        for c in 0..idx.n_clusters() {
            let b = ClusterBlock::build(&idx, &ew, c, &init, 300, 5.0, 4);
            assert_eq!(b.size % 512, 0);
            assert!(b.n_real <= b.size);
            b.write_back(&mut global);
        }
        assert_eq!(global, init);
    }

    #[test]
    fn from_parts_reproduces_build_exactly() {
        // the shard path (extract -> serialize -> from_parts) must yield a
        // block identical to the in-process build; positions arrive via an
        // ingest, modeled here by copying them in after construction
        let (idx, ew, init) = setup(300);
        for c in 0..idx.n_clusters() {
            let built = ClusterBlock::build(&idx, &ew, c, &init, 300, 5.0, 4);
            let parts = BlockParts::extract(&idx, &ew, c);
            assert_eq!(parts.n_real(), built.n_real);
            let mut from_parts = ClusterBlock::from_parts(parts, None, 300, 5.0, 4);
            assert!(from_parts.pos.iter().all(|&v| v == 0.0));
            for (l, &g) in from_parts.global_ids.clone().iter().enumerate() {
                let g = g as usize;
                from_parts.pos[l * 2] = init[g * 2];
                from_parts.pos[l * 2 + 1] = init[g * 2 + 1];
            }
            assert_eq!(from_parts, built);
        }
    }

    #[test]
    fn local_edges_match_global_edges() {
        let (idx, ew, init) = setup(300);
        let b = ClusterBlock::build(&idx, &ew, 0, &init, 300, 5.0, 4);
        for (l, &g) in b.global_ids.iter().enumerate() {
            let g = g as usize;
            for s in 0..b.k {
                let lj = b.nbr_idx[l * b.k + s];
                let w = b.nbr_w[l * b.k + s];
                if w > 0.0 {
                    let gj = b.global_ids[lj as usize];
                    assert_eq!(gj, idx.nbr_idx[g * b.k + s]);
                    assert_eq!(w, ew.w[g * b.k + s]);
                }
            }
        }
    }

    #[test]
    fn negatives_avoid_self_and_padding() {
        let (idx, ew, init) = setup(300);
        let mut b = ClusterBlock::build(&idx, &ew, 1, &init, 300, 5.0, 6);
        let mut rng = Rng::new(7);
        b.resample_negatives(&mut rng);
        for l in 0..b.n_real {
            for s in 0..6 {
                let v = b.neg_idx[l * 6 + s];
                assert!((v as usize) < b.n_real);
                assert_ne!(v as usize, l);
            }
        }
        for l in b.n_real..b.size {
            for s in 0..6 {
                assert_eq!(b.neg_idx[l * 6 + s] as usize, l);
            }
        }
    }

    #[test]
    fn mean_is_average_of_real_rows() {
        let (idx, ew, init) = setup(300);
        let b = ClusterBlock::build(&idx, &ew, 2, &init, 300, 5.0, 4);
        let m = b.mean();
        let mut want = [0.0f64; 2];
        for &g in &b.global_ids {
            want[0] += init[g as usize * 2] as f64;
            want[1] += init[g as usize * 2 + 1] as f64;
        }
        want[0] /= b.n_real as f64;
        want[1] /= b.n_real as f64;
        assert!((m[0] as f64 - want[0]).abs() < 1e-5);
        assert!((m[1] as f64 - want[1]).abs() < 1e-5);
    }

    /// Minimal block with hand-set edges, for sampling/transpose tests.
    fn bare_block(n_real: usize, size: usize, negs: usize) -> ClusterBlock {
        let nbr_idx = vec![0i32; size];
        let nbr_w = vec![0.0f32; size];
        let neg_idx = vec![0i32; size * negs];
        let nbr_in = EdgeTranspose::build(&nbr_idx, size, 1, |e| nbr_w[e] != 0.0);
        let neg_in = EdgeTranspose::build(&neg_idx, size, negs, |_| true);
        ClusterBlock {
            cluster_id: 0,
            global_ids: (0..n_real as u32).collect(),
            size,
            n_real,
            pos: vec![0.0; size * 2],
            nbr_idx,
            nbr_w,
            nbr_w_exag: None,
            nbr_in,
            neg_idx,
            neg_w: 1.0,
            neg_in,
            valid: (0..size).map(|l| if l < n_real { 1.0 } else { 0.0 }).collect(),
            k: 1,
            negs,
        }
    }

    #[test]
    fn resampled_negatives_are_uniform_over_non_self_rows() {
        let (n_real, negs) = (7usize, 4usize);
        let mut b = bare_block(n_real, 8, negs);
        let mut rng = Rng::new(123);
        let rounds = 4000;
        let mut counts = vec![0u64; n_real * n_real];
        for _ in 0..rounds {
            b.resample_negatives(&mut rng);
            for l in 0..n_real {
                for s in 0..negs {
                    counts[l * n_real + b.neg_idx[l * negs + s] as usize] += 1;
                }
            }
        }
        // per head: no self hits, and every other row within 10% of the
        // uniform expectation (the old `(v+1) % n` fixup put 2x mass on
        // row l+1, a 100% excess — far outside this band)
        let expect = (rounds * negs) as f64 / (n_real - 1) as f64;
        for l in 0..n_real {
            assert_eq!(counts[l * n_real + l], 0, "head {l} drew itself");
            for v in 0..n_real {
                if v == l {
                    continue;
                }
                let c = counts[l * n_real + v] as f64;
                assert!(
                    (c - expect).abs() < 0.10 * expect,
                    "head {l} row {v}: {c} draws vs expected {expect}"
                );
            }
        }
    }

    #[test]
    fn edge_transpose_inverts_the_edge_list() {
        let mut rng = Rng::new(5);
        let (rows, fanout) = (37usize, 5usize);
        let idx: Vec<i32> = (0..rows * fanout).map(|_| rng.below(rows) as i32).collect();
        let w: Vec<f32> =
            (0..rows * fanout).map(|_| if rng.f32() < 0.3 { 0.0 } else { rng.f32() }).collect();
        let t = EdgeTranspose::build(&idx, rows, fanout, |e| w[e] != 0.0);
        assert_eq!(t.ptr.len(), rows + 1);
        // every kept edge appears exactly once, under its target, ascending
        let kept: usize = w.iter().filter(|x| **x != 0.0).count();
        assert_eq!(t.edge.len(), kept);
        let mut seen = std::collections::HashSet::new();
        for target in 0..rows {
            let inc = t.incoming(target);
            for win in inc.windows(2) {
                assert!(win[0] < win[1], "edge ids not ascending");
            }
            for &e in inc {
                assert_eq!(idx[e as usize] as usize, target);
                assert!(w[e as usize] != 0.0);
                assert!(seen.insert(e));
            }
        }
        assert_eq!(seen.len(), kept);
    }

    #[test]
    fn resample_rebuilds_negative_transpose() {
        let (idx, ew, init) = setup(300);
        let mut b = ClusterBlock::build(&idx, &ew, 1, &init, 300, 5.0, 6);
        let mut rng = Rng::new(11);
        b.resample_negatives(&mut rng);
        let expect = EdgeTranspose::build(&b.neg_idx, b.size, b.negs, |_| true);
        assert_eq!(b.neg_in, expect);
        assert_eq!(b.neg_in.edge.len(), b.size * b.negs);
        // block-built kNN transpose matches a from-scratch rebuild too
        let nbr_expect = EdgeTranspose::build(&b.nbr_idx, b.size, b.k, |e| b.nbr_w[e] != 0.0);
        assert_eq!(b.nbr_in, nbr_expect);
    }

    #[test]
    fn bucket_rounding() {
        assert_eq!(bucket_for(1), 512);
        assert_eq!(bucket_for(512), 512);
        assert_eq!(bucket_for(513), 1024);
        assert_eq!(bucket_for(1025), 2048);
        assert_eq!(bucket_for(8192), 8192);
        assert_eq!(bucket_for(9000), 16384);
    }
}
