//! [`ClusterBlock`]: one padded K-Means cluster, the shard unit of NOMAD.

use crate::ann::{graph::EdgeWeights, ClusterIndex, NO_NEIGHBOR};
use crate::util::rng::Rng;

/// Shape buckets for block padding.  These must match the AOT artifact
/// buckets (`python/compile/aot.py STEP_BUCKETS`); the runtime picks the
/// smallest bucket that fits, and the native backend accepts any size.
pub const STEP_BUCKETS: [usize; 5] = [512, 1024, 2048, 4096, 8192];

/// Round a cluster size up to its padding bucket.
pub fn bucket_for(n: usize) -> usize {
    for b in STEP_BUCKETS {
        if n <= b {
            return b;
        }
    }
    // beyond the largest bucket: pad to the next multiple (native path only)
    let top = STEP_BUCKETS[STEP_BUCKETS.len() - 1];
    n.div_ceil(top) * top
}

/// One cluster of points, padded to a bucket, with local-index edges.
#[derive(Clone, Debug)]
pub struct ClusterBlock {
    /// global cluster id in the index
    pub cluster_id: u32,
    /// global point ids of the real rows (len = n_real)
    pub global_ids: Vec<u32>,
    /// padded row count (bucket)
    pub size: usize,
    /// real row count
    pub n_real: usize,
    /// positions, size x 2 (padded rows stay at 0 and never move)
    pub pos: Vec<f32>,
    /// local neighbor indices, size x k (self-loop for padding/missing)
    pub nbr_idx: Vec<i32>,
    /// p(j|i) weights, size x k (0 for padding/missing)
    pub nbr_w: Vec<f32>,
    /// Lazily cached early-exaggeration copy of `nbr_w`, tagged with the
    /// multiplier it was built from so an annealed/changed factor rebuilds
    /// it instead of silently reusing stale weights (device worker use).
    /// While a step is in flight the device swaps the scaled copy into
    /// `nbr_w` and parks the originals here under the same tag.
    pub nbr_w_exag: Option<(f32, Vec<f32>)>,
    /// per-epoch exact-negative local indices, size x negs
    pub neg_idx: Vec<i32>,
    /// scalar weight |M| * p(m in this cluster) / negs
    pub neg_w: f32,
    /// 1.0 for real rows
    pub valid: Vec<f32>,
    pub k: usize,
    pub negs: usize,
}

impl ClusterBlock {
    /// Build the block for cluster `c` of the index.
    ///
    /// `n_total` is the full dataset size (for p(m in r) = |r|/n), `m_noise`
    /// the nominal |M|.  Initial positions are gathered from `init` (n x 2
    /// row-major).
    pub fn build(
        index: &ClusterIndex,
        weights: &EdgeWeights,
        c: usize,
        init: &[f32],
        n_total: usize,
        m_noise: f64,
        negs: usize,
    ) -> ClusterBlock {
        let members = &index.clusters[c];
        let n_real = members.len();
        let size = bucket_for(n_real.max(1));
        let k = index.k;

        // local index of each global member
        let mut local_of = std::collections::HashMap::with_capacity(n_real * 2);
        for (l, &g) in members.iter().enumerate() {
            local_of.insert(g, l as i32);
        }

        let mut pos = vec![0.0f32; size * 2];
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut valid = vec![0.0f32; size];

        for (l, &g) in members.iter().enumerate() {
            let g = g as usize;
            pos[l * 2] = init[g * 2];
            pos[l * 2 + 1] = init[g * 2 + 1];
            valid[l] = 1.0;
            for s in 0..k {
                let j = index.nbr_idx[g * k + s];
                if j == NO_NEIGHBOR {
                    nbr_idx[l * k + s] = l as i32; // self loop, weight 0
                } else {
                    let lj = *local_of
                        .get(&j)
                        .expect("kNN edge crossed cluster boundary — index invariant violated");
                    nbr_idx[l * k + s] = lj;
                    nbr_w[l * k + s] = weights.w[g * k + s];
                }
            }
        }
        // padded rows: self loops
        for l in n_real..size {
            for s in 0..k {
                nbr_idx[l * k + s] = l as i32;
            }
        }

        let p_cell = n_real as f64 / n_total.max(1) as f64;
        let neg_w = ((m_noise * p_cell) / negs.max(1) as f64) as f32;

        ClusterBlock {
            cluster_id: c as u32,
            global_ids: members.clone(),
            size,
            n_real,
            pos,
            nbr_idx: nbr_idx.clone(),
            nbr_w,
            nbr_w_exag: None,
            neg_idx: vec![0i32; size * negs],
            neg_w,
            valid,
            k,
            negs,
        }
    }

    /// Resample the exact negatives uniformly from this cluster's real rows
    /// (padding heads self-loop so they contribute nothing).
    pub fn resample_negatives(&mut self, rng: &mut Rng) {
        let negs = self.negs;
        if self.n_real <= 1 {
            for l in 0..self.size {
                for s in 0..negs {
                    self.neg_idx[l * negs + s] = l as i32;
                }
            }
            return;
        }
        for l in 0..self.size {
            for s in 0..negs {
                self.neg_idx[l * negs + s] = if l < self.n_real {
                    let mut v = rng.below(self.n_real);
                    if v == l {
                        v = (v + 1) % self.n_real; // avoid self-negatives
                    }
                    v as i32
                } else {
                    l as i32
                };
            }
        }
    }

    /// Mean of the real rows' positions (the cluster's embedding mean,
    /// published in the all-gather).
    pub fn mean(&self) -> [f32; 2] {
        let mut m = [0.0f64; 2];
        for l in 0..self.n_real {
            m[0] += self.pos[l * 2] as f64;
            m[1] += self.pos[l * 2 + 1] as f64;
        }
        let inv = 1.0 / self.n_real.max(1) as f64;
        [(m[0] * inv) as f32, (m[1] * inv) as f32]
    }

    /// Scatter this block's positions back to the global position matrix.
    pub fn write_back(&self, global_pos: &mut [f32]) {
        for (l, &g) in self.global_ids.iter().enumerate() {
            let g = g as usize;
            global_pos[g * 2] = self.pos[l * 2];
            global_pos[g * 2 + 1] = self.pos[l * 2 + 1];
        }
    }

    /// Weight |M| * p(m in this cluster) for when OTHER blocks treat this
    /// cluster as a mean-negative.
    pub fn mean_weight(&self, n_total: usize, m_noise: f64) -> f32 {
        (m_noise * self.n_real as f64 / n_total.max(1) as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ann::backend::NativeBackend;
    use crate::ann::graph::{edge_weights, WeightModel};
    use crate::ann::IndexParams;
    use crate::data::gaussian_mixture;

    fn setup(n: usize) -> (ClusterIndex, EdgeWeights, Vec<f32>) {
        let mut rng = Rng::new(0);
        let ds = gaussian_mixture(n, 8, 4, 8.0, 0.2, 0.5, &mut rng);
        let idx = ClusterIndex::build(
            &ds.x,
            &IndexParams { n_clusters: 4, k: 5, ..Default::default() },
            &NativeBackend::default(),
            &mut rng,
        );
        let ew = edge_weights(&idx, WeightModel::InverseRankForward);
        let init: Vec<f32> = (0..n * 2).map(|i| (i % 17) as f32 * 0.1).collect();
        (idx, ew, init)
    }

    #[test]
    fn block_roundtrips_positions() {
        let (idx, ew, init) = setup(300);
        let mut global = init.clone();
        for c in 0..idx.n_clusters() {
            let b = ClusterBlock::build(&idx, &ew, c, &init, 300, 5.0, 4);
            assert_eq!(b.size % 512, 0);
            assert!(b.n_real <= b.size);
            b.write_back(&mut global);
        }
        assert_eq!(global, init);
    }

    #[test]
    fn local_edges_match_global_edges() {
        let (idx, ew, init) = setup(300);
        let b = ClusterBlock::build(&idx, &ew, 0, &init, 300, 5.0, 4);
        for (l, &g) in b.global_ids.iter().enumerate() {
            let g = g as usize;
            for s in 0..b.k {
                let lj = b.nbr_idx[l * b.k + s];
                let w = b.nbr_w[l * b.k + s];
                if w > 0.0 {
                    let gj = b.global_ids[lj as usize];
                    assert_eq!(gj, idx.nbr_idx[g * b.k + s]);
                    assert_eq!(w, ew.w[g * b.k + s]);
                }
            }
        }
    }

    #[test]
    fn negatives_avoid_self_and_padding() {
        let (idx, ew, init) = setup(300);
        let mut b = ClusterBlock::build(&idx, &ew, 1, &init, 300, 5.0, 6);
        let mut rng = Rng::new(7);
        b.resample_negatives(&mut rng);
        for l in 0..b.n_real {
            for s in 0..6 {
                let v = b.neg_idx[l * 6 + s];
                assert!((v as usize) < b.n_real);
                assert_ne!(v as usize, l);
            }
        }
        for l in b.n_real..b.size {
            for s in 0..6 {
                assert_eq!(b.neg_idx[l * 6 + s] as usize, l);
            }
        }
    }

    #[test]
    fn mean_is_average_of_real_rows() {
        let (idx, ew, init) = setup(300);
        let b = ClusterBlock::build(&idx, &ew, 2, &init, 300, 5.0, 4);
        let m = b.mean();
        let mut want = [0.0f64; 2];
        for &g in &b.global_ids {
            want[0] += init[g as usize * 2] as f64;
            want[1] += init[g as usize * 2 + 1] as f64;
        }
        want[0] /= b.n_real as f64;
        want[1] /= b.n_real as f64;
        assert!((m[0] as f64 - want[0]).abs() < 1e-5);
        assert!((m[1] as f64 - want[1]).abs() < 1e-5);
    }

    #[test]
    fn bucket_rounding() {
        assert_eq!(bucket_for(1), 512);
        assert_eq!(bucket_for(512), 512);
        assert_eq!(bucket_for(513), 1024);
        assert_eq!(bucket_for(1025), 2048);
        assert_eq!(bucket_for(8192), 8192);
        assert_eq!(bucket_for(9000), 16384);
    }
}
