//! Native (pure-Rust) implementation of the NOMAD per-block step.
//!
//! This mirrors the Pallas kernel / JAX graph **exactly** (see DESIGN.md §7
//! for the shared math): same analytic gradient decomposition, same
//! mean-over-valid-heads normalization, same masked SGD update.  It is the
//! fallback when no AOT artifact matches a block's bucket, the oracle that
//! the XLA path is cross-checked against, and the CPU performance baseline.
//!
//! # Parallel execution
//!
//! The head loop scatters into `grad[j]`/`grad[nloc]` (both endpoints of an
//! edge move), so naive head parallelism races.  [`nomad_grad_threaded`]
//! therefore splits the heads into **fixed-size chunks** ([`HEAD_CHUNK`]),
//! gives every chunk a private gradient accumulator, and reduces the
//! accumulators **in chunk order** — which makes the result bitwise
//! independent of the worker-thread count (only the chunk partition, fixed
//! by the block size, determines the float summation order).
//! [`nomad_grad_serial`] keeps the original single-pass loop as the oracle;
//! the two agree to f32 reassociation error (cross-checked in tests).

use super::{ClusterBlock, StepBackend, StepInputs, SyncStepBackend};
use crate::util::parallel::{num_threads, par_map, par_rows_mut};
use crate::util::rng::Rng;

/// Heads per parallel chunk.  Fixed (not derived from the thread count) so
/// that the chunk-ordered reduction yields identical results on any number
/// of workers; small enough that even a 512-bucket block exposes 4-way
/// parallelism.
pub const HEAD_CHUNK: usize = 128;

/// Coordinate rows per task in the parallel gradient reduction.
const REDUCE_ROWS: usize = 512;

/// Pure-Rust step executor.
#[derive(Default)]
pub struct NativeStepBackend {}

impl StepBackend for NativeStepBackend {
    fn step(&self, block: &mut ClusterBlock, inputs: &StepInputs, rng: &mut Rng) -> f64 {
        block.resample_negatives(rng);
        let threads = if inputs.threads == 0 { num_threads() } else { inputs.threads };
        let (grad, loss) = nomad_grad_threaded(
            &block.pos,
            &block.nbr_idx,
            &block.nbr_w,
            &block.neg_idx,
            block.neg_w,
            inputs.means,
            inputs.mean_w,
            &block.valid,
            block.k,
            block.negs,
            threads,
        );
        let lr = inputs.lr;
        for l in 0..block.n_real {
            block.pos[l * 2] -= lr * grad[l * 2];
            block.pos[l * 2 + 1] -= lr * grad[l * 2 + 1];
        }
        loss
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn as_sync(&self) -> Option<&dyn SyncStepBackend> {
        Some(self)
    }
}

impl SyncStepBackend for NativeStepBackend {}

/// Cauchy kernel q = 1/(1+d²) on 2-d points.
#[inline(always)]
fn q2(ax: f32, ay: f32, bx: f32, by: f32) -> (f32, f32, f32) {
    let dx = ax - bx;
    let dy = ay - by;
    (1.0 / (1.0 + dx * dx + dy * dy), dx, dy)
}

/// Accumulate the unnormalized gradient and loss contributions of heads
/// `lo..hi` into `grad` (full block size).  Shared verbatim by the serial
/// oracle and every parallel chunk, so the two paths cannot drift.
/// Returns `(loss_sum, nvalid)` for the processed range.
fn accumulate_heads(
    lo: usize,
    hi: usize,
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    grad: &mut [f32],
) -> (f64, f64) {
    let r = mean_w.len();
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    // scratch buffers hoisted out of the head loop (§Perf iteration 1:
    // per-head Vec allocation dominated the R-heavy profiles); deltas are
    // cached alongside q so the repulsion pass is pure FMA (§Perf iter 3)
    let mut q_ir = vec![0.0f32; r];
    let mut dm = vec![0.0f32; r * 2];
    let mut q_in = vec![0.0f32; negs];

    for i in lo..hi {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);

        // ---- negative mass A_i (means + exact negatives) ----------------
        let mut a = 0.0f32;
        for rr in 0..r {
            let w = mean_w[rr];
            let dx = pix - means[rr * 2];
            let dy = piy - means[rr * 2 + 1];
            let q = 1.0 / (1.0 + dx * dx + dy * dy);
            q_ir[rr] = q;
            dm[rr * 2] = dx;
            dm[rr * 2 + 1] = dy;
            a += w * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            q_in[s] = q;
            a += neg_w * q;
        }

        // ---- positive edges: loss + attraction + s_i --------------------
        let mut s_i = 0.0f32;
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, dx, dy) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
            s_i += w / z;
            let c_att = 2.0 * w * q * (1.0 - q / z);
            grad[i * 2] += c_att * dx;
            grad[i * 2 + 1] += c_att * dy;
            grad[j * 2] -= c_att * dx;
            grad[j * 2 + 1] -= c_att * dy;
        }

        if s_i == 0.0 {
            continue;
        }

        // ---- mean repulsion (means are stop-gradient) --------------------
        let mut gx = 0.0f32;
        let mut gy = 0.0f32;
        for rr in 0..r {
            let q = q_ir[rr];
            let c = mean_w[rr] * q * q;
            gx += c * dm[rr * 2];
            gy += c * dm[rr * 2 + 1];
        }
        grad[i * 2] -= 2.0 * s_i * gx;
        grad[i * 2 + 1] -= 2.0 * s_i * gy;

        // ---- exact-negative repulsion (both endpoints move) --------------
        if neg_w != 0.0 {
            for s in 0..negs {
                let nloc = neg_idx[i * negs + s] as usize;
                let q = q_in[s];
                let dx = pix - pos[nloc * 2];
                let dy = piy - pos[nloc * 2 + 1];
                let c = 2.0 * s_i * neg_w * q * q;
                grad[i * 2] -= c * dx;
                grad[i * 2 + 1] -= c * dy;
                grad[nloc * 2] += c * dx;
                grad[nloc * 2 + 1] += c * dy;
            }
        }
    }
    (loss_sum, nvalid)
}

/// Divide by the valid-head count — the mean-normalization both paths share.
fn finalize(mut grad: Vec<f32>, loss_sum: f64, nvalid: f64) -> (Vec<f32>, f64) {
    let inv = 1.0 / nvalid.max(1.0);
    for g in grad.iter_mut() {
        *g = (*g as f64 * inv) as f32;
    }
    // padding rows must not move even if scatter touched them (it cannot:
    // padding never appears as a neighbor/negative of a valid head)
    (grad, loss_sum * inv)
}

/// Assembled, mean-normalized NOMAD gradient for one padded block —
/// **serial oracle**.  Returns `(grad, mean_loss)` where `grad` is
/// size x 2 (padding rows 0).  Mirrors
/// `python/compile/kernels/ref.py::nomad_grad_ref` + `nomad_forces_ref`
/// with the scatter folded in.
pub fn nomad_grad_serial(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let mut grad = vec![0.0f32; size * 2];
    let (loss_sum, nvalid) = accumulate_heads(
        0, size, pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs, &mut grad,
    );
    finalize(grad, loss_sum, nvalid)
}

/// Parallel NOMAD gradient: fixed [`HEAD_CHUNK`]-head chunks with private
/// accumulators, reduced in chunk order (see the module docs).  `threads`
/// bounds the worker count; the *result* does not depend on it.  Falls back
/// to [`nomad_grad_serial`] when the block is a single chunk.
pub fn nomad_grad_threaded(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
    threads: usize,
) -> (Vec<f32>, f64) {
    let size = valid.len();
    let n_chunks = size.div_ceil(HEAD_CHUNK);
    if n_chunks <= 1 {
        return nomad_grad_serial(pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs);
    }
    let threads = threads.max(1).min(n_chunks);

    // per-chunk private accumulators (scatter targets cover the whole
    // block, so each buffer is full-size)
    let partials: Vec<(Vec<f32>, f64, f64)> = par_map(n_chunks, threads, |c| {
        let lo = c * HEAD_CHUNK;
        let hi = (lo + HEAD_CHUNK).min(size);
        let mut g = vec![0.0f32; size * 2];
        let (ls, nv) = accumulate_heads(
            lo, hi, pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid, k, negs, &mut g,
        );
        (g, ls, nv)
    });

    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    for (_, ls, nv) in &partials {
        loss_sum += *ls;
        nvalid += *nv;
    }

    // chunk-ordered reduction, parallel over disjoint coordinate ranges
    let mut grad = vec![0.0f32; size * 2];
    par_rows_mut(&mut grad, 2, REDUCE_ROWS, threads, |r0, rows| {
        for (p, _, _) in &partials {
            let src = &p[r0 * 2..r0 * 2 + rows.len()];
            for (d, s) in rows.iter_mut().zip(src) {
                *d += *s;
            }
        }
    });
    finalize(grad, loss_sum, nvalid)
}

/// Default-threaded NOMAD gradient (env/machine thread count).  This is the
/// signature the rest of the crate and the property tests use.
pub fn nomad_grad(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> (Vec<f32>, f64) {
    nomad_grad_threaded(
        pos,
        nbr_idx,
        nbr_w,
        neg_idx,
        neg_w,
        means,
        mean_w,
        valid,
        k,
        negs,
        num_threads(),
    )
}

/// Scalar NOMAD loss only (no gradient) — used by tests and line searches.
pub fn nomad_loss(
    pos: &[f32],
    nbr_idx: &[i32],
    nbr_w: &[f32],
    neg_idx: &[i32],
    neg_w: f32,
    means: &[f32],
    mean_w: &[f32],
    valid: &[f32],
    k: usize,
    negs: usize,
) -> f64 {
    let size = valid.len();
    let r = mean_w.len();
    let mut loss_sum = 0.0f64;
    let mut nvalid = 0.0f64;
    for i in 0..size {
        if valid[i] == 0.0 {
            continue;
        }
        nvalid += 1.0;
        let (pix, piy) = (pos[i * 2], pos[i * 2 + 1]);
        let mut a = 0.0f32;
        for rr in 0..r {
            let (q, _, _) = q2(pix, piy, means[rr * 2], means[rr * 2 + 1]);
            a += mean_w[rr] * q;
        }
        for s in 0..negs {
            let nloc = neg_idx[i * negs + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[nloc * 2], pos[nloc * 2 + 1]);
            a += neg_w * q;
        }
        for s in 0..k {
            let w = nbr_w[i * k + s];
            if w == 0.0 {
                continue;
            }
            let j = nbr_idx[i * k + s] as usize;
            let (q, _, _) = q2(pix, piy, pos[j * 2], pos[j * 2 + 1]);
            let z = q + a;
            loss_sum -= (w * (q.ln() - z.ln())) as f64;
        }
    }
    loss_sum / nvalid.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a random padded problem mirroring the python test generator.
    pub fn random_problem(
        rng: &mut Rng,
        size: usize,
        k: usize,
        negs: usize,
        r: usize,
        n_real: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<f32>, Vec<i32>, f32, Vec<f32>, Vec<f32>, Vec<f32>) {
        let pos: Vec<f32> = (0..size * 2).map(|_| rng.normal() * 3.0).collect();
        let mut nbr_idx = vec![0i32; size * k];
        let mut nbr_w = vec![0.0f32; size * k];
        let mut neg_idx = vec![0i32; size * negs];
        for i in 0..size {
            for s in 0..k {
                nbr_idx[i * k + s] = rng.below(n_real.max(1)) as i32;
                nbr_w[i * k + s] = if i < n_real { rng.f32() } else { 0.0 };
            }
            let wsum: f32 = nbr_w[i * k..(i + 1) * k].iter().sum();
            if wsum > 0.0 {
                for s in 0..k {
                    nbr_w[i * k + s] /= wsum;
                }
            }
            for s in 0..negs {
                neg_idx[i * negs + s] =
                    if i < n_real { rng.below(n_real.max(1)) as i32 } else { i as i32 };
            }
        }
        let neg_w = rng.f32() + 0.1;
        let means: Vec<f32> = (0..r * 2).map(|_| rng.normal() * 3.0).collect();
        let mean_w: Vec<f32> = (0..r).map(|_| rng.f32() * 4.0).collect();
        let mut valid = vec![0.0f32; size];
        for v in valid.iter_mut().take(n_real) {
            *v = 1.0;
        }
        (pos, nbr_idx, nbr_w, neg_idx, neg_w, means, mean_w, valid)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Rng::new(0);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 28);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        let eps = 3e-4f32;
        for probe in [0usize, 5, 11, 23, 54] {
            let mut pp = pos.clone();
            pp[probe] += eps;
            let lp = nomad_loss(&pp, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let mut pm = pos.clone();
            pm[probe] -= eps;
            let lm = nomad_loss(&pm, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
            let fd = (lp - lm) / (2.0 * eps as f64);
            let an = grad[probe] as f64;
            assert!(
                (fd - an).abs() < 3e-3 * (1.0 + an.abs()),
                "coord {probe}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn padding_rows_have_zero_gradient() {
        let mut rng = Rng::new(1);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 48, 5, 3, 4, 30);
        let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 5, 3);
        for l in 30..48 {
            assert_eq!(grad[l * 2], 0.0);
            assert_eq!(grad[l * 2 + 1], 0.0);
        }
    }

    #[test]
    fn parallel_grad_matches_serial_oracle() {
        let mut rng = Rng::new(11);
        for &(size, k, negs, r, n_real) in
            &[(512usize, 6usize, 4usize, 33usize, 480usize), (384, 5, 3, 17, 300)]
        {
            let (pos, ni, nw, gi, gw, me, mw, va) =
                random_problem(&mut rng, size, k, negs, r, n_real);
            let (gs, ls) = nomad_grad_serial(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs);
            let (gp, lp) =
                nomad_grad_threaded(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, k, negs, 4);
            assert!(
                (ls - lp).abs() < 1e-5 * (1.0 + ls.abs()),
                "loss serial {ls} vs parallel {lp}"
            );
            for i in 0..size * 2 {
                let d = (gs[i] - gp[i]).abs();
                assert!(
                    d < 1e-5 * (1.0 + gs[i].abs()),
                    "size {size} coord {i}: serial {} parallel {}",
                    gs[i],
                    gp[i]
                );
            }
            // padding rows stay exactly zero on the parallel path too
            for l in n_real..size {
                assert_eq!(gp[l * 2], 0.0);
                assert_eq!(gp[l * 2 + 1], 0.0);
            }
        }
    }

    #[test]
    fn threaded_grad_invariant_to_thread_count() {
        let mut rng = Rng::new(12);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 512, 6, 4, 20, 500);
        let (g1, l1) = nomad_grad_threaded(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 1);
        let (g2, l2) = nomad_grad_threaded(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 2);
        let (g8, l8) = nomad_grad_threaded(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4, 8);
        assert_eq!(g1, g2, "1 vs 2 workers must be bitwise identical");
        assert_eq!(g2, g8, "2 vs 8 workers must be bitwise identical");
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(l2.to_bits(), l8.to_bits());
    }

    #[test]
    fn steps_reduce_loss() {
        let mut rng = Rng::new(2);
        let (mut pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 64, 6, 4, 6, 64);
        let l0 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        for _ in 0..20 {
            let (grad, _) = nomad_grad(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
            for (p, g) in pos.iter_mut().zip(&grad) {
                *p -= 3.0 * g;
            }
        }
        let l1 = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 6, 4);
        assert!(l1 < l0, "loss {l0} -> {l1}");
    }

    #[test]
    fn loss_invariant_under_padding_growth() {
        let mut rng = Rng::new(3);
        let (pos, ni, nw, gi, gw, me, mw, va) = random_problem(&mut rng, 32, 4, 3, 5, 32);
        let l = nomad_loss(&pos, &ni, &nw, &gi, gw, &me, &mw, &va, 4, 3);
        // grow to 64 with padding
        let mut pos2 = pos.clone();
        pos2.extend(std::iter::repeat(0.0).take(64));
        let mut ni2 = ni.clone();
        let mut nw2 = nw.clone();
        let mut gi2 = gi.clone();
        let mut va2 = va.clone();
        for l2 in 32..64 {
            for _ in 0..4 {
                ni2.push(l2 as i32);
                nw2.push(0.0);
            }
            for _ in 0..3 {
                gi2.push(l2 as i32);
            }
            va2.push(0.0);
        }
        let lp = nomad_loss(&pos2, &ni2, &nw2, &gi2, gw, &me, &mw, &va2, 4, 3);
        assert!((l - lp).abs() < 1e-9);
    }
}
